//! Compile once, serve many: pack a model's RSR plans to `.rsrz`
//! artifacts, then serve them from a shared `PlanStore` across worker
//! threads — the production deployment shape (`rsr pack` + `rsr serve
//! --plans`), in miniature.
//!
//! ```sh
//! cargo run --release --example plan_store
//! ```

use std::sync::Arc;
use std::time::Instant;

use rsr::kernels::artifact::{ternary_fingerprint, PlanArtifact};
use rsr::kernels::index::TernaryRsrIndex;
use rsr::kernels::optimal_k::optimal_k_rsrpp;
use rsr::model::config::ModelConfig;
use rsr::model::sampler::Sampler;
use rsr::model::transformer::Transformer;
use rsr::model::weights::ModelWeights;
use rsr::runtime::PlanStore;
use rsr::util::rng::Rng;

fn main() -> rsr::Result<()> {
    // A trained 1.58-bit model (synthetic stand-in; see model::weights).
    let weights = Arc::new(ModelWeights::generate(ModelConfig::tiny(), 42)?);
    let names = weights.matrix_names();
    println!("model `{}`: {} ternary matrices", weights.config.name, names.len());

    // ── 1. PACK (offline, once) ─────────────────────────────────────
    // Algorithm 1 over every weight matrix, serialized to versioned,
    // checksummed .rsrz artifacts. This is `rsr pack`.
    let dir = std::env::temp_dir().join(format!("rsr-example-plans-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let t0 = Instant::now();
    let (mut disk, mut dense) = (0usize, 0usize);
    for (name, m, scale) in weights.named_matrices() {
        let k = optimal_k_rsrpp(m.rows());
        let art = PlanArtifact::ternary(name.clone(), TernaryRsrIndex::preprocess(m, k), scale)?
            .with_weights_fingerprint(ternary_fingerprint(m));
        disk += art.meta.payload_bytes;
        dense += art.meta.dense_f32_bytes();
        art.save(dir.join(format!("{name}.rsrz")))?;
    }
    println!(
        "packed in {:.1} ms → {:.1} KB of artifacts ({:.1} KB dense f32)",
        t0.elapsed().as_secs_f64() * 1e3,
        disk as f64 / 1024.0,
        dense as f64 / 1024.0,
    );

    // ── 2. SERVE (every process start, many times) ──────────────────
    // One store per process; plans load lazily, each exactly once.
    let t0 = Instant::now();
    let store = Arc::new(PlanStore::open(&dir)?);
    store.preload(&names)?;
    println!(
        "store loaded {} plans in {:.1} ms ({:.1} KB shared index)",
        store.loaded_len(),
        t0.elapsed().as_secs_f64() * 1e3,
        store.index_bytes() as f64 / 1024.0,
    );

    // Worker threads share the store: each builds a Transformer whose
    // BitLinear layers execute the SAME Arc'd indices with private
    // scratch. No preprocessing happens on these threads.
    let prompt: Vec<u32> = "What is 2+2?".bytes().map(|b| b as u32).collect();
    let mut handles = Vec::new();
    for wid in 0..3 {
        let store = Arc::clone(&store);
        let weights = Arc::clone(&weights);
        let prompt = prompt.clone();
        handles.push(std::thread::spawn(move || -> rsr::Result<(usize, Vec<u32>)> {
            let t0 = Instant::now();
            let mut model = Transformer::from_plan_store(&weights, &store)?;
            let build_ms = t0.elapsed().as_secs_f64() * 1e3;
            let mut rng = Rng::new(0);
            let tokens = model.generate(&prompt, 8, Sampler::Greedy, &mut rng)?;
            println!("  worker {wid}: model ready in {build_ms:.1} ms (no preprocessing)");
            Ok((wid, tokens))
        }));
    }
    let mut outputs = Vec::new();
    for h in handles {
        outputs.push(h.join().expect("worker panicked")?);
    }

    // ── 3. VERIFY ───────────────────────────────────────────────────
    // Store-served workers must agree with a freshly preprocessed
    // in-memory model, token for token.
    let mut reference =
        Transformer::from_weights(&weights, rsr::kernels::Backend::RsrPlusPlus, 0)?;
    let mut rng = Rng::new(0);
    let expect = reference.generate(&prompt, 8, Sampler::Greedy, &mut rng)?;
    for (wid, tokens) in &outputs {
        assert_eq!(tokens, &expect, "worker {wid} diverged");
    }
    println!("all {} workers match the in-memory reference: {:?}", outputs.len(), expect);

    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
