//! Model-weight compression (paper Fig 5 / §5.2 deployment story):
//! preprocess a full 1.58-bit model's weights into RSR indices, write
//! both forms to disk, and compare sizes — "companies training new
//! LLMs could preprocess their weights to release only the final
//! segments, permutations, and the optimal parameter k".
//!
//! ```sh
//! cargo run --release --example compression
//! ```

use rsr::kernels::index::TernaryRsrIndex;
use rsr::kernels::optimal_k::optimal_k_rsrpp;
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;

fn main() -> rsr::Result<()> {
    let cfg = ModelConfig::small_125m();
    println!(
        "generating {} (~{:.0}M params)...",
        cfg.name,
        cfg.param_count() as f64 / 1e6
    );
    let weights = ModelWeights::generate(cfg.clone(), 2025)?;

    let dir = std::env::temp_dir().join("rsr_compression_example");
    std::fs::create_dir_all(&dir)?;

    // Ship form A: raw ternary checkpoint (.rtw, 2-bit packed).
    let rtw = dir.join("model.rtw");
    weights.save(&rtw)?;
    let rtw_bytes = std::fs::metadata(&rtw)?.len();

    // Ship form B: RSR indices per weight matrix (.rsi each).
    let k = optimal_k_rsrpp(cfg.d_model);
    let mut index_bytes = 0u64;
    let mut n_matrices = 0;
    for (li, lw) in weights.layers.iter().enumerate() {
        for (name, m) in [
            ("wq", &lw.wq),
            ("wk", &lw.wk),
            ("wv", &lw.wv),
            ("wo", &lw.wo),
            ("gate", &lw.gate),
            ("up", &lw.up),
            ("down", &lw.down),
        ] {
            let idx = TernaryRsrIndex::preprocess(m, k);
            let path = dir.join(format!("layer{li}_{name}_plus.rsi"));
            idx.plus.save(&path)?;
            let path_m = dir.join(format!("layer{li}_{name}_minus.rsi"));
            idx.minus.save(&path_m)?;
            index_bytes +=
                std::fs::metadata(&path)?.len() + std::fs::metadata(&path_m)?.len();
            n_matrices += 1;
        }
    }

    // What a dense f32 release (the NumPy-style baseline) would be.
    let dense_f32: u64 = weights
        .layers
        .iter()
        .flat_map(|lw| {
            [&lw.wq, &lw.wk, &lw.wv, &lw.wo, &lw.gate, &lw.up, &lw.down]
        })
        .map(|m| (m.rows() * m.cols() * 4) as u64)
        .sum();

    println!("\n{} weight matrices, k = {k}", n_matrices * 1);
    println!("dense f32 release:        {:>8.1} MB", dense_f32 as f64 / 1048576.0);
    println!("2-bit ternary checkpoint: {:>8.1} MB (.rtw)", rtw_bytes as f64 / 1048576.0);
    println!("RSR index release:        {:>8.1} MB (.rsi)", index_bytes as f64 / 1048576.0);
    println!(
        "index vs dense f32:       {:>8.2}x smaller — and inference-ready \
         (no preprocessing on the client)",
        dense_f32 as f64 / index_bytes as f64
    );

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
