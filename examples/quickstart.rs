//! Quickstart: preprocess a ternary weight matrix once, multiply many
//! times — the paper's core loop in five steps.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use rsr::kernels::index::TernaryRsrIndex;
use rsr::kernels::optimal_k::optimal_k_rsrpp;
use rsr::kernels::rsrpp::TernaryRsrPlusPlusPlan;
use rsr::kernels::standard::standard_mul_ternary;
use rsr::kernels::TernaryMatrix;
use rsr::util::rng::Rng;

fn main() -> rsr::Result<()> {
    let n = 4096;
    let mut rng = Rng::new(7);

    // 1. A fixed ternary weight matrix (what a trained 1.58-bit model
    //    ships) and an activation vector arriving at inference time.
    let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);

    // 2. Choose the blocking parameter k (Eq 7's analytic optimum).
    let k = optimal_k_rsrpp(n);
    println!("n = {n}, optimal k = {k}");

    // 3. Preprocess ONCE (paper Algorithm 1: blocking → binary row
    //    order → full segmentation, on both Prop 2.1 halves).
    let t0 = std::time::Instant::now();
    let index = TernaryRsrIndex::preprocess(&a, k);
    println!(
        "preprocessed in {:.1} ms — index {:.1} MB vs {:.1} MB dense f32",
        t0.elapsed().as_secs_f64() * 1e3,
        index.bytes() as f64 / 1048576.0,
        (n * n * 4) as f64 / 1048576.0,
    );

    // 4. Multiply MANY times (paper Algorithm 2 + 3).
    let mut plan = TernaryRsrPlusPlusPlan::new(index)?;
    let mut out = vec![0.0f32; n];
    let t0 = std::time::Instant::now();
    let reps = 20;
    for _ in 0..reps {
        plan.execute(&v, &mut out)?;
    }
    let rsr_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    // 5. Compare with the standard O(n²) multiplies. Two baselines:
    //    the naive branchy loop (the paper's "Standard" — what a plain
    //    C++ implementation does) and an auto-vectorized multiply loop
    //    (the strongest dense CPU baseline; see the ablations bench).
    let t0 = std::time::Instant::now();
    let expect = rsr::kernels::standard::standard_mul_ternary_i8(&v, &a);
    let naive_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = std::time::Instant::now();
    let expect2 = standard_mul_ternary(&v, &a);
    let vec_ms = t0.elapsed().as_secs_f64() * 1e3;
    let max_err = out
        .iter()
        .zip(expect.iter())
        .map(|(g, e)| (g - e).abs())
        .fold(0.0f32, f32::max);
    drop(expect2);

    println!("RSR++:                {rsr_ms:.3} ms/multiply");
    println!("Standard (naive):     {naive_ms:.3} ms/multiply  -> {:.1}x speedup", naive_ms / rsr_ms);
    println!("Standard (vectorized):{vec_ms:.3} ms/multiply  -> {:.1}x", vec_ms / rsr_ms);
    println!("max |err| = {max_err:.2e}");
    assert!(max_err < 1e-2, "results must agree");
    Ok(())
}
