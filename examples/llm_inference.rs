//! END-TO-END DRIVER (DESIGN.md §6): the full system on a real small
//! workload, proving all layers compose.
//!
//! 1. Generate a ~125M-parameter-shape 1.58-bit transformer
//!    (`small-125m` preset) and save/load it through the `.rtw` format.
//! 2. Preprocess every weight matrix into RSR indices (Algorithm 1) —
//!    once, inside the serving engine's workers.
//! 3. Serve batched synthetic ShortQuestions requests through the
//!    whole coordinator (queue → batcher → scheduler → workers),
//!    decoding greedily, on BOTH the Standard backend and RSR++.
//! 4. Assert token-level output equality between backends (the paper's
//!    §5.3 check) and report per-token latency + throughput for both.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! cargo run --release --example llm_inference          # ~125M model
//! RSR_E2E_SMALL=1 cargo run --release --example llm_inference  # quick
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rsr::data::datasets::{Dataset, DatasetKind};
use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::tokenizer::Tokenizer;
use rsr::model::weights::ModelWeights;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::request::Request;

struct RunReport {
    tokens: HashMap<u64, Vec<u32>>,
    wall: Duration,
    decode_us_per_tok: f64,
    tokens_out: u64,
}

fn run_backend(
    weights: &Arc<ModelWeights>,
    backend: Backend,
    requests: &[(u64, Vec<u32>, usize)],
) -> rsr::Result<RunReport> {
    println!("  [{}] starting engine (preprocessing weights)...", backend.name());
    let t0 = Instant::now();
    let engine = InferenceEngine::start(
        Arc::clone(weights),
        EngineConfig { workers: 1, backend, ..Default::default() },
    )?;
    println!(
        "  [{}] engine ready in {:.1}s",
        backend.name(),
        t0.elapsed().as_secs_f64()
    );

    let t0 = Instant::now();
    for (id, prompt, max_new) in requests {
        engine.submit(Request::new(*id, prompt.clone(), *max_new))?;
    }
    let mut tokens = HashMap::new();
    let mut decode_us = 0.0;
    let mut tokens_out = 0u64;
    for _ in 0..requests.len() {
        let resp = engine
            .recv_timeout(Duration::from_secs(600))
            .ok_or_else(|| rsr::Error::Serving("timeout".into()))?;
        if let Some(e) = resp.error {
            return Err(rsr::Error::Serving(e));
        }
        decode_us += resp.timing.decode.as_micros() as f64;
        tokens_out += resp.tokens.len() as u64;
        tokens.insert(resp.id, resp.tokens);
    }
    let wall = t0.elapsed();
    engine.shutdown();
    Ok(RunReport {
        tokens,
        wall,
        decode_us_per_tok: decode_us / tokens_out.max(1) as f64,
        tokens_out,
    })
}

fn main() -> rsr::Result<()> {
    let quick = std::env::var("RSR_E2E_SMALL").is_ok();
    let cfg = if quick {
        ModelConfig::tiny()
    } else {
        ModelConfig::small_125m()
    };
    println!(
        "== end-to-end driver: {} (~{:.0}M params, d={}, {} layers) ==",
        cfg.name,
        cfg.param_count() as f64 / 1e6,
        cfg.d_model,
        cfg.n_layers
    );

    // 1. Generate + round-trip through the on-disk format.
    let t0 = Instant::now();
    let weights = ModelWeights::generate(cfg, 20_250_711)?;
    let path = std::env::temp_dir().join("rsr_e2e_model.rtw");
    weights.save(&path)?;
    let weights = Arc::new(ModelWeights::load(&path)?);
    println!(
        "generated + save/load round-trip in {:.1}s ({:.1} MB on disk)",
        t0.elapsed().as_secs_f64(),
        std::fs::metadata(&path)?.len() as f64 / 1048576.0
    );

    // 2. The workload: synthetic ShortQuestions, a few tokens each.
    let n_requests = if quick { 4 } else { 6 };
    let max_new = if quick { 4 } else { 6 };
    let ds = Dataset::generate(DatasetKind::ShortQuestions, n_requests, 42);
    let tokenizer = Tokenizer::new();
    let requests: Vec<(u64, Vec<u32>, usize)> = ds
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| (i as u64, tokenizer.encode_with_bos(p), max_new))
        .collect();
    println!("workload: {n_requests} prompts x {max_new} new tokens\n");

    // 3. Serve on both backends.
    let std_report = run_backend(&weights, Backend::Standard, &requests)?;
    let rsr_report = run_backend(&weights, Backend::RsrPlusPlus, &requests)?;

    // 4. Equality check + report.
    for (id, _, _) in &requests {
        assert_eq!(
            std_report.tokens[id], rsr_report.tokens[id],
            "backend outputs diverged on request {id}"
        );
    }
    println!("\nALL OUTPUTS EQUAL across backends (paper §5.3 check) ✓\n");
    for (name, r) in [("Standard", &std_report), ("RSR++", &rsr_report)] {
        println!(
            "{name:>9}: {:>6.2}s wall, {:>7.0} µs/token decode, {:.2} tok/s",
            r.wall.as_secs_f64(),
            r.decode_us_per_tok,
            r.tokens_out as f64 / r.wall.as_secs_f64(),
        );
    }
    println!(
        "\nper-token decode speedup (RSR++ vs Standard): {:.2}x",
        std_report.decode_us_per_tok / rsr_report.decode_us_per_tok
    );

    // Show one exchange for flavor.
    let (id0, prompt0, _) = &requests[0];
    println!(
        "\nsample: {:?} -> {} greedy tokens (identical on both backends)",
        ds.prompts[*id0 as usize],
        rsr_report.tokens[id0].len()
    );
    let _ = prompt0;
    std::fs::remove_file(&path).ok();
    Ok(())
}
