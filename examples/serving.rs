//! Serving: bring up the full L3 stack (router → batcher → scheduler →
//! engine workers) on a TCP port, drive it with concurrent clients
//! replaying a Poisson trace of synthetic questions, and report
//! latency/throughput from the metrics sink.
//!
//! ```sh
//! cargo run --release --example serving
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use rsr::data::datasets::{Dataset, DatasetKind};
use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::router::Router;
use rsr::serving::client::Client;
use rsr::serving::server::Server;

fn main() -> rsr::Result<()> {
    // A small-but-real model so the example finishes in ~a minute.
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = 256;
    cfg.d_ff = 512;
    cfg.n_heads = 8;
    cfg.n_kv_heads = 4;
    cfg.n_layers = 4;
    println!("building {} (~{:.1}M params)...", cfg.name, cfg.param_count() as f64 / 1e6);
    let weights = Arc::new(ModelWeights::generate(cfg, 11)?);

    let engine = Arc::new(InferenceEngine::start(
        Arc::clone(&weights),
        EngineConfig { workers: 2, backend: Backend::RsrPlusPlus, ..Default::default() },
    )?);
    let router = Arc::new(Router::new(vec![Arc::clone(&engine)])?);
    let server = Server::new(Arc::clone(&router));

    // Bind on an ephemeral port.
    let stop = Arc::new(AtomicBool::new(false));
    let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::default();
    let bound2 = Arc::clone(&bound);
    let stop2 = Arc::clone(&stop);
    let server_thread = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", stop2, move |addr| {
                *bound2.lock().unwrap() = Some(addr);
            })
            .unwrap();
    });
    let addr = loop {
        if let Some(a) = *bound.lock().unwrap() {
            break a;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    println!("server bound on {addr}");

    // Drive it: 3 concurrent clients × questions from the synthetic
    // ShortQuestions dataset.
    let ds = Dataset::generate(DatasetKind::ShortQuestions, 12, 77);
    let t0 = Instant::now();
    let mut client_threads = Vec::new();
    for (ci, chunk) in ds.prompts.chunks(4).enumerate() {
        let prompts: Vec<String> = chunk.to_vec();
        client_threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).expect("connect");
            let mut lines = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                let reply = client
                    .prompt((ci * 100 + i) as u64, p)
                    .max_new(8)
                    .send_json()
                    .expect("request");
                lines.push(format!(
                    "client{ci}: {:<46} -> {} tok, {}µs decode",
                    p,
                    reply.get("tokens").and_then(|t| t.as_arr()).map_or(0, |a| a.len()),
                    reply
                        .get("decode_us")
                        .and_then(|x| x.as_f64())
                        .unwrap_or(0.0)
                ));
            }
            lines
        }));
    }
    let mut completed = 0;
    for t in client_threads {
        for line in t.join().unwrap() {
            println!("{line}");
            completed += 1;
        }
    }
    let elapsed = t0.elapsed();

    // Report.
    let snap = engine.metrics().snapshot();
    println!("\n--- metrics ---");
    println!("{}", snap.to_string());
    println!(
        "\n{completed} requests in {:.2}s = {:.1} req/s; tokens out: {}",
        elapsed.as_secs_f64(),
        completed as f64 / elapsed.as_secs_f64(),
        snap.get("tokens_out").and_then(|x| x.as_f64()).unwrap_or(0.0),
    );

    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
    Ok(())
}
