//! `rsr` — the command-line entrypoint of the RSR/RSR++ reproduction.
//!
//! ```text
//! rsr preprocess  --n 4096 --k 0 --out idx.rsi        # Algorithm 1
//! rsr multiply    --n 4096 --backend rsr++ [--check]  # one product
//! rsr generate-model --preset tiny --out model.rtw    # synthetic 1.58-bit model
//! rsr pack        --model model.rtw --out plans/      # compile-once: .rsrz plan artifacts
//! rsr tune        --weights model.rtw --out model.rsrt [--budget-ms N]  # measure (k, backend)/layer
//! rsr inspect     --plans plans/ [--deep]             # artifact/.rsrt stats, integrity
//! rsr serve       --model model.rtw [--plans plans/] [--profile model.rsrt] --addr 0.0.0.0:7878
//! rsr client      --addr 127.0.0.1:7878 --prompt "What is the capital of France?" [--stream]
//! rsr drain       --addr 127.0.0.1:7878                # graceful drain: finish, refuse new, exit
//! rsr experiment  fig4|fig5|fig6|fig9|fig10|fig11|fig12|table1|ablations [--full]
//! rsr selfcheck                                        # cross-backend sanity
//! rsr artifacts                                        # list AOT artifacts
//! ```
//!
//! (clap is unavailable in the offline registry; parsing is manual.)

// Same style-class allowances as the library crate root (CI runs
// `clippy -D warnings` over both).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::collapsible_if,
    clippy::field_reassign_with_default
)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use rsr::bench::harness::Table;
use rsr::error::{Error, Result};
use rsr::kernels::artifact::{ternary_fingerprint, PlanArtifact};
use rsr::kernels::index::{RsrIndex, TernaryRsrIndex};
use rsr::kernels::optimal_k::{optimal_k_rsr, optimal_k_rsrpp};
use rsr::kernels::{Backend, BinaryMatrix, TernaryMatrix};
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::router::Router;
use rsr::serving::client::Client;
use rsr::serving::server::{Server, ServerIdentity};
use rsr::tune::{human_ns, tune_model, TuneOpts, TuneProfile};
use rsr::util::json::Json;
use rsr::util::obs::{set_log_level, Level};
use rsr::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        rsr::log!(Level::Error, "{e}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs after the subcommand.
fn flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn get_usize(f: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match f.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| Error::Config(format!("--{key} expects an integer, got {v}"))),
    }
}

/// Parse a byte size with an optional `K`/`M`/`G` suffix (decimal
/// digits, binary multipliers): `512M`, `2G`, `65536`.
fn parse_byte_size(v: &str, flag: &str) -> Result<u64> {
    let s = v.trim();
    let err = || {
        Error::Config(format!(
            "--{flag} expects a byte size like 512M, 2G or 65536, got {v}"
        ))
    };
    let (digits, mult) = match s.chars().last() {
        Some('k') | Some('K') => (&s[..s.len() - 1], 1u64 << 10),
        Some('m') | Some('M') => (&s[..s.len() - 1], 1u64 << 20),
        Some('g') | Some('G') => (&s[..s.len() - 1], 1u64 << 30),
        Some(c) if c.is_ascii_digit() => (s, 1u64),
        _ => return Err(err()),
    };
    let n: u64 = digits.trim().parse().map_err(|_| err())?;
    n.checked_mul(mult).ok_or_else(err)
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    let f = flags(rest);
    match cmd.as_str() {
        "preprocess" => cmd_preprocess(&f),
        "multiply" => cmd_multiply(&f),
        "generate-model" => cmd_generate_model(&f),
        "pack" => cmd_pack(&f),
        "tune" => cmd_tune(&f),
        "inspect" => cmd_inspect(&f),
        "serve" => cmd_serve(&f),
        "client" => cmd_client(&f),
        "metrics" => cmd_metrics(&f),
        "status" => cmd_status(&f),
        "trace" => cmd_trace(&f),
        "drain" => cmd_drain(&f),
        "bench-kernels" => cmd_bench_kernels(&f),
        "bench-serve" => cmd_bench_serve(&f),
        "bench-prefill" => cmd_bench_prefill(&f),
        "experiment" => cmd_experiment(rest, &f),
        "selfcheck" => cmd_selfcheck(),
        "artifacts" => cmd_artifacts(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command {other} (try `rsr help`)"))),
    }
}

fn print_help() {
    println!(
        "rsr — RSR/RSR++ efficient binary/ternary matmul (ICML 2025 reproduction)\n\n\
         commands:\n  \
         preprocess     --n N [--k K] [--seed S] [--out FILE]   build a block index\n  \
         multiply       --n N [--backend B] [--k K] [--check]   run one v·A product\n  \
         generate-model [--preset P] [--seed S] --out FILE      synthetic 1.58-bit model\n  \
         pack           --model FILE | --n N  --out DIR [--k K] [--profile FILE.rsrt]  preprocess to .rsrz\n  \
         tune           --weights FILE --out FILE.rsrt [--budget-ms N] [--radius R] [--trials T]\n  \
         inspect        --plans DIR | --file FILE [--deep] [--verify]  .rsrz / .rsrt stats, integrity\n  \
         serve          --model FILE [--plans DIR] [--profile FILE.rsrt] [--addr A] [--replicas R] [--workers W] [--max-slots S] [--prefill-chunk C] [--backend B] [--kv-budget BYTES] [--kv-page-tokens N] [--default-deadline-ms D] [--replica-stall-ms S] [--log-level L] [--trace-slow-ms T] [--profile-layers]\n  \
         client         [--addr A] --prompt TEXT [--max-new N] [--deadline-ms D] [--stream]\n  \
         metrics        [--addr A] [--prom] [--watch SECS]      scrape a live server's metrics\n  \
         status         [--addr A]                              live server identity + gauges\n  \
         trace          [--addr A]                              dump request trace timelines\n  \
         drain          [--addr A]                              graceful drain: finish work, refuse new, exit\n  \
         bench-kernels  [--sizes 1024,4096] [--shapes 4096x11008] [--reps N] [--batch B] [--threads T] [--json FILE]\n  \
         bench-serve    [--batches 1,4,8,16] [--d-model 1024] [--d-ff 2048] [--layers 1] [--steps 32] [--prompt 4] [--prompt-lens 16,128,512] [--prefill-chunk 8] [--overload-requests 48] [--overload-rps 2000] [--overload-deadline-ms 60] [--json FILE]\n  \
         bench-prefill  [--chunks 1,4,8,16] [--d-model 1024] [--d-ff 2048] [--layers 1] [--prompt 256] [--trials 3] [--json FILE]\n  \
         experiment     <fig4|fig5|fig6|fig9|fig10|fig11|fig12|table1|ablations|all> [--full]\n  \
         selfcheck                                              cross-backend equality\n  \
         artifacts                                              list AOT artifacts\n\n\
         backends: standard standard-packed rsr rsr++ rsr-parallel tensorized\n\
         presets:  {}",
        ModelConfig::PRESETS.join(" ")
    );
}

fn cmd_preprocess(f: &HashMap<String, String>) -> Result<()> {
    let n = get_usize(f, "n", 4096)?;
    let seed = get_usize(f, "seed", 42)? as u64;
    let k = match get_usize(f, "k", 0)? {
        0 => optimal_k_rsrpp(n),
        k => k,
    };
    let mut rng = Rng::new(seed);
    let b = BinaryMatrix::random(n, n, 0.5, &mut rng);
    let t0 = std::time::Instant::now();
    let idx = RsrIndex::preprocess(&b, k);
    let dt = t0.elapsed();
    println!(
        "preprocessed {n}x{n} (k={k}) in {:.1}ms: {} blocks, index {:.2} MB \
         (dense f32 would be {:.2} MB)",
        dt.as_secs_f64() * 1e3,
        idx.blocks.len(),
        idx.bytes() as f64 / 1048576.0,
        (n * n * 4) as f64 / 1048576.0
    );
    if let Some(path) = f.get("out") {
        idx.save(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_multiply(f: &HashMap<String, String>) -> Result<()> {
    let n = get_usize(f, "n", 4096)?;
    let seed = get_usize(f, "seed", 42)? as u64;
    let k = get_usize(f, "k", 0)?;
    let backend = f
        .get("backend")
        .map(|s| {
            Backend::from_name(s)
                .ok_or_else(|| Error::Config(format!("unknown backend {s}")))
        })
        .transpose()?
        .unwrap_or(Backend::RsrPlusPlus);
    let check = f.contains_key("check");

    let mut rng = Rng::new(seed);
    let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);
    let mut layer = rsr::model::bitlinear::BitLinear::new(a.clone(), 1.0, backend, k)?;
    let mut out = vec![0.0f32; n];

    let t0 = std::time::Instant::now();
    layer.forward(&v, &mut out)?;
    let dt = t0.elapsed();
    println!(
        "{} multiply {n}x{n}: {:.3} ms (out[0..4] = {:?})",
        backend.name(),
        dt.as_secs_f64() * 1e3,
        &out[..4.min(n)]
    );
    if check {
        let expect = rsr::kernels::standard::standard_mul_ternary(&v, &a);
        let max_err = out
            .iter()
            .zip(expect.iter())
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f32, f32::max);
        println!("max |err| vs standard: {max_err:.2e}");
        if max_err > 1e-2 {
            return Err(Error::Config("check FAILED".into()));
        }
        println!("check OK");
    }
    Ok(())
}

fn cmd_generate_model(f: &HashMap<String, String>) -> Result<()> {
    let preset = f.get("preset").map(|s| s.as_str()).unwrap_or("tiny");
    let seed = get_usize(f, "seed", 42)? as u64;
    let out = f
        .get("out")
        .ok_or_else(|| Error::Config("generate-model requires --out FILE".into()))?;
    let cfg = ModelConfig::preset(preset)
        .ok_or_else(|| Error::Config(format!("unknown preset {preset}")))?;
    println!(
        "generating {} (~{:.0}M params, d={}, layers={})...",
        cfg.name,
        cfg.param_count() as f64 / 1e6,
        cfg.d_model,
        cfg.n_layers
    );
    let weights = ModelWeights::generate(cfg, seed)?;
    weights.save(out)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_serve(f: &HashMap<String, String>) -> Result<()> {
    let model_path = f
        .get("model")
        .ok_or_else(|| Error::Config("serve requires --model FILE".into()))?;
    let addr = f.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".into());
    let replicas = get_usize(f, "replicas", 1)?.max(1);
    let workers = get_usize(f, "workers", 2)?.max(1);
    let backend = f
        .get("backend")
        .map(|s| {
            Backend::from_name(s)
                .ok_or_else(|| Error::Config(format!("unknown backend {s}")))
        })
        .transpose()?
        .unwrap_or(Backend::RsrPlusPlus);

    let plans = f.get("plans").map(PathBuf::from);
    let profile = f.get("profile").map(PathBuf::from);
    let k = get_usize(f, "k", 0)?;
    // Observability knobs (all default-off; defaults add nothing to
    // the decode hot path — see ARCHITECTURE.md §Observability).
    if let Some(level) = f.get("log-level") {
        let l = Level::parse(level).ok_or_else(|| {
            Error::Config(format!(
                "unknown --log-level {level} (error|warn|info|debug)"
            ))
        })?;
        set_log_level(l);
    }
    // Presence-based: `--trace-slow-ms 0` is a valid threshold (pin
    // every request), absence turns tracing off entirely.
    let trace_slow_ms = f
        .get("trace-slow-ms")
        .map(|v| {
            v.parse::<u64>().map_err(|_| {
                Error::Config(format!("--trace-slow-ms expects an integer, got {v}"))
            })
        })
        .transpose()?;
    let profile_layers = f.contains_key("profile-layers");
    // Continuous-batching knobs: concurrent decode slots per worker
    // (1 serves strictly sequentially — the pre-batching path) and the
    // chunked-prefill chunk (1 feeds prompts one token per step — the
    // pre-chunking path; larger values cut time-to-first-token by
    // stacking prompt tokens along the batched kernels' batch axis).
    let batch = rsr::serving::batcher::BatchPolicy {
        max_slots: get_usize(
            f,
            "max-slots",
            rsr::serving::batcher::BatchPolicy::default().max_slots,
        )?
        .max(1),
        prefill_chunk: get_usize(
            f,
            "prefill-chunk",
            rsr::serving::batcher::BatchPolicy::default().prefill_chunk,
        )?
        .max(1),
        ..Default::default()
    };

    // Memory governance: --kv-budget caps the bytes the paged KV cache
    // may hold across every layer × slot × worker of a replica (absent
    // = unbounded, the pre-budget behavior, bit-identical serving);
    // --kv-page-tokens sets the page granularity.
    let kv_budget = f
        .get("kv-budget")
        .map(|v| parse_byte_size(v, "kv-budget"))
        .transpose()?;
    let kv_page_tokens =
        get_usize(f, "kv-page-tokens", EngineConfig::default().kv_page_tokens)?.max(1);

    println!("loading {model_path}...");
    let weights = Arc::new(ModelWeights::load(model_path)?);

    // One process-wide plan store on the RSR++ path: every replica and
    // every worker thread shares the same compiled plans (the
    // compile-once/serve-many contract; the (plans, backend, profile)
    // policy lives in InferenceEngine::build_plan_store).
    let cfg = EngineConfig {
        workers,
        backend,
        k,
        batch,
        plan_dir: plans.clone(),
        tune_profile: profile.clone(),
        trace_slow_ms,
        profile_layers,
        kv_budget,
        kv_page_tokens,
        ..Default::default()
    };
    if let Some(dir) = &plans {
        println!("opening plan artifacts in {}...", dir.display());
    }
    let t0 = std::time::Instant::now();
    let store = InferenceEngine::build_plan_store(&weights, &cfg)?;
    if let Some(s) = &store {
        if plans.is_some() {
            println!(
                "loaded {} plans ({:.1} MB shared index) in {:.1}ms",
                s.loaded_len(),
                s.index_bytes() as f64 / 1048576.0,
                t0.elapsed().as_secs_f64() * 1e3
            );
        }
    }

    println!(
        "model {} loaded; {} replica(s) x {} worker(s) x {} slot(s), \
         prefill chunk {}, backend {}{}",
        weights.config.name,
        replicas,
        workers,
        cfg.batch.max_slots,
        cfg.batch.prefill_chunk,
        backend.name(),
        if store.is_some() { " (shared plan store)" } else { "" }
    );
    let engines: Vec<Arc<InferenceEngine>> = (0..replicas)
        .map(|_| {
            match &store {
                Some(s) => InferenceEngine::start_with_store(
                    Arc::clone(&weights),
                    cfg.clone(),
                    Arc::clone(s),
                ),
                None => InferenceEngine::start(Arc::clone(&weights), cfg.clone()),
            }
            .map(Arc::new)
        })
        .collect::<Result<_>>()?;
    // Request-lifecycle knobs: a deadline stamped on requests that
    // don't carry their own `deadline_ms` (0 = none — requests wait as
    // long as they take), and the heartbeat staleness beyond which the
    // router stops sending traffic to a replica (0 = no health
    // filtering; must exceed the model's worst-case step time).
    let default_deadline_ms = get_usize(f, "default-deadline-ms", 0)? as u64;
    let replica_stall_ms = get_usize(f, "replica-stall-ms", 0)? as u64;
    let mut router = Router::new(engines)?;
    if replica_stall_ms > 0 {
        router =
            router.with_replica_stall(std::time::Duration::from_millis(replica_stall_ms));
        println!("replica health: skip replicas stalled > {replica_stall_ms}ms");
    }
    let router = Arc::new(router);
    let mut server = Server::new(router).with_identity(ServerIdentity {
        model: weights.config.name.to_string(),
        plan_dir: plans.as_ref().map(|p| p.display().to_string()),
        tune_profile: profile.as_ref().map(|p| p.display().to_string()),
    });
    if default_deadline_ms > 0 {
        server = server
            .with_default_deadline(std::time::Duration::from_millis(default_deadline_ms));
        println!("default request deadline: {default_deadline_ms}ms");
    }
    if let Some(bytes) = kv_budget {
        println!(
            "kv budget: {} per replica ({} tokens/page) — requests beyond it are \
             shed or evicted youngest-first with a kv_budget_exceeded outcome",
            human_bytes(bytes as usize),
            kv_page_tokens
        );
    }
    if let Some(ms) = trace_slow_ms {
        println!("request tracing: pinning requests slower than {ms}ms (rsr trace)");
    }
    if profile_layers {
        println!("per-layer profiling: on (rsr metrics reports layer rows)");
    }
    let stop = Arc::new(AtomicBool::new(false));
    // SIGTERM begins a graceful drain (identical to the `drain` wire
    // command): queued and in-flight work completes, new submissions
    // are refused with code `draining`, and serve() returns once every
    // replica is idle.
    #[cfg(unix)]
    {
        let term = install_sigterm_flag();
        let drain = server.drain_handle();
        std::thread::spawn(move || loop {
            if term.load(Ordering::Relaxed) {
                drain.store(true, Ordering::Relaxed);
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        });
    }
    println!("serving on {addr} (Ctrl-C to stop; SIGTERM or `rsr drain` to drain)");
    server.serve(&addr, stop, |bound| println!("bound {bound}"))
}

/// Install a SIGTERM handler that only sets a flag (libc is not a
/// dependency; `signal(2)` is declared by hand). The handler is
/// async-signal-safe: one relaxed atomic store.
#[cfg(unix)]
fn install_sigterm_flag() -> &'static AtomicBool {
    const SIGTERM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    extern "C" fn on_sigterm(_signum: i32) {
        TERM.store(true, Ordering::Relaxed);
    }
    static TERM: AtomicBool = AtomicBool::new(false);
    // SAFETY: installing a handler that performs a single atomic store.
    unsafe {
        signal(SIGTERM, on_sigterm);
    }
    &TERM
}

fn cmd_client(f: &HashMap<String, String>) -> Result<()> {
    let addr: std::net::SocketAddr = f
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7878".into())
        .parse()
        .map_err(|e| Error::Config(format!("bad --addr: {e}")))?;
    let prompt = f
        .get("prompt")
        .ok_or_else(|| Error::Config("client requires --prompt TEXT".into()))?;
    let max_new = get_usize(f, "max-new", 16)?;
    // --deadline-ms rides the wire as `deadline_ms`; the server sheds
    // or retires the request with code `deadline_exceeded` once the
    // budget is spent (0 = no deadline).
    let deadline_ms = get_usize(f, "deadline-ms", 0)? as u64;
    let mut client = Client::connect(addr)?;
    let mut builder = client.prompt(1, prompt).max_new(max_new);
    if deadline_ms > 0 {
        builder = builder.deadline_ms(deadline_ms);
    }
    if f.contains_key("stream") {
        // Print each token frame as it lands, then the terminal line.
        let out = builder.stream_with(|frame| println!("{}", frame.to_string()))?;
        println!("{}", out.raw.to_string());
    } else {
        let reply = builder.send_json()?;
        println!("{}", reply.to_string());
    }
    Ok(())
}

/// Parse `--addr` (shared by the scrape commands).
fn control_addr(f: &HashMap<String, String>) -> Result<std::net::SocketAddr> {
    f.get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7878".into())
        .parse()
        .map_err(|e| Error::Config(format!("bad --addr: {e}")))
}

/// `rsr metrics`: scrape a live server's `metrics` wire command —
/// JSON by default, Prometheus text exposition with `--prom`,
/// repeating every `--watch SECS` seconds until interrupted.
fn cmd_metrics(f: &HashMap<String, String>) -> Result<()> {
    let addr = control_addr(f)?;
    let prom = f.contains_key("prom");
    let watch_s = get_usize(f, "watch", 0)?;
    let line = if prom {
        r#"{"cmd": "metrics", "format": "prom"}"#
    } else {
        r#"{"cmd": "metrics"}"#
    };
    let mut client = Client::connect(addr)?;
    loop {
        let reply = client.send_raw(line)?;
        if let Some(err) = reply.get("error").and_then(|e| e.as_str()) {
            return Err(Error::Serving(err.to_string()));
        }
        match reply.get("prom").and_then(|p| p.as_str()) {
            // The prom text rides the wire JSON-escaped; print it raw.
            Some(text) => print!("{text}"),
            None => println!("{}", reply.to_string()),
        }
        if watch_s == 0 {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs(watch_s as u64));
    }
}

/// `rsr status`: one-shot engine state — identity (model, plan dir,
/// tuned profile) plus per-replica gauges.
fn cmd_status(f: &HashMap<String, String>) -> Result<()> {
    let mut client = Client::connect(control_addr(f)?)?;
    let reply = client.send_raw(r#"{"cmd": "status"}"#)?;
    println!("{}", reply.to_string());
    Ok(())
}

/// `rsr trace`: dump the per-request trace ring (recent + slow-pinned
/// timelines; requires the server to run with `--trace-slow-ms`).
fn cmd_trace(f: &HashMap<String, String>) -> Result<()> {
    let mut client = Client::connect(control_addr(f)?)?;
    let reply = client.send_raw(r#"{"cmd": "trace"}"#)?;
    if reply.get("enabled") == Some(&Json::Bool(false)) {
        println!("tracing is off — start the server with --trace-slow-ms N");
    }
    println!("{}", reply.to_string());
    Ok(())
}

/// `rsr drain`: flip a live server into drain mode — it finishes
/// queued and in-flight work (streams included), refuses new requests
/// with code `draining`, and exits once every replica is idle.
fn cmd_drain(f: &HashMap<String, String>) -> Result<()> {
    let mut client = Client::connect(control_addr(f)?)?;
    let reply = client.control("drain")?;
    println!("{}", reply.to_string());
    Ok(())
}

/// `rsr bench-kernels`: time the kernel backends on a size grid and
/// record `BENCH_kernels.json` (the repo's machine-readable perf
/// trajectory — see ISSUE/README perf notes).
fn cmd_bench_kernels(f: &HashMap<String, String>) -> Result<()> {
    use rsr::bench::experiments::kernels::{run, KernelBenchOpts};
    let mut opts = KernelBenchOpts::default();
    // --sizes N,… (squares) and/or --shapes NxM,… (rectangles); naming
    // either replaces the default grid.
    let mut shapes = Vec::new();
    if let Some(sizes) = f.get("sizes") {
        for n in parse_usize_list(sizes, "sizes")? {
            shapes.push((n, n));
        }
    }
    if let Some(spec) = f.get("shapes") {
        for s in spec.split(',') {
            shapes.push(parse_shape(s.trim())?);
        }
    }
    if !shapes.is_empty() {
        opts.shapes = shapes;
    }
    if opts.shapes.iter().any(|&(n, m)| n == 0 || m == 0) {
        return Err(Error::Config("shapes must be positive".into()));
    }
    opts.reps = get_usize(f, "reps", opts.reps)?.max(1);
    opts.batch = get_usize(f, "batch", opts.batch)?.max(1);
    opts.threads = get_usize(f, "threads", 0)?;
    opts.budget =
        std::time::Duration::from_millis(get_usize(f, "budget-ms", 250)? as u64);
    opts.json_path = Some(PathBuf::from(
        f.get("json").cloned().unwrap_or_else(|| "BENCH_kernels.json".into()),
    ));
    run(&opts)?;
    Ok(())
}

/// `rsr bench-serve`: sweep continuous-batching batch sizes over a
/// synthetic model and record decode tokens/sec to `BENCH_serving.json`
/// (the serving-layer perf trajectory; see bench/experiments/serving).
fn cmd_bench_serve(f: &HashMap<String, String>) -> Result<()> {
    use rsr::bench::experiments::serving::{run, ServeBenchOpts};
    let mut opts = ServeBenchOpts::default();
    if let Some(spec) = f.get("batches") {
        opts.batches = parse_usize_list(spec, "batches")?;
    }
    opts.d_model = get_usize(f, "d-model", opts.d_model)?;
    opts.d_ff = get_usize(f, "d-ff", opts.d_ff)?;
    opts.n_layers = get_usize(f, "layers", opts.n_layers)?.max(1);
    opts.steps = get_usize(f, "steps", opts.steps)?.max(1);
    opts.prompt_len = get_usize(f, "prompt", opts.prompt_len)?.max(1);
    // --prompt-lens 16,128,512 drives the TTFT sweep (`none` skips it);
    // --prefill-chunk sets the measured chunk (compared to chunk 1).
    if let Some(spec) = f.get("prompt-lens") {
        opts.prompt_lens = if spec == "none" {
            Vec::new()
        } else {
            parse_usize_list(spec, "prompt-lens")?
        };
    }
    opts.prefill_chunk = get_usize(f, "prefill-chunk", opts.prefill_chunk)?.max(1);
    // Open-loop overload run (0 requests skips it): Poisson arrivals
    // against a bounded queue, recording shed/deadline-miss rates and
    // end-to-end p50/p99 into the same JSON record.
    opts.overload_requests = get_usize(f, "overload-requests", opts.overload_requests)?;
    opts.overload_rps = get_usize(f, "overload-rps", opts.overload_rps as usize)? as f64;
    opts.overload_deadline_ms =
        get_usize(f, "overload-deadline-ms", opts.overload_deadline_ms as usize)? as u64;
    opts.json_path = Some(PathBuf::from(
        f.get("json").cloned().unwrap_or_else(|| "BENCH_serving.json".into()),
    ));
    run(&opts)?;
    Ok(())
}

/// `rsr bench-prefill`: sweep the chunked-prefill chunk size over a
/// synthetic n=1024 stack and record TTFT + prefill tokens/sec to
/// `BENCH_prefill.json` (the prefill perf trajectory; see
/// bench/experiments/prefill).
fn cmd_bench_prefill(f: &HashMap<String, String>) -> Result<()> {
    use rsr::bench::experiments::prefill::{run, PrefillBenchOpts};
    let mut opts = PrefillBenchOpts::default();
    if let Some(spec) = f.get("chunks") {
        opts.chunks = parse_usize_list(spec, "chunks")?;
    }
    opts.d_model = get_usize(f, "d-model", opts.d_model)?;
    opts.d_ff = get_usize(f, "d-ff", opts.d_ff)?;
    opts.n_layers = get_usize(f, "layers", opts.n_layers)?.max(1);
    opts.prompt_len = get_usize(f, "prompt", opts.prompt_len)?.max(1);
    opts.trials = get_usize(f, "trials", opts.trials)?.max(1);
    opts.json_path = Some(PathBuf::from(
        f.get("json").cloned().unwrap_or_else(|| "BENCH_prefill.json".into()),
    ));
    run(&opts)?;
    Ok(())
}

/// Parse one positive comma-separated integer list flag.
fn parse_usize_list(spec: &str, flag: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for s in spec.split(',') {
        let v: usize = s
            .trim()
            .parse()
            .map_err(|_| Error::Config(format!("bad value {s} in --{flag}")))?;
        if v == 0 {
            return Err(Error::Config(format!("--{flag} values must be positive")));
        }
        out.push(v);
    }
    if out.is_empty() {
        return Err(Error::Config(format!("--{flag} needs at least one value")));
    }
    Ok(out)
}

/// Parse one `NxM` pair (e.g. `4096x11008`).
fn parse_shape(s: &str) -> Result<(usize, usize)> {
    let err = || Error::Config(format!("bad shape {s} in --shapes (expected NxM)"));
    let (n, m) = s.split_once(|c| c == 'x' || c == 'X').ok_or_else(err)?;
    Ok((
        n.trim().parse().map_err(|_| err())?,
        m.trim().parse().map_err(|_| err())?,
    ))
}

fn cmd_experiment(rest: &[String], f: &HashMap<String, String>) -> Result<()> {
    let full = f.contains_key("full") || rsr::bench::full_mode();
    let which = rest
        .iter()
        .find(|a| !a.starts_with("--"))
        .ok_or_else(|| Error::Config("experiment requires a figure id".into()))?;
    use rsr::bench::experiments as ex;
    match which.as_str() {
        "fig4" => ex::fig4::run(full),
        "fig5" => ex::fig5::run(full),
        "fig6" => ex::fig6::run(full),
        "fig9" => ex::fig9::run(full),
        "fig10" => ex::fig10::run(full),
        "fig11" => ex::fig11::run(full),
        "fig12" => ex::fig12::run(full),
        "table1" => ex::table1::run(full),
        "ablations" => ex::ablations::run(full),
        "perf" => ex::perf::run(full),
        "all" => {
            for r in [
                ex::fig4::run as fn(bool),
                ex::fig5::run,
                ex::fig6::run,
                ex::fig9::run,
                ex::fig10::run,
                ex::fig11::run,
                ex::fig12::run,
                ex::table1::run,
                ex::ablations::run,
            ] {
                r(full);
            }
        }
        other => return Err(Error::Config(format!("unknown experiment {other}"))),
    }
    Ok(())
}

/// Preprocess one ternary matrix (paper Algorithm 1), wrap it in a
/// `.rsrz` artifact, save it, and account for it in the report table.
fn pack_one(
    out_dir: &Path,
    name: &str,
    m: &TernaryMatrix,
    scale: f32,
    k_flag: usize,
    table: &mut Table,
    totals: &mut (usize, usize),
) -> Result<()> {
    let k = if k_flag == 0 { optimal_k_rsrpp(m.rows()) } else { k_flag };
    let t0 = std::time::Instant::now();
    let idx = TernaryRsrIndex::preprocess(m, k);
    let art = PlanArtifact::ternary(name, idx, scale)?
        .with_weights_fingerprint(ternary_fingerprint(m));
    art.save(out_dir.join(format!("{name}.rsrz")))?;
    let meta = &art.meta;
    table.row(&[
        name.to_string(),
        format!("{}x{}", meta.rows, meta.cols),
        k.to_string(),
        human_bytes(meta.payload_bytes),
        human_bytes(meta.dense_f32_bytes()),
        format!("{:.3}", meta.ratio_vs_dense()),
        format!("{:.1}ms", t0.elapsed().as_secs_f64() * 1e3),
    ]);
    totals.0 += meta.payload_bytes;
    totals.1 += meta.dense_f32_bytes();
    Ok(())
}

fn cmd_pack(f: &HashMap<String, String>) -> Result<()> {
    let out = f
        .get("out")
        .ok_or_else(|| Error::Config("pack requires --out DIR".into()))?;
    let out = PathBuf::from(out);
    std::fs::create_dir_all(&out)?;
    let k_flag = get_usize(f, "k", 0)?;
    // Fail before any preprocessing: k > 16 would panic in blocking and
    // could never be loaded back anyway.
    if k_flag > 16 {
        return Err(Error::Config(format!(
            "--k {k_flag} is out of range (1..=16, or 0 for the analytic optimum)"
        )));
    }
    // --profile packs each layer at its tuned k, so the artifacts can
    // be served together with that profile (`rsr serve --plans …
    // --profile …`). No host fingerprint check here: packing routinely
    // happens on a build box for a profile tuned on the serve box.
    let profile = match f.get("profile") {
        None => None,
        Some(p) => {
            let prof = TuneProfile::load(p)?;
            println!(
                "packing at the tuned blocking from {p} ({} layers, machine {})",
                prof.len(),
                prof.fingerprint.describe()
            );
            Some(prof)
        }
    };

    let mut table =
        Table::new(&["name", "shape", "k", "artifact", "dense f32", "ratio", "preprocess"]);
    let mut totals = (0usize, 0usize);
    if let Some(path) = f.get("model") {
        println!("loading {path}...");
        let weights = ModelWeights::load(path)?;
        for (name, m, scale) in weights.named_matrices() {
            // Tuned k per layer; layers absent from the profile keep
            // the --k / analytic default.
            let k_layer = profile
                .as_ref()
                .and_then(|p| p.get(&name))
                .map_or(k_flag, |l| l.winner().k);
            pack_one(&out, &name, m, scale, k_layer, &mut table, &mut totals)?;
        }
    } else {
        let n = get_usize(f, "n", 0)?;
        if n == 0 {
            return Err(Error::Config("pack requires --model FILE or --n N".into()));
        }
        let seed = get_usize(f, "seed", 42)? as u64;
        let mut rng = Rng::new(seed);
        let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
        pack_one(&out, &format!("synthetic_n{n}"), &a, 1.0, k_flag, &mut table, &mut totals)?;
    }
    table.print(&format!("packed plan artifacts → {}", out.display()));
    println!(
        "\ntotal: {} of .rsrz artifacts vs {} dense f32 (ratio {:.3}) — \
         preprocessing is now an offline, one-time cost",
        human_bytes(totals.0),
        human_bytes(totals.1),
        totals.0 as f64 / totals.1 as f64
    );
    Ok(())
}

fn cmd_inspect(f: &HashMap<String, String>) -> Result<()> {
    // --verify is --deep plus housekeeping: stray *.tmp leftovers of a
    // killed `rsr pack`/`rsr tune` are deleted (each one logged), and
    // any artifact or profile that fails its checksum walk makes the
    // command exit nonzero naming the offending file.
    let verify = f.contains_key("verify");
    let deep = f.contains_key("deep") || verify;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut profiles: Vec<PathBuf> = Vec::new();
    let is_rsrt = |p: &Path| p.extension().is_some_and(|e| e == "rsrt");
    if let Some(file) = f.get("file") {
        let p = PathBuf::from(file);
        if is_rsrt(&p) {
            profiles.push(p);
        } else {
            paths.push(p);
        }
    } else if let Some(file) = f.get("profile") {
        profiles.push(PathBuf::from(file));
    } else if let Some(dir) = f.get("plans") {
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            if verify && rsr::util::atomicfile::is_tmp(&p) && p.is_file() {
                std::fs::remove_file(&p)?;
                println!("deleted stray tmp file {} (interrupted write)", p.display());
                continue;
            }
            if p.extension().is_some_and(|e| e == "rsrz") {
                paths.push(p);
            } else if is_rsrt(&p) {
                profiles.push(p);
            }
        }
        paths.sort();
        profiles.sort();
        if paths.is_empty() && profiles.is_empty() {
            return Err(Error::Config(format!(
                "no .rsrz artifacts or .rsrt profiles in {dir}"
            )));
        }
    } else {
        return Err(Error::Config(
            "inspect requires --plans DIR, --file FILE or --profile FILE".into(),
        ));
    }

    if !paths.is_empty() {
        let mut table = Table::new(&[
            "name", "kind", "shape", "k", "scale", "index bytes", "dense f32", "packed", "ratio",
        ]);
        let mut totals = (0usize, 0usize);
        for p in &paths {
            // --deep decodes the payload, verifies the checksum and
            // re-validates every structural invariant; the default reads
            // only the header. The error names the offending file (and
            // exits nonzero through main) — the --verify contract.
            let meta = if deep {
                PlanArtifact::load(p)
                    .map_err(|e| Error::Artifact(format!("{}: {e}", p.display())))?
                    .meta
            } else {
                PlanArtifact::peek(p)?
            };
            table.row(&[
                meta.name.clone(),
                meta.kind.name().to_string(),
                format!("{}x{}", meta.rows, meta.cols),
                meta.k.to_string(),
                format!("{:.4}", meta.scale),
                human_bytes(meta.payload_bytes),
                human_bytes(meta.dense_f32_bytes()),
                human_bytes(meta.packed_bytes()),
                format!("{:.3}", meta.ratio_vs_dense()),
            ]);
            totals.0 += meta.payload_bytes;
            totals.1 += meta.dense_f32_bytes();
        }
        table.print(if deep {
            "plan artifacts (deep: payload decoded, checksum + invariants verified)"
        } else {
            "plan artifacts"
        });
        println!(
            "\ntotal index {} vs dense f32 {} — ratio {:.3}",
            human_bytes(totals.0),
            human_bytes(totals.1),
            totals.0 as f64 / totals.1 as f64
        );
    }
    for p in &profiles {
        inspect_profile(p)
            .map_err(|e| Error::Artifact(format!("{}: {e}", p.display())))?;
    }
    if verify {
        println!(
            "\nverify OK: {} artifact(s) and {} profile(s) passed the deep \
             checksum walk",
            paths.len(),
            profiles.len()
        );
    }
    Ok(())
}

/// Print one `.rsrt` tuning profile: fingerprint (flagged when it is
/// not this host's), per-layer winner and the head of the fallback
/// chain. Loading alone verifies the checksum and every structural
/// invariant.
fn inspect_profile(path: &Path) -> Result<()> {
    let p = TuneProfile::load(path)?;
    let foreign = p.verify_host().is_err();
    let mut table =
        Table::new(&["layer", "shape", "winner", "k", "median", "fallback chain"]);
    for l in &p.layers {
        let w = l.winner();
        let fallbacks: Vec<String> = l
            .chain
            .iter()
            .skip(1)
            .take(3)
            .map(|c| format!("{} k={}", c.backend.name(), c.k))
            .collect();
        table.row(&[
            l.name.clone(),
            format!("{}x{}", l.rows, l.cols),
            w.backend.name().to_string(),
            w.k.to_string(),
            human_ns(w.ns),
            if fallbacks.is_empty() { "-".into() } else { fallbacks.join(", ") },
        ]);
    }
    table.print(&format!(
        "tuning profile {} — {} layers, machine {}, batched measured at batch {}{}",
        path.display(),
        p.len(),
        p.fingerprint.describe(),
        p.bench_batch,
        if foreign { " (NOT this host: serving would reject it)" } else { "" }
    ));
    Ok(())
}

fn cmd_tune(f: &HashMap<String, String>) -> Result<()> {
    let weights_path = f
        .get("weights")
        .or_else(|| f.get("model"))
        .ok_or_else(|| Error::Config("tune requires --weights FILE (a .rtw model)".into()))?;
    let out = f
        .get("out")
        .ok_or_else(|| Error::Config("tune requires --out FILE (the .rsrt profile)".into()))?;
    let budget_ms = get_usize(f, "budget-ms", 250)?.max(1);
    let radius = get_usize(f, "radius", 2)?;
    let trials = get_usize(f, "trials", 5)?.max(1);

    println!("loading {weights_path}...");
    let weights = ModelWeights::load(weights_path)?;
    let opts = TuneOpts {
        radius,
        budget_per_layer: std::time::Duration::from_millis(budget_ms as u64),
        trials,
    };
    println!(
        "tuning {} layers ({budget_ms}ms/layer, k-radius {radius}, {trials} trials)...",
        weights.matrix_names().len()
    );
    let t0 = std::time::Instant::now();
    let (profile, reports) = tune_model(&weights, &opts, |r| {
        let w = r.winner();
        println!(
            "  {:<14} {:>5}x{:<5} -> {} k={} ({})",
            r.name,
            r.rows,
            r.cols,
            w.candidate.backend.name(),
            w.candidate.k,
            human_ns(w.result.median_ns)
        );
    })?;

    let mut table =
        Table::new(&["layer", "shape", "winner", "k", "median", "runner-up", "margin"]);
    for r in &reports {
        let w = r.winner();
        let ru = r.timings.get(1);
        table.row(&[
            r.name.clone(),
            format!("{}x{}", r.rows, r.cols),
            w.candidate.backend.name().to_string(),
            w.candidate.k.to_string(),
            human_ns(w.result.median_ns),
            ru.map_or_else(
                || "-".into(),
                |t| format!("{} k={}", t.candidate.backend.name(), t.candidate.k),
            ),
            ru.map_or_else(
                || "-".into(),
                |t| {
                    format!(
                        "{:+.1}%",
                        (t.result.median_ns / w.result.median_ns.max(1e-9) - 1.0) * 100.0
                    )
                },
            ),
        ]);
    }
    table.print("tune: per-layer winners");
    profile.save(out)?;
    println!(
        "\nwrote {out} — {} layers, machine {}, tuned in {:.1}s",
        profile.len(),
        profile.fingerprint.describe(),
        t0.elapsed().as_secs_f64()
    );
    println!("serve it with: rsr serve --model {weights_path} --profile {out}");
    Ok(())
}


fn human_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.2} MB", b as f64 / 1048576.0)
    } else if b >= 1024 {
        format!("{:.1} KB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

fn cmd_selfcheck() -> Result<()> {
    println!("cross-backend equality on random ternary 512x512...");
    let mut rng = Rng::new(1);
    let a = TernaryMatrix::random(512, 512, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(512, -1.0, 1.0);
    let expect = rsr::kernels::standard::standard_mul_ternary(&v, &a);
    for backend in Backend::ALL {
        let mut layer = rsr::model::bitlinear::BitLinear::new(a.clone(), 1.0, backend, 0)?;
        let mut out = vec![0.0f32; 512];
        layer.forward(&v, &mut out)?;
        let max_err = out
            .iter()
            .zip(expect.iter())
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f32, f32::max);
        println!("  {:<16} max |err| = {max_err:.2e}", backend.name());
        if max_err > 1e-2 {
            return Err(Error::Config(format!("{} disagrees", backend.name())));
        }
    }
    // The TL lookup path (runtime-dispatched column loop).
    let tl = rsr::kernels::TlPlan::from_weights(512, 512, rsr::kernels::TL_GROUP, a.data())?;
    let mut lut = tl.scratch();
    let mut out = vec![0.0f32; 512];
    tl.execute(&v, &mut out, &mut lut)?;
    let max_err = out
        .iter()
        .zip(expect.iter())
        .map(|(g, e)| (g - e).abs())
        .fold(0.0f32, f32::max);
    println!("  {:<16} max |err| = {max_err:.2e}", "tl");
    if max_err > 1e-2 {
        return Err(Error::Config("tl disagrees".into()));
    }
    // Index round-trip.
    let idx = TernaryRsrIndex::preprocess(&a, optimal_k_rsr(512));
    idx.validate()?;
    println!("  index validation OK ({} bytes)", idx.bytes());
    println!("selfcheck OK");
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = rsr::runtime::Engine::load(rsr::runtime::Engine::default_dir())?;
    println!("artifacts in {:?}:", rsr::runtime::Engine::default_dir());
    for name in engine.names() {
        let spec = engine.spec(name).unwrap();
        let ins: Vec<String> = spec.inputs.iter().map(|s| format!("{:?}", s.shape)).collect();
        println!("  {name:<28} inputs {}", ins.join(" "));
    }
    Ok(())
}
