//! Request arrival traces for serving experiments: Poisson arrivals
//! with configurable rate, plus a bursty variant — the workloads the
//! batcher/scheduler ablations replay.

use std::time::Duration;

use crate::util::rng::Rng;

/// One trace entry: when a request arrives and its shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Offset from trace start.
    pub at: Duration,
    /// Prompt length (tokens).
    pub prompt_len: usize,
    /// Tokens to generate.
    pub max_new: usize,
}

/// Arrival process shape.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Poisson with mean `rate_per_sec`.
    Poisson {
        /// Mean arrival rate (req/s).
        rate_per_sec: f64,
    },
    /// Bursts of `burst` back-to-back requests every `period`.
    Bursty {
        /// Requests per burst.
        burst: usize,
        /// Gap between bursts.
        period: Duration,
    },
}

/// Generate a deterministic trace of `count` events.
pub fn generate(
    arrival: Arrival,
    count: usize,
    prompt_range: (usize, usize),
    max_new: usize,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed);
    let mut events = Vec::with_capacity(count);
    let mut t = Duration::ZERO;
    let mut in_burst = 0usize;
    for _ in 0..count {
        match arrival {
            Arrival::Poisson { rate_per_sec } => {
                // Exponential inter-arrival via inverse CDF.
                let u = rng.next_f64().max(1e-12);
                let gap = -u.ln() / rate_per_sec.max(1e-9);
                t += Duration::from_secs_f64(gap);
            }
            Arrival::Bursty { burst, period } => {
                if in_burst >= burst {
                    t += period;
                    in_burst = 0;
                }
                in_burst += 1;
            }
        }
        events.push(TraceEvent {
            at: t,
            prompt_len: rng.range(prompt_range.0, prompt_range.1.max(prompt_range.0 + 1)),
            max_new,
        });
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let events = generate(
            Arrival::Poisson { rate_per_sec: 100.0 },
            2000,
            (5, 20),
            8,
            3,
        );
        assert_eq!(events.len(), 2000);
        let total = events.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((60.0..150.0).contains(&rate), "observed rate {rate}");
        // Monotone timestamps.
        for w in events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn bursty_produces_gaps() {
        let events = generate(
            Arrival::Bursty { burst: 4, period: Duration::from_millis(100) },
            12,
            (5, 6),
            4,
            7,
        );
        // Events 0..4 share t=0; then a 100ms jump.
        assert_eq!(events[0].at, events[3].at);
        assert!(events[4].at >= Duration::from_millis(100));
    }

    #[test]
    fn prompt_lengths_in_range() {
        let events =
            generate(Arrival::Poisson { rate_per_sec: 10.0 }, 100, (3, 9), 4, 11);
        assert!(events.iter().all(|e| (3..9).contains(&e.prompt_len)));
    }
}
