//! Synthetic datasets and request traces (DESIGN.md §Substitutions for
//! ShortQuestions / SimpleQuestions / TREC QA).

pub mod datasets;
pub mod trace;

pub use datasets::{Dataset, DatasetKind};
