//! Synthetic question datasets matching the paper's §5.3 evaluation.
//!
//! The paper uses three datasets whose *content* is irrelevant to
//! kernel timing — only the prompt-length distribution matters (each
//! input runs one feed-forward pass per prompt token). We generate:
//!
//! * **ShortQuestions** — short factual questions (the paper built the
//!   original with GPT-4; e.g. "What is the capital of France?"),
//! * **SimpleQuestions-like** — entity-centric single-fact questions
//!   mirroring Diefenbach et al. 2017's templates,
//! * **TREC-like** — questions following the TREC QA taxonomy
//!   (abbreviation / entity / description / human / location / number).

use crate::util::rng::Rng;

/// Which synthetic dataset to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// Short factual questions.
    ShortQuestions,
    /// Entity-fact questions (SimpleQuestions-like).
    SimpleQuestions,
    /// TREC-taxonomy questions.
    TrecQa,
}

impl DatasetKind {
    /// All kinds, in the paper's Fig 6 order.
    pub const ALL: [DatasetKind; 3] =
        [DatasetKind::ShortQuestions, DatasetKind::SimpleQuestions, DatasetKind::TrecQa];

    /// Display name used in bench reports.
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::ShortQuestions => "ShortQuestions",
            DatasetKind::SimpleQuestions => "SimpleQuestions",
            DatasetKind::TrecQa => "TREC QA",
        }
    }

    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "shortquestions" | "short" => Some(DatasetKind::ShortQuestions),
            "simplequestions" | "simple" => Some(DatasetKind::SimpleQuestions),
            "trec" | "trecqa" | "trec-qa" => Some(DatasetKind::TrecQa),
            _ => None,
        }
    }
}

/// A generated dataset: text prompts.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Which generator produced it.
    pub kind: DatasetKind,
    /// The prompts.
    pub prompts: Vec<String>,
}

const CAPITALS: &[(&str, &str)] = &[
    ("France", "Paris"),
    ("Japan", "Tokyo"),
    ("Italy", "Rome"),
    ("Canada", "Ottawa"),
    ("Egypt", "Cairo"),
    ("Brazil", "Brasilia"),
    ("Kenya", "Nairobi"),
    ("Norway", "Oslo"),
];

const ENTITIES: &[&str] = &[
    "the Nile", "Mount Everest", "the Pacific Ocean", "the Amazon rainforest",
    "the Great Wall", "the Sahara", "Lake Baikal", "the Danube",
];

const PEOPLE: &[&str] = &[
    "Marie Curie", "Alan Turing", "Ada Lovelace", "Isaac Newton",
    "Katherine Johnson", "Leonhard Euler",
];

const SHORT_TEMPLATES: &[&str] = &[
    "What is the capital of {X}?",
    "How many continents are there?",
    "What year did World War II end?",
    "Who wrote Romeo and Juliet?",
    "What is the chemical symbol for gold?",
    "How many planets are in the solar system?",
    "What is the largest mammal?",
    "What language is spoken in {X}?",
];

const SIMPLE_TEMPLATES: &[&str] = &[
    "Where is {E} located?",
    "What type of place is {E}?",
    "Which country contains {E}?",
    "Who discovered {E}?",
    "What is {E} known for?",
];

const TREC_TEMPLATES: &[&str] = &[
    // ABBR / ENTY / DESC / HUM / LOC / NUM classes.
    "What does the abbreviation NASA stand for?",
    "What breed of dog is the smallest?",
    "Why is the sky blue?",
    "Who was {P}?",
    "Where is {E}?",
    "How many meters tall is {E}?",
    "When was {P} born?",
    "What is the speed of light?",
];

impl Dataset {
    /// Generate `count` prompts deterministically from a seed.
    pub fn generate(kind: DatasetKind, count: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x9E3779B9));
        let prompts = (0..count)
            .map(|_| match kind {
                DatasetKind::ShortQuestions => fill(&mut rng, SHORT_TEMPLATES),
                DatasetKind::SimpleQuestions => fill(&mut rng, SIMPLE_TEMPLATES),
                DatasetKind::TrecQa => fill(&mut rng, TREC_TEMPLATES),
            })
            .collect();
        Self { kind, prompts }
    }

    /// Mean prompt length in bytes (≈ tokens under the byte tokenizer).
    pub fn mean_len(&self) -> f64 {
        if self.prompts.is_empty() {
            return 0.0;
        }
        self.prompts.iter().map(|p| p.len()).sum::<usize>() as f64
            / self.prompts.len() as f64
    }
}

fn fill(rng: &mut Rng, templates: &[&str]) -> String {
    let t = *rng.choose(templates);
    t.replace("{X}", CAPITALS[rng.range(0, CAPITALS.len())].0)
        .replace("{E}", *rng.choose(ENTITIES))
        .replace("{P}", *rng.choose(PEOPLE))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_and_kind_sensitive() {
        let a = Dataset::generate(DatasetKind::ShortQuestions, 20, 1);
        let b = Dataset::generate(DatasetKind::ShortQuestions, 20, 1);
        let c = Dataset::generate(DatasetKind::TrecQa, 20, 1);
        assert_eq!(a.prompts, b.prompts);
        assert_ne!(a.prompts, c.prompts);
    }

    #[test]
    fn prompts_are_questions_and_short() {
        for kind in DatasetKind::ALL {
            let d = Dataset::generate(kind, 50, 2);
            assert_eq!(d.prompts.len(), 50);
            for p in &d.prompts {
                assert!(p.ends_with('?'), "{kind:?}: {p}");
                assert!(p.len() < 120, "{kind:?}: too long: {p}");
                assert!(!p.contains('{'), "unfilled template: {p}");
            }
            // "Short factual questions": mean well under 100 bytes.
            assert!(d.mean_len() < 80.0, "{kind:?} mean {:.1}", d.mean_len());
        }
    }

    #[test]
    fn names_round_trip() {
        for kind in DatasetKind::ALL {
            let lowered = kind.name().to_ascii_lowercase().replace(' ', "");
            assert_eq!(DatasetKind::from_name(&lowered), Some(kind), "{lowered}");
        }
        assert_eq!(DatasetKind::from_name("bogus"), None);
    }
}
