//! # rsr — efficient inference for binary & ternary neural networks
//!
//! A production-oriented reproduction of *"An Efficient Matrix
//! Multiplication Algorithm for Accelerating Inference in Binary and
//! Ternary Neural Networks"* (Dehghankar, Erfanian & Asudeh, ICML
//! 2025): the **RSR** and **RSR++** algorithms, which preprocess fixed
//! binary/ternary weight matrices into *block indices* (per-block row
//! permutations + full segmentation lists) and then multiply an
//! activation vector by the matrix in `O(n²/log n)` time and
//! `O(n²/log n)` index space.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * [`kernels`] — the paper's algorithms, every multiply backend, and
//!   the versioned `.rsrz` plan-artifact format
//!   ([`kernels::artifact`]),
//! * [`model`] — a 1.58-bit (ternary) transformer substrate whose
//!   `BitLinear` layers dispatch to any backend or execute shared
//!   store-compiled plans,
//! * [`runtime`] — the [`runtime::PlanStore`] (compile-once/serve-many
//!   plan registry shared by every worker and replica) and the PJRT
//!   engine that executes AOT-compiled XLA artifacts (HLO text produced
//!   by the python/JAX/Pallas build step; `pjrt` feature),
//! * [`tune`] — the empirical autotuner: per-layer `(k, backend)`
//!   microbenchmarks compiled into versioned `.rsrt` profiles that the
//!   plan store executes ([`tune::TuneProfile`],
//!   [`runtime::ExecutablePlan`]),
//! * [`serving`] — request router, dynamic batcher and prefill/decode
//!   scheduler serving the model over TCP,
//! * [`bench`] — the harness regenerating every table and figure of the
//!   paper's evaluation section,
//! * [`data`] — synthetic datasets and request traces,
//! * [`util`] — PRNG/stats/threadpool/json substrates (offline
//!   environment: no rand/rayon/serde/criterion).
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```
//! use rsr::kernels::TernaryMatrix;
//! use rsr::kernels::index::TernaryRsrIndex;
//! use rsr::kernels::rsr::TernaryRsrPlan;
//! use rsr::util::rng::Rng;
//!
//! let mut rng = Rng::new(0);
//! let a = TernaryMatrix::random(256, 256, 1.0 / 3.0, &mut rng);
//! let v = rng.f32_vec(256, -1.0, 1.0);
//!
//! // Preprocess once (paper Algorithm 1) …
//! let index = TernaryRsrIndex::preprocess(&a, 6);
//! let mut plan = TernaryRsrPlan::new(index).unwrap();
//!
//! // … multiply many times (paper Algorithm 2).
//! let mut out = vec![0.0; 256];
//! plan.execute(&v, &mut out).unwrap();
//! ```

// Style-class clippy lints the kernel code intentionally trades away:
// index-centric loops mirror the paper's pseudocode, bench/kernel
// signatures carry many scalar parameters, and the offline substrates
// (json, stats) predate the trait conventions clippy nudges toward.
// CI compiles with `clippy -D warnings`; anything outside this list is
// a hard error there.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::inherent_to_string,
    clippy::new_without_default,
    clippy::large_enum_variant,
    clippy::type_complexity,
    clippy::manual_memcpy,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain,
    clippy::missing_safety_doc
)]

pub mod bench;
pub mod data;
pub mod error;
pub mod kernels;
pub mod model;
pub mod runtime;
pub mod serving;
pub mod tune;
pub mod util;

pub use error::{Error, Result};
