//! Global KV page pool: a hard byte budget over fixed-size KV pages.
//!
//! The serving side of the paper's compile-once/serve-many design
//! holds one preprocessed plan index per layer and N per-slot KV
//! caches. Before this pool existed, every slot eagerly materialized
//! `max_seq_len × kv_dim` K and V rows per layer, so memory grew with
//! `max_slots × max_seq_len` regardless of how long sequences actually
//! ran — raising `--max-slots` risked an unceremonious OOM kill. The
//! pool turns that into a governed resource: [`KvCache`] allocates
//! fixed-size pages (`--kv-page-tokens` positions each) on demand and
//! returns them on retirement, and the pool enforces a process-wide
//! byte ceiling (`--kv-budget`) so exhaustion is a *named, graceful*
//! outcome (`Error::KvBudgetExceeded`) the engine can shed or evict
//! on, never an OOM abort.
//!
//! # Accounting pool, cache-local storage
//!
//! The pool tracks **page grants**, not page storage: each `KvCache`
//! owns the `f32` buffers of the pages it holds (allocated at grant
//! time, freed at release), so the attention read path stays
//! lock-free and touches no shared mutable memory across worker
//! threads. Budget enforcement is a single atomic compare-exchange per
//! page grant — one CAS per `--kv-page-tokens` appended positions, off
//! the per-token hot path. Physical page sharing (prefix caching) can
//! later slot in behind the same grant/release API.
//!
//! [`KvCache`]: crate::model::kv_cache::KvCache

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::error::{Error, Result};

/// A page-grant pool under an optional hard page ceiling, shared by
/// every [`KvCache`](crate::model::kv_cache::KvCache) of an engine
/// (all layers, all slots, all workers — the budget is global).
#[derive(Debug)]
pub struct KvPool {
    /// Positions per page (`--kv-page-tokens`).
    page_tokens: usize,
    /// Hard ceiling in pages; `usize::MAX` when unbudgeted.
    total_pages: usize,
    /// Pages currently granted.
    in_use: AtomicUsize,
    /// High-water mark of `in_use` (bench reporting).
    peak_in_use: AtomicUsize,
    /// Admission reservations refused for lack of pages.
    reservations_failed: AtomicU64,
    /// Mid-decode slot evictions forced by page exhaustion.
    evictions: AtomicU64,
}

/// Bytes one page occupies: K and V rows, `page_tokens` positions of
/// `kv_dim` f32 lanes each.
pub fn page_bytes(page_tokens: usize, kv_dim: usize) -> usize {
    2 * page_tokens * kv_dim * 4
}

impl KvPool {
    /// Default positions per page (`--kv-page-tokens`).
    pub const DEFAULT_PAGE_TOKENS: usize = 64;

    /// A budgeted pool: `budget_bytes` is the hard ceiling over all K
    /// and V storage granted through this pool; `kv_dim` sizes a page.
    /// The budget must cover at least one page.
    pub fn bounded(page_tokens: usize, kv_dim: usize, budget_bytes: u64) -> Result<Self> {
        if page_tokens == 0 || kv_dim == 0 {
            return Err(Error::Config("kv pool: zero page_tokens or kv_dim".into()));
        }
        let pb = page_bytes(page_tokens, kv_dim) as u64;
        let total = (budget_bytes / pb) as usize;
        if total == 0 {
            return Err(Error::Config(format!(
                "kv budget {budget_bytes} B is below one {pb} B page \
                 ({page_tokens} tokens × {kv_dim} kv lanes) — raise --kv-budget \
                 or lower --kv-page-tokens"
            )));
        }
        Ok(Self {
            page_tokens,
            total_pages: total,
            in_use: AtomicUsize::new(0),
            peak_in_use: AtomicUsize::new(0),
            reservations_failed: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        })
    }

    /// An unbudgeted pool: grants always succeed (the `--kv-budget`
    /// unset path — paging still fixes the eager over-allocation, but
    /// no reservation can fail and no eviction ever fires).
    pub fn unbounded(page_tokens: usize) -> Self {
        Self {
            page_tokens: page_tokens.max(1),
            total_pages: usize::MAX,
            in_use: AtomicUsize::new(0),
            peak_in_use: AtomicUsize::new(0),
            reservations_failed: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Positions per page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// True when a `--kv-budget` ceiling is being enforced.
    pub fn is_bounded(&self) -> bool {
        self.total_pages != usize::MAX
    }

    /// The page ceiling (`usize::MAX` when unbudgeted).
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Pages needed to hold `positions` cached positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_tokens)
    }

    /// Try to take one page grant. Lock-free CAS loop: concurrent
    /// grants race but never overshoot the ceiling.
    pub fn try_acquire(&self) -> bool {
        let mut cur = self.in_use.load(Ordering::Relaxed);
        loop {
            if cur >= self.total_pages {
                return false;
            }
            match self.in_use.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak_in_use.fetch_max(cur + 1, Ordering::Relaxed);
                    return true;
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Return `n` page grants.
    pub fn release(&self, n: usize) {
        if n > 0 {
            let prev = self.in_use.fetch_sub(n, Ordering::Relaxed);
            debug_assert!(prev >= n, "kv pool released more pages than granted");
        }
    }

    /// Pages still grantable right now (advisory — concurrent grants
    /// may take them first; `usize::MAX`-ceiling pools report a huge
    /// headroom).
    pub fn available(&self) -> usize {
        self.total_pages.saturating_sub(self.in_use.load(Ordering::Relaxed))
    }

    /// Admission check: could `n` pages be granted right now? The
    /// unbudgeted pool always says yes (reservation is a no-op).
    pub fn can_reserve(&self, n: usize) -> bool {
        !self.is_bounded() || n <= self.available()
    }

    /// Pages currently granted.
    pub fn pages_in_use(&self) -> usize {
        self.in_use.load(Ordering::Relaxed)
    }

    /// High-water mark of granted pages since startup.
    pub fn peak_pages_in_use(&self) -> usize {
        self.peak_in_use.load(Ordering::Relaxed)
    }

    /// Count one refused admission reservation.
    pub fn record_reservation_failed(&self) {
        self.reservations_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Admission reservations refused since startup.
    pub fn reservations_failed(&self) -> u64 {
        self.reservations_failed.load(Ordering::Relaxed)
    }

    /// Count one mid-decode eviction.
    pub fn record_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Mid-decode evictions since startup.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bounded_pool_enforces_the_page_ceiling() {
        // 3 pages of 4 tokens × 2 lanes: page = 2·4·2·4 = 64 B.
        let pool = KvPool::bounded(4, 2, 3 * 64).unwrap();
        assert!(pool.is_bounded());
        assert_eq!(pool.total_pages(), 3);
        assert!(pool.try_acquire());
        assert!(pool.try_acquire());
        assert!(pool.try_acquire());
        assert!(!pool.try_acquire(), "fourth grant must fail");
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.available(), 0);
        pool.release(2);
        assert_eq!(pool.pages_in_use(), 1);
        assert!(pool.try_acquire());
        assert_eq!(pool.peak_pages_in_use(), 3, "peak survives releases");
    }

    #[test]
    fn budget_below_one_page_is_a_config_error() {
        let err = KvPool::bounded(64, 128, 10).unwrap_err();
        assert!(err.to_string().contains("kv budget"), "{err}");
        assert!(KvPool::bounded(0, 2, 1024).is_err());
    }

    #[test]
    fn budget_rounds_down_to_whole_pages() {
        // Page = 64 B; a 100 B budget holds exactly one page.
        let pool = KvPool::bounded(4, 2, 100).unwrap();
        assert_eq!(pool.total_pages(), 1);
    }

    #[test]
    fn unbounded_pool_always_reserves_and_grants() {
        let pool = KvPool::unbounded(64);
        assert!(!pool.is_bounded());
        assert!(pool.can_reserve(1_000_000));
        for _ in 0..1000 {
            assert!(pool.try_acquire());
        }
        assert_eq!(pool.pages_in_use(), 1000);
        pool.release(1000);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn can_reserve_tracks_availability() {
        let pool = KvPool::bounded(4, 2, 2 * 64).unwrap();
        assert!(pool.can_reserve(2));
        assert!(!pool.can_reserve(3));
        assert!(pool.try_acquire());
        assert!(pool.can_reserve(1));
        assert!(!pool.can_reserve(2));
    }

    #[test]
    fn pages_for_rounds_up() {
        let pool = KvPool::unbounded(64);
        assert_eq!(pool.pages_for(0), 0);
        assert_eq!(pool.pages_for(1), 1);
        assert_eq!(pool.pages_for(64), 1);
        assert_eq!(pool.pages_for(65), 2);
    }

    #[test]
    fn counters_accumulate() {
        let pool = KvPool::bounded(4, 2, 64).unwrap();
        pool.record_reservation_failed();
        pool.record_reservation_failed();
        pool.record_eviction();
        assert_eq!(pool.reservations_failed(), 2);
        assert_eq!(pool.evictions(), 1);
    }

    #[test]
    fn concurrent_grants_never_overshoot() {
        let pool = Arc::new(KvPool::bounded(4, 2, 50 * 64).unwrap());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let mut got = 0usize;
                for _ in 0..100 {
                    if p.try_acquire() {
                        got += 1;
                    }
                }
                got
            }));
        }
        let granted: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(granted, 50, "exactly the ceiling is granted");
        assert_eq!(pool.pages_in_use(), 50);
        assert!(pool.peak_pages_in_use() <= 50);
    }
}
