//! Layer-2 runtime: compiled-plan registries shared by every inference
//! path.
//!
//! Two registries live here:
//!
//! * [`plan_store`] — the [`PlanStore`](plan_store::PlanStore): a
//!   thread-safe, lazily-populated cache of **RSR plans** (preprocessed
//!   block indices, paper Algorithm 1) keyed by layer name. Plans are
//!   compiled once — from weights in memory or from versioned `.rsrz`
//!   artifacts on disk — and shared across every serving worker and
//!   replica; callers hold per-thread execution scratch. This is the
//!   crate's compile-once/serve-many backbone.
//! * [`kv_pool`] — the [`KvPool`](kv_pool::KvPool): the serving-side
//!   memory governor — a global grant pool of fixed-size KV pages
//!   under a hard byte budget (`rsr serve --kv-budget`), shared by
//!   every per-slot `KvCache` so exhaustion degrades gracefully
//!   (`Error::KvBudgetExceeded`) instead of OOM-killing the process.
//! * [`executable`] — the [`ExecutablePlan`]: one execution object
//!   over a store-shared plan, dispatching to whichever backend an
//!   `rsr tune` profile selected for that layer (RSR, RSR++
//!   scalar/SIMD, block-parallel, batched).
//! * [`Engine`] — the PJRT engine: loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` (`make artifacts`)
//!   and executes them on the XLA CPU client. The dense-matvec
//!   artifacts serve as the *optimized-library baseline* (the
//!   NumPy/cuBLAS analog) in Fig 11; the `rsr_matvec_*` artifact is the
//!   Layer-1 Pallas kernel lowered through Layer-2, executed from rust
//!   with rust-computed block keys.
//!
//! PJRT needs the external `xla` crate, which the offline environment
//! cannot fetch; every call into it is gated behind the `pjrt` cargo
//! feature. Without the feature [`Engine`] still parses manifests (so
//! `rsr artifacts` works) but refuses to compile or execute, and
//! [`pjrt_enabled`] reports `false` so tests and benches skip cleanly.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::util::json::Json;

pub mod executable;
pub mod kv_pool;
pub mod plan_store;

pub use executable::ExecutablePlan;
pub use kv_pool::KvPool;
pub use plan_store::{PlanEntry, PlanScratch, PlanStore, SharedRsrPlan, SharedTernaryPlan};

/// Whether this build can execute AOT artifacts through PJRT.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// Element type of an artifact tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed int.
    I32,
}

impl DType {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(Error::Artifact(format!("unknown dtype {other}"))),
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Dimensions (row-major).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: DType,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Stable name (e.g. `dense_matvec_n4096`).
    pub name: String,
    /// File name of the HLO text within the artifact dir.
    pub path: String,
    /// Input tensors in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensors.
    pub outputs: Vec<TensorSpec>,
}

/// A host tensor to feed an artifact.
#[derive(Debug, Clone)]
pub enum Tensor {
    /// f32 data + shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data + shape.
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Tensor::I32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        match self {
            Tensor::F32(data, shape) => {
                spec.dtype == DType::F32 && shape == &spec.shape && data.len() == spec.elements()
            }
            Tensor::I32(data, shape) => {
                spec.dtype == DType::I32 && shape == &spec.shape && data.len() == spec.elements()
            }
        }
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    spec: ArtifactSpec,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// The artifact's manifest entry.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Validate input arity + shapes against the manifest.
    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Artifact(format!(
                "{}: {} inputs given, {} expected",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            if !t.matches(s) {
                return Err(Error::Artifact(format!(
                    "{}: input {i} shape/dtype mismatch (expected {:?})",
                    self.spec.name, s
                )));
            }
        }
        Ok(())
    }

    /// Execute with host tensors, returning the (single-output) result
    /// as f32. Validates shapes against the manifest.
    #[cfg(feature = "pjrt")]
    pub fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<f32>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute with host tensors — unavailable in this build: requires
    /// the `pjrt` feature (see the module docs).
    #[cfg(not(feature = "pjrt"))]
    pub fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<f32>> {
        self.check_inputs(inputs)?;
        Err(Error::Artifact(format!(
            "{}: executing AOT artifacts requires the `pjrt` feature",
            self.spec.name
        )))
    }
}

/// The PJRT engine: one CPU client + the artifact registry.
///
/// Compilation is lazy and cached: the first `executable(name)` call
/// compiles the HLO, later calls reuse it.
///
/// `PjRtClient` is `Rc`-based and therefore **not `Send`**: an `Engine`
/// lives on one thread. Components that need PJRT from a threaded
/// context (benches) construct one engine per worker thread via
/// [`thread_engine`].
pub struct Engine {
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    compiled: RefCell<HashMap<String, Rc<Executable>>>,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

impl Engine {
    /// Load the manifest from an artifact directory (and, with the
    /// `pjrt` feature, create the CPU client). Fails if the directory
    /// or manifest is missing.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                manifest_path.display()
            ))
        })?;
        let json = Json::parse(&text).map_err(Error::Artifact)?;
        let mut specs = HashMap::new();
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| Error::Artifact("manifest missing artifacts[]".into()))?;
        for a in arts {
            let spec = parse_artifact(a)?;
            specs.insert(spec.name.clone(), spec);
        }
        #[cfg(feature = "pjrt")]
        return Ok(Self {
            dir,
            specs,
            compiled: RefCell::new(HashMap::new()),
            client: xla::PjRtClient::cpu()?,
        });
        #[cfg(not(feature = "pjrt"))]
        return Ok(Self { dir, specs, compiled: RefCell::new(HashMap::new()) });
    }

    /// The default artifact directory: `$RSR_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("RSR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// The artifact directory this engine was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Artifact names available in the manifest.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Manifest entry by name.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Get (compiling on first use) an executable.
    #[cfg(feature = "pjrt")]
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.compiled.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name}")))?
            .clone();
        let path = self.dir.join(&spec.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = Rc::new(Executable { spec, exe });
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), Rc::clone(&executable));
        Ok(executable)
    }

    /// Get an executable — unavailable in this build: compiling HLO
    /// requires the `pjrt` feature (see the module docs).
    #[cfg(not(feature = "pjrt"))]
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.compiled.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let _ = self
            .specs
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name}")))?;
        Err(Error::Artifact(format!(
            "artifact {name} cannot be compiled: this build lacks the `pjrt` feature \
             (a vendored xla crate is required; see ARCHITECTURE.md)"
        )))
    }

    /// Convenience: execute an artifact in one call.
    pub fn run_f32(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<f32>> {
        self.executable(name)?.run_f32(inputs)
    }
}

fn parse_artifact(a: &Json) -> Result<ArtifactSpec> {
    let name = a
        .get("name")
        .and_then(|n| n.as_str())
        .ok_or_else(|| Error::Artifact("artifact missing name".into()))?
        .to_string();
    let path = a
        .get("path")
        .and_then(|p| p.as_str())
        .ok_or_else(|| Error::Artifact(format!("{name}: missing path")))?
        .to_string();
    let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
        a.get(key)
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::Artifact(format!("{name}: missing {key}")))?
            .iter()
            .map(|s| {
                let shape = s
                    .get("shape")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| Error::Artifact(format!("{name}: bad shape")))?
                    .iter()
                    .map(|d| d.as_f64().unwrap_or(0.0) as usize)
                    .collect();
                let dtype = DType::from_str(
                    s.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32"),
                )?;
                Ok(TensorSpec { shape, dtype })
            })
            .collect()
    };
    let inputs = parse_specs("inputs")?;
    let outputs = parse_specs("outputs")?;
    Ok(ArtifactSpec { name, path, inputs, outputs })
}

thread_local! {
    static THREAD_ENGINE: RefCell<Option<Rc<Engine>>> = const { RefCell::new(None) };
}

/// Per-thread engine (PJRT clients are heavy and `!Send`; one per
/// thread, constructed on first use from [`Engine::default_dir`]).
pub fn thread_engine() -> Result<Rc<Engine>> {
    THREAD_ENGINE.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(e) = slot.as_ref() {
            return Ok(Rc::clone(e));
        }
        let engine = Rc::new(Engine::load(Engine::default_dir())?);
        *slot = Some(Rc::clone(&engine));
        Ok(engine)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_elements() {
        let s = TensorSpec { shape: vec![2, 3, 4], dtype: DType::F32 };
        assert_eq!(s.elements(), 24);
    }

    #[test]
    fn tensor_shape_matching() {
        let spec = TensorSpec { shape: vec![2, 2], dtype: DType::F32 };
        assert!(Tensor::F32(vec![0.0; 4], vec![2, 2]).matches(&spec));
        assert!(!Tensor::F32(vec![0.0; 4], vec![4]).matches(&spec));
        assert!(!Tensor::I32(vec![0; 4], vec![2, 2]).matches(&spec));
    }

    #[test]
    fn manifest_parsing() {
        let manifest = r#"{"format":"hlo-text","artifacts":[
            {"name":"t","path":"t.hlo.txt",
             "inputs":[{"shape":[4],"dtype":"f32"},{"shape":[2,4],"dtype":"i32"}],
             "outputs":[{"shape":[4],"dtype":"f32"}],
             "meta":{"kind":"x"}}]}"#;
        let json = Json::parse(manifest).unwrap();
        let a = &json.get("artifacts").unwrap().as_arr().unwrap()[0];
        let spec = parse_artifact(a).unwrap();
        assert_eq!(spec.name, "t");
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[1].dtype, DType::I32);
        assert_eq!(spec.inputs[1].shape, vec![2, 4]);
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = match Engine::load("/nonexistent/dir") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn dtype_parsing() {
        assert!(DType::from_str("f32").is_ok());
        assert!(DType::from_str("i32").is_ok());
        assert!(DType::from_str("f16").is_err());
    }
}
