//! The [`PlanStore`] — a shared, thread-safe registry of compiled RSR
//! plans, and the compile-once/serve-many execution primitives
//! ([`SharedRsrPlan`], [`SharedTernaryPlan`], [`PlanScratch`]).
//!
//! ## Why this exists
//!
//! The per-plan state of [`crate::kernels::rsr::RsrPlan`] /
//! [`crate::kernels::rsrpp::RsrPlusPlusPlan`] bundles two things with
//! very different lifetimes:
//!
//! * the **flat plan** ([`crate::kernels::FlatPlan`], the contiguous
//!   arena form of the paper's Algorithm 1 output) — large, immutable,
//!   expensive to build, identical for every thread serving the model;
//! * the **execution scratch** (`u`, fold buffers) — tiny, mutated on
//!   every multiply, inherently per-thread.
//!
//! The seed code rebuilt both *per worker, per replica, per process
//! start*: a `serve --replicas 4 --workers 4` deployment preprocessed
//! every weight matrix sixteen times and held sixteen copies in memory.
//! This module splits the two: a [`SharedTernaryPlan`] holds the flat
//! plan behind an `Arc` (validated once, then read-only), and every
//! executor carries its own [`PlanScratch`] sized from the plan's
//! `max_u`. The [`PlanStore`] is the registry that hands plans out by
//! layer name, building each at most once — from an in-memory model,
//! or lazily from `.rsrz` artifacts packed offline by `rsr pack`
//! (see [`crate::kernels::artifact`]; the v2 payload *is* the arena,
//! so a disk load lands directly in execution form).
//!
//! Execution uses RSR++ (Algorithm 2 with Algorithm 3 in step 2), the
//! paper's `O(n²/log n)` fast path, through the **same** flat kernel
//! loop as the owned `TernaryRsrPlusPlusPlan` — outputs are
//! bit-identical to the owned in-memory plan, which the artifact
//! round-trip tests assert.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::kernels::artifact::{ternary_fingerprint, ArtifactPayload, PlanArtifact};
use crate::kernels::batched::BatchedExec;
use crate::kernels::flat::{
    execute_rsr_flat, execute_rsrpp_flat, execute_rsrpp_flat_scalar, FlatPlan,
    TernaryFlatPlan,
};
use crate::kernels::index::{RsrIndex, TernaryRsrIndex};
use crate::kernels::optimal_k::optimal_k_rsrpp;
use crate::kernels::rsr::check_shapes;
use crate::kernels::tl::{TlPlan, TL_GROUP};
use crate::model::weights::ModelWeights;
use crate::tune::profile::{LayerChoice, TuneProfile};

/// Per-thread execution scratch: the `u` segmented-sum buffer, the
/// RSR++ fold buffer, and the ternary subtraction temporary. Cheap to
/// create (three `Vec<f32>`s), grown on demand, reusable across plans.
#[derive(Debug, Clone, Default)]
pub struct PlanScratch {
    u: Vec<f32>,
    fold: Vec<f32>,
    tmp: Vec<f32>,
}

impl PlanScratch {
    /// An empty scratch; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    fn with_capacity(max_u: usize, cols: usize) -> Self {
        Self { u: vec![0.0; max_u], fold: vec![0.0; max_u], tmp: vec![0.0; cols] }
    }

    fn ensure_u(&mut self, max_u: usize) {
        if self.u.len() < max_u {
            self.u.resize(max_u, 0.0);
        }
        if self.fold.len() < max_u {
            self.fold.resize(max_u, 0.0);
        }
    }

    /// Heap bytes currently held — what each *thread* pays, as opposed
    /// to the shared index bytes paid once per process.
    pub fn bytes(&self) -> usize {
        (self.u.len() + self.fold.len() + self.tmp.len()) * 4
    }
}

/// An immutable, `Arc`-shareable RSR++ plan for one binary matrix: the
/// validated flat arena. Unlike
/// [`crate::kernels::rsrpp::RsrPlusPlusPlan`] it takes `&self` — many
/// threads execute the same plan concurrently, each with its own
/// [`PlanScratch`].
#[derive(Debug, Clone)]
pub struct SharedRsrPlan {
    flat: Arc<FlatPlan>,
}

impl SharedRsrPlan {
    /// Flatten (and validate) an index and wrap it for sharing.
    pub fn new(index: RsrIndex) -> Result<Self> {
        Ok(Self { flat: Arc::new(FlatPlan::from_index(&index)?) })
    }

    /// Wrap an already-validated flat plan (the `.rsrz` v2 load path —
    /// no copy, no revalidation).
    pub fn from_flat(flat: FlatPlan) -> Self {
        Self { flat: Arc::new(flat) }
    }

    /// The shared flat plan (the view every executor reads).
    pub fn flat(&self) -> &FlatPlan {
        &self.flat
    }

    /// Rows of the planned matrix (input length).
    pub fn rows(&self) -> usize {
        self.flat.rows()
    }

    /// Columns of the planned matrix (output length).
    pub fn cols(&self) -> usize {
        self.flat.cols()
    }

    /// Shared index bytes (paid once per process, not per thread).
    pub fn index_bytes(&self) -> usize {
        self.flat.bytes()
    }

    /// A scratch sized for this plan.
    pub fn scratch(&self) -> PlanScratch {
        PlanScratch::with_capacity(self.flat.max_u(), 0)
    }

    /// `out = v · B` via RSR++ (Algorithms 2 + 3), through the same
    /// flat kernel loop as `RsrPlusPlusPlan::execute` — bit-identical
    /// results.
    pub fn execute(&self, scratch: &mut PlanScratch, v: &[f32], out: &mut [f32]) -> Result<()> {
        check_shapes(self.flat.rows(), self.flat.cols(), v, out)?;
        scratch.ensure_u(self.flat.max_u());
        execute_rsrpp_flat(&self.flat, v, out, &mut scratch.u, &mut scratch.fold);
        Ok(())
    }

    /// [`execute`](Self::execute) pinned to the scalar gather kernel —
    /// the tuner's `rsr++-scalar` candidate, selected where the AVX2
    /// gather loses.
    pub fn execute_scalar(
        &self,
        scratch: &mut PlanScratch,
        v: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        check_shapes(self.flat.rows(), self.flat.cols(), v, out)?;
        scratch.ensure_u(self.flat.max_u());
        execute_rsrpp_flat_scalar(&self.flat, v, out, &mut scratch.u, &mut scratch.fold);
        Ok(())
    }

    /// `out = v · B` via RSR (Algorithm 2 with the dense step-2 block
    /// product) — bit-identical to
    /// [`RsrPlan::execute`](crate::kernels::rsr::RsrPlan::execute) on
    /// the same index.
    pub fn execute_rsr(
        &self,
        scratch: &mut PlanScratch,
        v: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        check_shapes(self.flat.rows(), self.flat.cols(), v, out)?;
        scratch.ensure_u(self.flat.max_u());
        execute_rsr_flat(&self.flat, v, out, &mut scratch.u);
        Ok(())
    }
}

/// An immutable, `Arc`-shareable ternary RSR++ plan (both Prop 2.1
/// halves). See [`SharedRsrPlan`] for the sharing model.
#[derive(Debug, Clone)]
pub struct SharedTernaryPlan {
    plus: SharedRsrPlan,
    minus: SharedRsrPlan,
    /// The derived TL code table, built lazily on the first executor
    /// that asks for a TL backend and shared by every clone (clones
    /// share the cell, so one build serves all replicas/workers).
    tl: Arc<OnceLock<Arc<TlPlan>>>,
}

impl SharedTernaryPlan {
    /// Flatten (and validate) a ternary index pair and wrap it for
    /// sharing.
    pub fn new(index: TernaryRsrIndex) -> Result<Self> {
        Self::from_flat(TernaryFlatPlan::from_index(&index)?)
    }

    /// Wrap an already-validated flat plan pair (the `.rsrz` v2 load
    /// path).
    pub fn from_flat(plan: TernaryFlatPlan) -> Result<Self> {
        plan.check_geometry()?;
        Ok(Self {
            plus: SharedRsrPlan::from_flat(plan.plus),
            minus: SharedRsrPlan::from_flat(plan.minus),
            tl: Arc::new(OnceLock::new()),
        })
    }

    /// The TL execution form of this plan at the default group size
    /// ([`TL_GROUP`]): grouped 2-bit weight codes reconstructed from
    /// the flat arenas, built at most once per shared plan and cached —
    /// the "precompute at plan-build time" half of the TL contract.
    /// Concurrent first callers may race the build; the loser's copy is
    /// dropped (benign — construction is deterministic).
    pub fn tl_plan(&self) -> Result<Arc<TlPlan>> {
        if let Some(p) = self.tl.get() {
            return Ok(Arc::clone(p));
        }
        let built = Arc::new(TlPlan::from_halves(
            self.plus.flat(),
            self.minus.flat(),
            TL_GROUP,
        )?);
        let _ = self.tl.set(built);
        Ok(Arc::clone(self.tl.get().expect("just set")))
    }

    /// Rows (input length).
    pub fn rows(&self) -> usize {
        self.plus.rows()
    }

    /// Columns (output length).
    pub fn cols(&self) -> usize {
        self.plus.cols()
    }

    /// Shared index bytes across both halves.
    pub fn index_bytes(&self) -> usize {
        self.plus.index_bytes() + self.minus.index_bytes()
    }

    /// The `B⁽¹⁾` half's flat plan.
    pub fn plus_flat(&self) -> &FlatPlan {
        self.plus.flat()
    }

    /// The `B⁽²⁾` half's flat plan.
    pub fn minus_flat(&self) -> &FlatPlan {
        self.minus.flat()
    }

    /// A scratch sized for this plan.
    pub fn scratch(&self) -> PlanScratch {
        PlanScratch::with_capacity(
            self.plus.flat.max_u().max(self.minus.flat.max_u()),
            self.cols(),
        )
    }

    /// `out = v · A = v·B⁽¹⁾ − v·B⁽²⁾` with `half` executing each
    /// Prop 2.1 half — the one subtraction structure every per-half
    /// variant shares.
    fn execute_with(
        &self,
        scratch: &mut PlanScratch,
        v: &[f32],
        out: &mut [f32],
        half: impl Fn(&SharedRsrPlan, &mut PlanScratch, &[f32], &mut [f32]) -> Result<()>,
    ) -> Result<()> {
        let mut tmp = std::mem::take(&mut scratch.tmp);
        if tmp.len() != self.cols() {
            tmp.resize(self.cols(), 0.0);
        }
        let result = (|| -> Result<()> {
            half(&self.plus, scratch, v, out)?;
            half(&self.minus, scratch, v, &mut tmp)?;
            for (o, t) in out.iter_mut().zip(tmp.iter()) {
                *o -= t;
            }
            Ok(())
        })();
        scratch.tmp = tmp;
        result
    }

    /// `out = v · A = v·B⁽¹⁾ − v·B⁽²⁾`, identical operation order to
    /// `TernaryRsrPlusPlusPlan::execute` — bit-identical results.
    pub fn execute(&self, scratch: &mut PlanScratch, v: &[f32], out: &mut [f32]) -> Result<()> {
        self.execute_with(scratch, v, out, SharedRsrPlan::execute)
    }

    /// [`execute`](Self::execute) pinned to the scalar gather kernel.
    pub fn execute_scalar(
        &self,
        scratch: &mut PlanScratch,
        v: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        self.execute_with(scratch, v, out, SharedRsrPlan::execute_scalar)
    }

    /// `out = v · A` via RSR (dense step-2 block product per half).
    pub fn execute_rsr(
        &self,
        scratch: &mut PlanScratch,
        v: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        self.execute_with(scratch, v, out, SharedRsrPlan::execute_rsr)
    }

    /// A batched executor sized for this plan, accepting batches up to
    /// `max_batch` rows — the per-instance scratch of the batched
    /// serving path, analogous to [`scratch`](Self::scratch) for the
    /// single-vector one.
    pub fn batch_exec(&self, max_batch: usize) -> Result<BatchedExec> {
        let max_u = self.plus.flat.max_u().max(self.minus.flat.max_u());
        BatchedExec::new(self.rows(), max_u, max_batch)
    }

    /// `out[b] = vs[b] · A` for every row of a row-major `batch × rows`
    /// activation block (`out` is `batch × cols`): the batched decode
    /// hot path, reading the shared index once per **batch** instead of
    /// once per vector (see [`crate::kernels::batched`]). Per row the
    /// kernel performs the identical f32 addition sequence at every
    /// batch size, so a row's result never depends on its batchmates.
    /// The executor's batch ceiling is raised to `batch` automatically
    /// (continuous batching grows the live-slot count mid-flight).
    pub fn execute_batch(
        &self,
        exec: &mut BatchedExec,
        vs: &[f32],
        batch: usize,
        out: &mut [f32],
    ) -> Result<()> {
        exec.ensure_batch(batch);
        exec.execute_ternary(self.plus_flat(), self.minus_flat(), vs, batch, out)
    }
}

/// A named, compiled plan held by the store.
#[derive(Debug, Clone)]
pub struct PlanEntry {
    /// Layer name (the store key, e.g. `layer0.wq`).
    pub name: String,
    /// Blocking parameter the index was built with.
    pub k: usize,
    /// Per-tensor scale β.
    pub scale: f32,
    /// Fingerprint of the weights this plan was compiled from
    /// ([`ternary_fingerprint`]); `0` = unbound. Serve-time model
    /// builders compare it against their weights so stale artifact
    /// directories fail loudly instead of serving wrong logits.
    pub weights_fp: u64,
    /// The tuned execution choice for this layer, when the store was
    /// built [`with_profile`](PlanStore::with_profile); `None` executes
    /// the untuned default (shared RSR++). Consumers
    /// ([`BitLinear::from_plan_entry`]) materialize an
    /// [`ExecutablePlan`](crate::runtime::ExecutablePlan) from it.
    ///
    /// [`BitLinear::from_plan_entry`]: crate::model::bitlinear::BitLinear::from_plan_entry
    pub tuned: Option<LayerChoice>,
    /// The plan itself.
    pub plan: PlanKind,
}

/// Binary or ternary compiled plan.
#[derive(Debug, Clone)]
pub enum PlanKind {
    /// Plan over one binary matrix.
    Binary(Arc<SharedRsrPlan>),
    /// Plan over a ternary matrix (both Prop 2.1 halves).
    Ternary(Arc<SharedTernaryPlan>),
}

impl PlanEntry {
    /// The ternary plan, or an error if this entry is binary.
    pub fn ternary(&self) -> Result<Arc<SharedTernaryPlan>> {
        match &self.plan {
            PlanKind::Ternary(p) => Ok(Arc::clone(p)),
            PlanKind::Binary(_) => Err(Error::Config(format!(
                "plan {} is binary, expected ternary",
                self.name
            ))),
        }
    }

    /// The binary plan, or an error if this entry is ternary.
    pub fn binary(&self) -> Result<Arc<SharedRsrPlan>> {
        match &self.plan {
            PlanKind::Binary(p) => Ok(Arc::clone(p)),
            PlanKind::Ternary(_) => Err(Error::Config(format!(
                "plan {} is ternary, expected binary",
                self.name
            ))),
        }
    }

    /// Shared index bytes of this entry.
    pub fn index_bytes(&self) -> usize {
        match &self.plan {
            PlanKind::Binary(p) => p.index_bytes(),
            PlanKind::Ternary(p) => p.index_bytes(),
        }
    }

    /// `(rows, cols)` of the planned matrix.
    pub fn shape(&self) -> (usize, usize) {
        match &self.plan {
            PlanKind::Binary(p) => (p.rows(), p.cols()),
            PlanKind::Ternary(p) => (p.rows(), p.cols()),
        }
    }
}

/// Where the store materializes plans from on a cache miss.
enum Source {
    /// No backing source; only explicitly inserted entries resolve.
    None,
    /// A directory of `{name}.rsrz` artifacts (the `rsr pack` output).
    Dir(PathBuf),
    /// Preprocess lazily from in-memory model weights with blocking
    /// parameter `k` (`0` → analytic optimum per matrix).
    Model { weights: Arc<ModelWeights>, k: usize },
}

/// The process-wide plan registry: loads/compiles each plan once (two
/// racing first requests may duplicate the build; one result wins),
/// caches it behind an `Arc`, and serves it to every thread.
///
/// Typical lifecycle:
///
/// ```text
///   offline:  rsr pack --model m.rtw --out plans/      (Algorithm 1, once)
///   serve:    PlanStore::open("plans/")                (mmap-friendly lazy loads)
///             → engine workers share Arc<PlanStore>
///             → each worker: plan = store.get("layer0.wq"),
///                            scratch = plan.scratch()   (per-thread)
/// ```
///
/// All methods take `&self`; the store is `Send + Sync` and intended to
/// live in an `Arc` shared across replicas and worker threads.
pub struct PlanStore {
    source: Source,
    entries: Mutex<HashMap<String, Arc<PlanEntry>>>,
    /// Tuned `(k, backend)` choices per layer
    /// ([`with_profile`](Self::with_profile)); `None` = untuned
    /// defaults.
    profile: Option<Arc<TuneProfile>>,
    /// Set once [`verify_fingerprints`](Self::verify_fingerprints) has
    /// succeeded, letting per-worker model builds skip the per-layer
    /// weight hashing.
    fingerprints_verified: AtomicBool,
}

impl PlanStore {
    /// An empty registry; populate with [`insert_ternary`](Self::insert_ternary).
    pub fn new() -> Self {
        Self {
            source: Source::None,
            entries: Mutex::new(HashMap::new()),
            profile: None,
            fingerprints_verified: AtomicBool::new(false),
        }
    }

    /// A registry backed by a directory of `.rsrz` artifacts (the
    /// output of `rsr pack`). Artifacts load lazily on first `get`;
    /// each load validates the artifact checksum before the plan is
    /// handed to any executor. Stray `*.tmp` leftovers of a killed
    /// `rsr pack` are quarantined here, at open, so a partial write
    /// can never shadow or be mistaken for a finished plan.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(Error::Artifact(format!(
                "plan directory {} does not exist",
                dir.display()
            )));
        }
        crate::util::atomicfile::quarantine_stray_tmp(&dir)?;
        Ok(Self {
            source: Source::Dir(dir),
            entries: Mutex::new(HashMap::new()),
            profile: None,
            fingerprints_verified: AtomicBool::new(false),
        })
    }

    /// A registry that preprocesses lazily from in-memory model weights
    /// (`k = 0` → analytic optimum per matrix). Each layer is cached
    /// after its first build and shared by every replica/worker that
    /// requests it.
    pub fn for_model(weights: Arc<ModelWeights>, k: usize) -> Self {
        Self {
            source: Source::Model { weights, k },
            entries: Mutex::new(HashMap::new()),
            profile: None,
            fingerprints_verified: AtomicBool::new(false),
        }
    }

    /// Attach an `rsr tune` profile: every layer the profile names is
    /// materialized with its tuned `(k, backend)` instead of the global
    /// defaults, and the resulting entries carry the choice for
    /// executors to dispatch on. Strictly additive — layers absent from
    /// the profile (and stores never given one) behave exactly as
    /// before.
    ///
    /// Fails if the profile was measured on a different machine
    /// ([`TuneProfile::verify_host`]) — tuned rankings do not transfer —
    /// or if entries were already materialized (the choice must govern
    /// the build, not race it).
    ///
    /// On an artifact-backed store the profile can only *select*, not
    /// re-preprocess: a layer whose artifact was packed at a different
    /// `k` than the profile's winner fails at load with instructions to
    /// re-pack.
    pub fn with_profile(self, profile: TuneProfile) -> Result<Self> {
        profile.verify_host()?;
        if self.loaded_len() > 0 {
            return Err(Error::Config(
                "with_profile must be applied before any plan is materialized".into(),
            ));
        }
        Ok(Self { profile: Some(Arc::new(profile)), ..self })
    }

    /// The attached tuning profile, if any.
    pub fn profile(&self) -> Option<&TuneProfile> {
        self.profile.as_deref()
    }

    /// Get (building/loading on first use) the plan for `name`.
    pub fn get(&self, name: &str) -> Result<Arc<PlanEntry>> {
        if let Some(e) = self.entries.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        // Build OUTSIDE the lock: a multi-second Algorithm-1 run (or a
        // disk load) must not serialize unrelated lookups and cache
        // hits. Racing first requests for the same name may build
        // twice; the first insert wins and every caller converges on
        // that one `Arc`, so sharing still holds.
        let entry = Arc::new(self.build(name)?);
        let mut entries = self.entries.lock().unwrap();
        let winner = entries.entry(name.to_string()).or_insert(entry);
        Ok(Arc::clone(winner))
    }

    fn build(&self, name: &str) -> Result<PlanEntry> {
        // The tuned choice governs this build: the blocking parameter
        // `k` the index must carry, and the backend the entry records.
        let layer_profile = self.profile.as_ref().and_then(|p| p.get(name));
        let tuned = layer_profile.map(|l| *l.winner());
        // A profile's measurements only apply to the matrix shape they
        // were taken on; a name collision across different checkpoints
        // must not silently apply a foreign (k, backend).
        let check_profile_shape = |rows: usize, cols: usize| -> Result<()> {
            if let Some(lp) = layer_profile {
                if (lp.rows, lp.cols) != (rows, cols) {
                    return Err(Error::InvalidModel(format!(
                        "tuning profile measured {name} as {}x{}, but the served \
                         matrix is {rows}x{cols} — re-run `rsr tune` on these weights",
                        lp.rows, lp.cols
                    )));
                }
            }
            Ok(())
        };
        match &self.source {
            Source::None => Err(Error::Config(format!(
                "plan {name} not found in store (no backing source)"
            ))),
            Source::Dir(dir) => {
                let path = dir.join(format!("{name}.rsrz"));
                let art = PlanArtifact::load(&path).map_err(|e| {
                    Error::Artifact(format!("loading {}: {e}", path.display()))
                })?;
                check_profile_shape(art.meta.rows, art.meta.cols)?;
                // A packed artifact is preprocessed at a fixed k; the
                // profile can select its backend but cannot re-block
                // the index.
                if let Some(choice) = &tuned {
                    if choice.k != art.meta.k {
                        return Err(Error::Config(format!(
                            "plan {name} was packed with k={} but the tuning profile \
                             selected k={} — re-pack at the tuned blocking \
                             (`rsr pack --model … --profile …`), or serve without \
                             --plans to preprocess at the tuned k",
                            art.meta.k, choice.k
                        )));
                    }
                }
                // The decoded payload is already the flat execution
                // form — wrap it without copying or revalidating.
                let plan = match art.payload {
                    ArtifactPayload::Binary(flat) => {
                        PlanKind::Binary(Arc::new(SharedRsrPlan::from_flat(flat)))
                    }
                    ArtifactPayload::Ternary(t) => {
                        PlanKind::Ternary(Arc::new(SharedTernaryPlan::from_flat(t)?))
                    }
                };
                Ok(PlanEntry {
                    name: name.to_string(),
                    k: art.meta.k,
                    scale: art.meta.scale,
                    weights_fp: art.meta.weights_fp,
                    tuned,
                    plan,
                })
            }
            Source::Model { weights, k } => {
                let (m, scale) = weights.matrix(name).ok_or_else(|| {
                    Error::Config(format!("model has no matrix named {name}"))
                })?;
                check_profile_shape(m.rows(), m.cols())?;
                let k_eff = match &tuned {
                    Some(choice) => choice.k,
                    None if *k == 0 => optimal_k_rsrpp(m.rows()),
                    None => *k,
                };
                let idx = TernaryRsrIndex::preprocess(m, k_eff);
                Ok(PlanEntry {
                    name: name.to_string(),
                    k: k_eff,
                    scale,
                    weights_fp: ternary_fingerprint(m),
                    tuned,
                    plan: PlanKind::Ternary(Arc::new(SharedTernaryPlan::new(idx)?)),
                })
            }
        }
    }

    /// Insert an explicitly built ternary plan (benches / tests / ad
    /// hoc callers without a model or artifact dir).
    pub fn insert_ternary(
        &self,
        name: impl Into<String>,
        index: TernaryRsrIndex,
        k: usize,
        scale: f32,
    ) -> Result<Arc<PlanEntry>> {
        let name = name.into();
        let entry = Arc::new(PlanEntry {
            name: name.clone(),
            k,
            scale,
            weights_fp: 0,
            tuned: None,
            plan: PlanKind::Ternary(Arc::new(SharedTernaryPlan::new(index)?)),
        });
        self.entries.lock().unwrap().insert(name, Arc::clone(&entry));
        Ok(entry)
    }

    /// Whether entries come from external artifacts (disk) rather than
    /// the served weights themselves. Only then does a serve-time
    /// weights-fingerprint comparison carry information — a
    /// Model-backed store's fingerprints were computed from the very
    /// matrices being served, so checking them would cost a full pass
    /// over the weights per worker to confirm a tautology.
    pub fn is_artifact_backed(&self) -> bool {
        matches!(self.source, Source::Dir(_))
    }

    /// Resolve every name now, surfacing missing/corrupt artifacts as
    /// one early error instead of per-worker failures at request time.
    pub fn preload(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Compare every one of `weights`' matrices against its loaded
    /// plan's weights fingerprint, **once** for the whole store; model
    /// builds ([`Transformer::from_plan_store`]) then skip their
    /// per-layer recomputation, so the full pass over the weights
    /// happens once per process instead of once per worker thread.
    ///
    /// [`Transformer::from_plan_store`]: crate::model::Transformer::from_plan_store
    pub fn verify_fingerprints(&self, weights: &ModelWeights) -> Result<()> {
        for (name, m, _scale) in weights.named_matrices() {
            let entry = self.get(&name)?;
            if entry.weights_fp != 0 && entry.weights_fp != ternary_fingerprint(m) {
                return Err(Error::InvalidModel(format!(
                    "plan {name} was packed from different weights \
                     (fingerprint mismatch — re-run `rsr pack`)"
                )));
            }
        }
        self.fingerprints_verified.store(true, Ordering::Release);
        Ok(())
    }

    /// Whether [`verify_fingerprints`](Self::verify_fingerprints) has
    /// already succeeded for this store.
    pub fn fingerprints_verified(&self) -> bool {
        self.fingerprints_verified.load(Ordering::Acquire)
    }

    /// Names currently materialized, sorted.
    pub fn loaded_names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.entries.lock().unwrap().keys().cloned().collect();
        v.sort_unstable();
        v
    }

    /// Number of materialized plans.
    pub fn loaded_len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Total shared index bytes across materialized plans — the
    /// process-wide weight footprint every thread shares.
    pub fn index_bytes(&self) -> usize {
        self.entries.lock().unwrap().values().map(|e| e.index_bytes()).sum()
    }
}

impl Default for PlanStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::rsrpp::TernaryRsrPlusPlusPlan;
    use crate::kernels::TernaryMatrix;
    use crate::model::config::ModelConfig;
    use crate::util::rng::Rng;

    fn sample_plan(n: usize, m: usize, k: usize, seed: u64) -> (TernaryMatrix, SharedTernaryPlan) {
        let mut rng = Rng::new(seed);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let plan = SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap();
        (a, plan)
    }

    #[test]
    fn shared_plan_is_bit_identical_to_owned_plan() {
        let (a, shared) = sample_plan(96, 64, 4, 401);
        let mut rng = Rng::new(402);
        let v = rng.f32_vec(96, -1.0, 1.0);
        let mut owned =
            TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(&a, 4)).unwrap();
        let mut expect = vec![0.0; 64];
        owned.execute(&v, &mut expect).unwrap();
        let mut scratch = shared.scratch();
        let mut got = vec![0.0; 64];
        shared.execute(&mut scratch, &v, &mut got).unwrap();
        assert_eq!(got, expect, "shared plan must be bit-identical to owned plan");
    }

    #[test]
    fn empty_scratch_grows_on_demand() {
        let (_, shared) = sample_plan(50, 30, 3, 403);
        let mut rng = Rng::new(404);
        let v = rng.f32_vec(50, -1.0, 1.0);
        let mut sized = shared.scratch();
        let mut fresh = PlanScratch::new();
        let mut a = vec![0.0; 30];
        let mut b = vec![0.0; 30];
        shared.execute(&mut sized, &v, &mut a).unwrap();
        shared.execute(&mut fresh, &v, &mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn concurrent_executions_share_one_index() {
        let (a, shared) = sample_plan(128, 80, 5, 405);
        let shared = Arc::new(shared);
        let mut rng = Rng::new(406);
        let v = rng.f32_vec(128, -1.0, 1.0);
        let mut owned =
            TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(&a, 5)).unwrap();
        let mut expect = vec![0.0; 80];
        owned.execute(&v, &mut expect).unwrap();

        let handles: Vec<_> = (0..4)
            .map(|_| {
                let plan = Arc::clone(&shared);
                let v = v.clone();
                std::thread::spawn(move || {
                    let mut scratch = plan.scratch();
                    let mut out = vec![0.0; 80];
                    for _ in 0..8 {
                        plan.execute(&mut scratch, &v, &mut out).unwrap();
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), expect);
        }
    }

    #[test]
    fn shared_execute_batch_matches_per_vector_rows() {
        let (a, shared) = sample_plan(72, 44, 4, 410);
        let mut rng = Rng::new(411);
        let batch = 3;
        let vs = rng.f32_vec(batch * 72, -1.0, 1.0);
        let mut exec = shared.batch_exec(1).unwrap(); // grows to 3 per call
        let mut out = vec![0.0; batch * 44];
        shared.execute_batch(&mut exec, &vs, batch, &mut out).unwrap();
        for bi in 0..batch {
            let expect = crate::kernels::standard::standard_mul_ternary(
                &vs[bi * 72..(bi + 1) * 72],
                &a,
            );
            for (g, e) in out[bi * 44..(bi + 1) * 44].iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()), "row {bi}: {g} vs {e}");
            }
            // Bit-identical to the same row executed alone — the
            // batch-size invariance ragged serving depends on.
            let mut solo = vec![0.0; 44];
            shared
                .execute_batch(&mut exec, &vs[bi * 72..(bi + 1) * 72], 1, &mut solo)
                .unwrap();
            assert_eq!(&out[bi * 44..(bi + 1) * 44], &solo[..]);
        }
    }

    #[test]
    fn tl_plan_is_built_once_and_matches_rsrpp() {
        let (_, shared) = sample_plan(60, 36, 4, 420);
        let first = shared.tl_plan().unwrap();
        let again = shared.tl_plan().unwrap();
        assert!(Arc::ptr_eq(&first, &again), "second request must hit the cache");
        let cloned = shared.clone();
        assert!(
            Arc::ptr_eq(&first, &cloned.tl_plan().unwrap()),
            "clones must share the cached TL plan"
        );
        // Integer activations: TL and RSR++ agree to the last bit.
        let mut rng = Rng::new(421);
        let v = rng.int_f32_vec(60, 4);
        let mut scratch = shared.scratch();
        let mut expect = vec![0.0; 36];
        shared.execute(&mut scratch, &v, &mut expect).unwrap();
        let mut lut = first.scratch();
        let mut got = vec![0.0; 36];
        first.execute(&v, &mut got, &mut lut).unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn store_builds_each_plan_once() {
        let weights =
            Arc::new(crate::model::weights::ModelWeights::generate(ModelConfig::tiny(), 7).unwrap());
        let store = PlanStore::for_model(Arc::clone(&weights), 0);
        let a = store.get("layer0.wq").unwrap();
        let b = store.get("layer0.wq").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second get must hit the cache");
        assert_eq!(store.loaded_len(), 1);
        assert!(store.index_bytes() > 0);
        assert!(store.get("layer0.nope").is_err());
    }

    #[test]
    fn store_rejects_unknown_names_without_source() {
        let store = PlanStore::new();
        assert!(store.get("anything").is_err());
        let mut rng = Rng::new(407);
        let a = TernaryMatrix::random(32, 16, 1.0 / 3.0, &mut rng);
        store
            .insert_ternary("adhoc", TernaryRsrIndex::preprocess(&a, 3), 3, 1.0)
            .unwrap();
        let e = store.get("adhoc").unwrap();
        assert_eq!(e.shape(), (32, 16));
        assert_eq!(e.ternary().unwrap().cols(), 16);
        assert!(e.binary().is_err());
    }

    #[test]
    fn with_profile_governs_k_and_marks_entries() {
        use crate::tune::candidates::TunedBackend;
        use crate::tune::profile::{
            LayerChoice, LayerProfile, MachineFingerprint, TuneProfile,
        };
        let weights =
            Arc::new(crate::model::weights::ModelWeights::generate(ModelConfig::tiny(), 9).unwrap());
        // Analytic k for d=64 rows differs from the forced k below.
        let forced_k = 3;
        assert_ne!(crate::kernels::optimal_k::optimal_k_rsrpp(64), forced_k);
        let profile = TuneProfile::new(
            MachineFingerprint::current(),
            vec![LayerProfile {
                name: "layer0.wq".into(),
                rows: 64,
                cols: 64,
                chain: vec![LayerChoice {
                    backend: TunedBackend::Rsr,
                    k: forced_k,
                    ns: 1.0,
                }],
            }],
        )
        .unwrap();
        let store = PlanStore::for_model(Arc::clone(&weights), 0)
            .with_profile(profile)
            .unwrap();
        let tuned = store.get("layer0.wq").unwrap();
        assert_eq!(tuned.k, forced_k, "profile k must govern the build");
        assert_eq!(tuned.tuned.unwrap().backend, TunedBackend::Rsr);
        // Layers absent from the profile keep the untuned defaults.
        let untouched = store.get("layer0.wk").unwrap();
        assert_eq!(untouched.k, crate::kernels::optimal_k::optimal_k_rsrpp(64));
        assert!(untouched.tuned.is_none());
    }

    #[test]
    fn foreign_profile_is_rejected_at_attach() {
        use crate::tune::profile::{MachineFingerprint, TuneProfile};
        let mut fp = MachineFingerprint::current();
        fp.threads += 1;
        let profile = TuneProfile::new(fp, vec![]).unwrap();
        let err = PlanStore::new().with_profile(profile).unwrap_err();
        assert!(err.to_string().contains("different machine"), "{err}");
    }

    #[test]
    fn shape_errors_surface() {
        let (_, shared) = sample_plan(40, 20, 3, 408);
        let mut scratch = shared.scratch();
        let mut out = vec![0.0; 20];
        assert!(shared.execute(&mut scratch, &[0.0; 39], &mut out).is_err());
        let mut bad_out = vec![0.0; 19];
        assert!(shared.execute(&mut scratch, &[0.0; 40], &mut bad_out).is_err());
    }

    #[test]
    fn flat_views_expose_both_halves() {
        let (_, shared) = sample_plan(48, 20, 3, 409);
        assert_eq!(shared.plus_flat().rows(), 48);
        assert_eq!(shared.minus_flat().cols(), 20);
        assert_eq!(
            shared.index_bytes(),
            shared.plus_flat().bytes() + shared.minus_flat().bytes()
        );
    }
}
