//! [`ExecutablePlan`] — one execution object over a store-shared plan,
//! dispatching to whichever backend a tuning profile selected.
//!
//! The plan families (RSR, RSR++ scalar/SIMD, block-parallel, batched,
//! table-lookup) previously had unrelated execute signatures; the
//! profile-driven serve path needs them behind **one** `execute(v,
//! out)` so a [`BitLinear`](crate::model::bitlinear::BitLinear) can run
//! whatever `rsr tune` measured fastest without caring which family
//! won. The heavy state — the validated flat arenas — stays behind the
//! store's `Arc` ([`SharedTernaryPlan`]); an `ExecutablePlan` owns only
//! its per-instance scratch (and, for the parallel variant, a handle to
//! the process-wide worker pool), so N workers still cost one index.
//!
//! The tuner executes candidates through this same type, which is what
//! makes its measurements transfer to serving.

use std::sync::Arc;
use std::time::Instant;

use super::plan_store::{PlanScratch, SharedTernaryPlan};
use crate::error::{Error, Result};
use crate::kernels::batched::BatchedExec;
use crate::kernels::parallel::SharedParallelExec;
use crate::kernels::tl::{tl_neon_available, TlPlan};
use crate::tune::candidates::TunedBackend;
use crate::util::obs::LayerProbe;
use crate::util::threadpool::PoolHandle;

/// Per-backend execution state (the plan itself lives in the shared
/// `Arc`; this is the cheap, per-instance part).
enum ExecState {
    /// RSR / RSR++ (scalar or SIMD): a plain per-thread scratch.
    Scratch(PlanScratch),
    /// Block-parallel: per-lane scratch + the shared pool handle.
    Parallel(SharedParallelExec),
    /// Batched layout executed at batch 1.
    Batched(BatchedExec),
    /// Table lookup: the shared (plan-cached) code table plus this
    /// executor's private lookup-table scratch.
    Tl { tl: Arc<TlPlan>, lut: Vec<f32> },
}

/// A ready-to-run multiply over a store-shared ternary plan, executing
/// the [`TunedBackend`] it was materialized with.
pub struct ExecutablePlan {
    plan: Arc<SharedTernaryPlan>,
    backend: TunedBackend,
    state: ExecState,
    /// Lazily-built batched executor for [`execute_batch`]
    /// (`Self::execute_batch`) on backends whose single-vector state is
    /// not already batched. `None` until the first batched call — a
    /// purely sequential deployment pays nothing for it.
    batch_exec: Option<BatchedExec>,
    /// Optional per-layer timing probe (`--profile-layers`). `None` —
    /// the default — costs one branch per execute; `Some` adds two
    /// `Instant::now()` calls and two relaxed atomic adds around the
    /// kernel, never a lock.
    probe: Option<Arc<LayerProbe>>,
}

impl std::fmt::Debug for ExecutablePlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecutablePlan")
            .field("backend", &self.backend.name())
            .field("rows", &self.plan.rows())
            .field("cols", &self.plan.cols())
            .finish()
    }
}

impl ExecutablePlan {
    /// Materialize an executor for `backend` over a shared plan. The
    /// parallel variant checks the **process-wide** pool out per
    /// execute ([`PoolHandle::global`]) — building N of these spawns no
    /// threads.
    pub fn new(plan: Arc<SharedTernaryPlan>, backend: TunedBackend) -> Result<Self> {
        let max_u = plan.plus_flat().max_u().max(plan.minus_flat().max_u());
        let state = match backend {
            TunedBackend::Rsr
            | TunedBackend::RsrPlusPlus
            | TunedBackend::RsrPlusPlusScalar => ExecState::Scratch(plan.scratch()),
            TunedBackend::Parallel => ExecState::Parallel(SharedParallelExec::new(
                PoolHandle::global(),
                max_u,
                plan.cols(),
            )),
            TunedBackend::Batched => {
                ExecState::Batched(BatchedExec::new(plan.rows(), max_u, 1)?)
            }
            TunedBackend::Tl | TunedBackend::TlNeon => {
                if backend == TunedBackend::TlNeon && !tl_neon_available() {
                    return Err(Error::Config(
                        "the tl-neon backend requires aarch64 NEON, \
                         which this host lacks"
                            .into(),
                    ));
                }
                let tl = plan.tl_plan()?;
                let lut = tl.scratch();
                ExecState::Tl { tl, lut }
            }
        };
        Ok(Self { plan, backend, state, batch_exec: None, probe: None })
    }

    /// The backend this executor dispatches to.
    pub fn backend(&self) -> TunedBackend {
        self.backend
    }

    /// Attach a timing probe: every [`execute`](Self::execute) /
    /// [`execute_batch`](Self::execute_batch) call records its wall
    /// nanoseconds into the probe's relaxed atomics.
    pub fn set_probe(&mut self, probe: Arc<LayerProbe>) {
        self.probe = Some(probe);
    }

    /// Rows of the planned matrix (input length).
    pub fn rows(&self) -> usize {
        self.plan.rows()
    }

    /// Columns of the planned matrix (output length).
    pub fn cols(&self) -> usize {
        self.plan.cols()
    }

    /// The shared plan this executor runs.
    pub fn plan(&self) -> &Arc<SharedTernaryPlan> {
        &self.plan
    }

    /// Shared index bytes (paid once per process, not per instance).
    pub fn index_bytes(&self) -> usize {
        self.plan.index_bytes()
    }

    /// `out = v · A` through the tuned backend. Same shape contract as
    /// every plan executor: `v.len() == rows`, `out.len() == cols`.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        if let Some(probe) = self.probe.clone() {
            let t0 = Instant::now();
            let res = self.execute_inner(v, out);
            probe.record(t0.elapsed().as_nanos() as u64);
            return res;
        }
        self.execute_inner(v, out)
    }

    fn execute_inner(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        match (&mut self.state, self.backend) {
            (ExecState::Scratch(s), TunedBackend::Rsr) => {
                self.plan.execute_rsr(s, v, out)
            }
            (ExecState::Scratch(s), TunedBackend::RsrPlusPlus) => {
                self.plan.execute(s, v, out)
            }
            (ExecState::Scratch(s), TunedBackend::RsrPlusPlusScalar) => {
                self.plan.execute_scalar(s, v, out)
            }
            (ExecState::Parallel(e), _) => {
                e.execute_ternary(self.plan.plus_flat(), self.plan.minus_flat(), v, out)
            }
            (ExecState::Batched(e), _) => e.execute_ternary(
                self.plan.plus_flat(),
                self.plan.minus_flat(),
                v,
                1,
                out,
            ),
            (ExecState::Tl { tl, lut }, TunedBackend::TlNeon) => {
                tl.execute_neon(v, out, lut)
            }
            (ExecState::Tl { tl, lut }, _) => tl.execute(v, out, lut),
            // `new` pairs state and backend; the combinations above are
            // exhaustive for what it constructs.
            (ExecState::Scratch(_), _) => unreachable!("scratch state with {:?}", self.backend),
        }
    }

    /// `out[b] = vs[b] · A` for a row-major `batch × rows` activation
    /// block — the continuous-batching hot path. The non-TL backends
    /// all dispatch to the **batched** flat kernel here, whatever their
    /// single-vector winner: per row that kernel performs the identical
    /// f32 addition sequence at every batch size, so a sequence's
    /// logits never change when batchmates join or retire (the
    /// invariant ragged batches rely on). The TL backends batch as a
    /// per-row loop over their own single-vector kernel — the same
    /// invariance, trivially, and the table stays the hot working set.
    /// The tuned winner keeps governing [`execute`](Self::execute),
    /// which strictly-sequential deployments (`max_slots == 1`) still
    /// serve.
    pub fn execute_batch(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        if let Some(probe) = self.probe.clone() {
            let t0 = Instant::now();
            let res = self.execute_batch_inner(vs, batch, out);
            probe.record(t0.elapsed().as_nanos() as u64);
            return res;
        }
        self.execute_batch_inner(vs, batch, out)
    }

    fn execute_batch_inner(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        if let ExecState::Tl { tl, lut } = &mut self.state {
            return if self.backend == TunedBackend::TlNeon {
                tl.execute_batch_neon(vs, batch, out, lut)
            } else {
                tl.execute_batch(vs, batch, out, lut)
            };
        }
        if !matches!(self.state, ExecState::Batched(_)) && self.batch_exec.is_none() {
            self.batch_exec = Some(self.plan.batch_exec(batch)?);
        }
        let exec = match &mut self.state {
            ExecState::Batched(e) => e,
            _ => self.batch_exec.as_mut().expect("created above"),
        };
        exec.ensure_batch(batch);
        exec.execute_ternary(self.plan.plus_flat(), self.plan.minus_flat(), vs, batch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::index::TernaryRsrIndex;
    use crate::kernels::standard::standard_mul_ternary;
    use crate::kernels::TernaryMatrix;
    use crate::util::rng::Rng;

    fn shared_plan(n: usize, m: usize, k: usize, seed: u64) -> (TernaryMatrix, Arc<SharedTernaryPlan>) {
        let mut rng = Rng::new(seed);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let plan =
            Arc::new(SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap());
        (a, plan)
    }

    #[test]
    fn every_backend_matches_the_standard_multiply() {
        let (a, plan) = shared_plan(96, 64, 4, 901);
        let mut rng = Rng::new(902);
        let v = rng.f32_vec(96, -1.0, 1.0);
        let expect = standard_mul_ternary(&v, &a);
        for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
            let mut exec = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap();
            assert_eq!(exec.backend(), backend);
            assert_eq!((exec.rows(), exec.cols()), (96, 64));
            let mut out = vec![0.0f32; 64];
            // Twice: scratch reuse must not change results.
            for _ in 0..2 {
                exec.execute(&v, &mut out).unwrap();
                for (g, e) in out.iter().zip(expect.iter()) {
                    assert!(
                        (g - e).abs() < 1e-3 * (1.0 + e.abs()),
                        "{}: {g} vs {e}",
                        backend.name()
                    );
                }
            }
        }
    }

    #[test]
    fn every_backend_is_bit_exact_on_integer_activations() {
        // With integer-valued f32 activations every intermediate sum is
        // exactly representable, so all backends — whatever their
        // accumulation order — must agree to the last bit. This is the
        // property that makes profile-driven backend swaps safe.
        let (a, plan) = shared_plan(80, 56, 3, 903);
        let mut rng = Rng::new(904);
        let v = rng.int_f32_vec(80, 3);
        let expect = standard_mul_ternary(&v, &a);
        for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
            let mut exec = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap();
            let mut out = vec![0.0f32; 56];
            exec.execute(&v, &mut out).unwrap();
            assert_eq!(out, expect, "{}", backend.name());
        }
    }

    #[test]
    fn rsrpp_backend_is_bit_identical_to_untuned_shared_execute() {
        let (_, plan) = shared_plan(64, 40, 4, 905);
        let mut rng = Rng::new(906);
        let v = rng.f32_vec(64, -1.0, 1.0);
        let mut scratch = plan.scratch();
        let mut expect = vec![0.0f32; 40];
        plan.execute(&mut scratch, &v, &mut expect).unwrap();
        let mut exec =
            ExecutablePlan::new(Arc::clone(&plan), TunedBackend::RsrPlusPlus).unwrap();
        let mut got = vec![0.0f32; 40];
        exec.execute(&v, &mut got).unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn execute_batch_is_bit_exact_vs_sequential_on_integer_activations() {
        // The batched-decode acceptance property: on integer-valued
        // activations (every intermediate sum exactly representable),
        // the batched path must agree to the last bit with the tuned
        // single-vector path — for EVERY selectable backend.
        let (a, plan) = shared_plan(88, 52, 4, 908);
        let mut rng = Rng::new(909);
        let batch = 4;
        let vs = rng.int_f32_vec(batch * 88, 3);
        for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
            let mut exec = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap();
            let mut batched = vec![0.0f32; batch * 52];
            exec.execute_batch(&vs, batch, &mut batched).unwrap();
            for bi in 0..batch {
                let row = &vs[bi * 88..(bi + 1) * 88];
                let mut seq = vec![0.0f32; 52];
                exec.execute(row, &mut seq).unwrap();
                assert_eq!(&batched[bi * 52..(bi + 1) * 52], &seq[..], "{}", backend.name());
                assert_eq!(seq, standard_mul_ternary(row, &a), "{}", backend.name());
            }
        }
    }

    #[test]
    fn execute_batch_rows_are_independent_of_batchmates() {
        // Float activations: row bi in a batch of 4 must be
        // bit-identical to the same row executed alone through the
        // batched path (ragged-batch invariance).
        let (_, plan) = shared_plan(64, 48, 4, 910);
        let mut rng = Rng::new(911);
        let vs = rng.f32_vec(4 * 64, -1.0, 1.0);
        let mut exec = ExecutablePlan::new(Arc::clone(&plan), TunedBackend::RsrPlusPlus).unwrap();
        let mut full = vec![0.0f32; 4 * 48];
        exec.execute_batch(&vs, 4, &mut full).unwrap();
        for bi in 0..4 {
            let mut solo = vec![0.0f32; 48];
            exec.execute_batch(&vs[bi * 64..(bi + 1) * 64], 1, &mut solo).unwrap();
            assert_eq!(&full[bi * 48..(bi + 1) * 48], &solo[..], "row {bi}");
        }
    }

    #[test]
    fn tl_executor_shares_the_plan_cached_table() {
        let (_, plan) = shared_plan(48, 32, 4, 912);
        let a = ExecutablePlan::new(Arc::clone(&plan), TunedBackend::Tl).unwrap();
        let b = ExecutablePlan::new(Arc::clone(&plan), TunedBackend::Tl).unwrap();
        match (&a.state, &b.state) {
            (ExecState::Tl { tl: ta, .. }, ExecState::Tl { tl: tb, .. }) => {
                assert!(Arc::ptr_eq(ta, tb), "both executors must share one code table");
            }
            _ => panic!("TL backend must build TL state"),
        }
    }

    #[test]
    fn unavailable_backends_fail_to_materialize_cleanly() {
        let (_, plan) = shared_plan(32, 16, 3, 913);
        for backend in TunedBackend::ALL.into_iter().filter(|b| !b.available()) {
            let err = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap_err();
            assert!(
                err.to_string().contains(backend.name()),
                "{}: {err}",
                backend.name()
            );
        }
    }

    #[test]
    fn shape_errors_surface_for_every_backend() {
        let (_, plan) = shared_plan(32, 16, 3, 907);
        for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
            let mut exec = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap();
            let mut out = vec![0.0f32; 16];
            assert!(exec.execute(&[0.0; 31], &mut out).is_err(), "{}", backend.name());
            let mut bad = vec![0.0f32; 15];
            assert!(exec.execute(&[0.0; 32], &mut bad).is_err(), "{}", backend.name());
        }
    }
}
