//! RSR++ — Algorithm 3 of the paper.
//!
//! Step 2 of RSR computes `u · Bin_[k]` densely in `O(k·2^k)`. RSR++
//! exploits the structure of `Bin_[k]`: the last output (LSB column) is
//! the sum of `u` at odd values; folding adjacent pairs
//! (`x'[m] = x[2m] + x[2m+1]`) shifts every value right by one bit, so
//! the same odd-sum on the folded vector yields the next column. Total
//! `Σᵢ O(2ⁱ) = O(2^k)`.
//!
//! With RSR++ as the step-2 subroutine the overall inference cost is
//! `O((n/k)(n + 2^k))`; with `k = log n` that is `O(n²/log n)`
//! (Theorem 4.4).
//!
//! The plans here execute on the contiguous [`FlatPlan`] arena (see
//! [`super::flat`]) — the index is copied into flat form once at plan
//! construction and the per-block `Vec`s are dropped.

use super::flat::{execute_rsrpp_flat, FlatPlan};
use super::index::{RsrIndex, TernaryRsrIndex};
use super::rsr::check_shapes;
use crate::error::Result;

/// Algorithm 3: `out = u · Bin_[width]` in `O(2^width)` using the
/// fold-and-odd-sum scheme. `scratch` must be at least `2^width` long;
/// `u` is consumed logically (scratch holds the folded copies).
///
/// Each level folds pairs **and** accumulates the odd-lane sum in one
/// pass, with a 4-wide unroll so the pair adds and the four odd
/// accumulators are independent instruction streams (the serial
/// `acc +=` chain of the textbook form is the bottleneck otherwise —
/// at this point the whole block's data is cache-resident and the
/// fold is pure ALU work).
#[inline]
pub fn block_product_fold(u: &[f32], width: usize, out: &mut [f32], scratch: &mut [f32]) {
    debug_assert_eq!(u.len(), 1 << width);
    debug_assert_eq!(out.len(), width);
    debug_assert!(scratch.len() >= 1 << width);

    let x = &mut scratch[..1 << width];
    x.copy_from_slice(u);
    let mut len = 1usize << width;
    // Columns are emitted LSB-first: col = width-1 down to 0.
    for col in (0..width).rev() {
        let half = len / 2;
        let mut odd0 = 0.0f32;
        let mut odd1 = 0.0f32;
        let mut odd2 = 0.0f32;
        let mut odd3 = 0.0f32;
        let mut m = 0usize;
        // SAFETY: all reads are at `< len` and all writes at `< half`,
        // both within `x[..1 << width]`; reads of iteration m touch
        // `[2m, 2m+8)` while earlier writes covered `[0, m+4)`, and
        // every read in an iteration happens before its writes, so the
        // in-place fold never reads a clobbered slot.
        unsafe {
            while m + 4 <= half {
                let a0 = *x.get_unchecked(2 * m);
                let b0 = *x.get_unchecked(2 * m + 1);
                let a1 = *x.get_unchecked(2 * m + 2);
                let b1 = *x.get_unchecked(2 * m + 3);
                let a2 = *x.get_unchecked(2 * m + 4);
                let b2 = *x.get_unchecked(2 * m + 5);
                let a3 = *x.get_unchecked(2 * m + 6);
                let b3 = *x.get_unchecked(2 * m + 7);
                *x.get_unchecked_mut(m) = a0 + b0;
                *x.get_unchecked_mut(m + 1) = a1 + b1;
                *x.get_unchecked_mut(m + 2) = a2 + b2;
                *x.get_unchecked_mut(m + 3) = a3 + b3;
                odd0 += b0;
                odd1 += b1;
                odd2 += b2;
                odd3 += b3;
                m += 4;
            }
            while m < half {
                let a = *x.get_unchecked(2 * m);
                let b = *x.get_unchecked(2 * m + 1);
                *x.get_unchecked_mut(m) = a + b;
                odd0 += b;
                m += 1;
            }
        }
        out[col] = (odd0 + odd1) + (odd2 + odd3);
        len = half;
    }
}

/// A reusable RSR++ plan: the flat arena + scratch (no allocation per
/// call).
#[derive(Debug, Clone)]
pub struct RsrPlusPlusPlan {
    plan: FlatPlan,
    u: Vec<f32>,
    fold: Vec<f32>,
}

impl RsrPlusPlusPlan {
    /// Build (and validate) a plan from a preprocessed index. The index
    /// is flattened into the contiguous arena form and dropped.
    pub fn new(index: RsrIndex) -> Result<Self> {
        let plan = FlatPlan::from_index(&index)?;
        let max_u = plan.max_u();
        Ok(Self { plan, u: vec![0.0; max_u], fold: vec![0.0; max_u] })
    }

    /// The underlying flat plan.
    pub fn flat(&self) -> &FlatPlan {
        &self.plan
    }

    /// Index bytes (Fig 5 accounting at the plan level).
    pub fn index_bytes(&self) -> usize {
        self.plan.bytes()
    }

    /// `out = v · B` using RSR with Algorithm 3 in step 2.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        check_shapes(self.plan.rows(), self.plan.cols(), v, out)?;
        execute_rsrpp_flat(&self.plan, v, out, &mut self.u, &mut self.fold);
        Ok(())
    }
}

/// One-shot convenience: preprocess + execute RSR++ on a binary matrix.
pub fn rsrpp_mul(v: &[f32], b: &super::binary::BinaryMatrix, k: usize) -> Vec<f32> {
    let mut plan =
        RsrPlusPlusPlan::new(RsrIndex::preprocess(b, k)).expect("fresh index is valid");
    let mut out = vec![0.0; b.cols()];
    plan.execute(v, &mut out).expect("shapes match");
    out
}

/// Ternary RSR++ plan (both Prop 2.1 halves).
#[derive(Debug, Clone)]
pub struct TernaryRsrPlusPlusPlan {
    plus: RsrPlusPlusPlan,
    minus: RsrPlusPlusPlan,
    tmp: Vec<f32>,
}

impl TernaryRsrPlusPlusPlan {
    /// Build from a preprocessed ternary index.
    pub fn new(index: TernaryRsrIndex) -> Result<Self> {
        let cols = index.plus.cols;
        Ok(Self {
            plus: RsrPlusPlusPlan::new(index.plus)?,
            minus: RsrPlusPlusPlan::new(index.minus)?,
            tmp: vec![0.0; cols],
        })
    }

    /// `out = v · A`.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        self.plus.execute(v, out)?;
        self.minus.execute(v, &mut self.tmp)?;
        for (o, t) in out.iter_mut().zip(self.tmp.iter()) {
            *o -= t;
        }
        Ok(())
    }

    /// Index bytes across both Prop 2.1 halves.
    pub fn index_bytes(&self) -> usize {
        self.plus.index_bytes() + self.minus.index_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::binary::BinaryMatrix;
    use super::super::rsr::{block_product_dense, rsr_mul};
    use super::super::standard::standard_mul_binary;
    use crate::util::rng::Rng;

    #[test]
    fn fold_matches_dense_block_product() {
        let mut rng = Rng::new(83);
        for width in 1..=10usize {
            let u = rng.f32_vec(1 << width, -1.0, 1.0);
            let mut dense = vec![0.0; width];
            let mut fold = vec![0.0; width];
            let mut scratch = vec![0.0; 1 << width];
            block_product_dense(&u, width, &mut dense);
            block_product_fold(&u, width, &mut fold, &mut scratch);
            for (a, b) in dense.iter().zip(fold.iter()) {
                assert!((a - b).abs() < 1e-3, "width {width}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fold_visualization_example() {
        // Fig 3 style check with a tiny concrete case, width=2:
        // u = [u0,u1,u2,u3]; out[1] (LSB col) = u1+u3; fold → [u0+u1,
        // u2+u3]; out[0] = u2+u3.
        let u = [1.0f32, 2.0, 3.0, 4.0];
        let mut out = [0.0f32; 2];
        let mut scratch = [0.0f32; 4];
        block_product_fold(&u, 2, &mut out, &mut scratch);
        assert_eq!(out, [7.0, 6.0]);
    }

    #[test]
    fn rsrpp_matches_standard_and_rsr() {
        let mut rng = Rng::new(89);
        for (n, m, k) in [(64, 64, 3), (100, 60, 4), (33, 7, 5), (128, 128, 8)] {
            let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
            let v = rng.f32_vec(n, -2.0, 2.0);
            let expect = standard_mul_binary(&v, &b);
            let got_pp = rsrpp_mul(&v, &b, k);
            let got_rsr = rsr_mul(&v, &b, k);
            for i in 0..m {
                assert!((got_pp[i] - expect[i]).abs() < 1e-3 * (1.0 + expect[i].abs()));
                assert!((got_pp[i] - got_rsr[i]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn ternary_plan_works() {
        use super::super::standard::standard_mul_ternary;
        use super::super::ternary::TernaryMatrix;
        let mut rng = Rng::new(97);
        let a = TernaryMatrix::random(50, 30, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(50, -1.0, 1.0);
        let mut plan =
            TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(&a, 3)).unwrap();
        let mut out = vec![0.0; 30];
        plan.execute(&v, &mut out).unwrap();
        let expect = standard_mul_ternary(&v, &a);
        for (g, e) in out.iter().zip(expect.iter()) {
            assert!((g - e).abs() < 1e-3);
        }
    }
}
