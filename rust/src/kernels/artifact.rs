//! Versioned, checksummed **plan artifacts** — the `.rsrz` format.
//!
//! The paper's central economics: trained binary/ternary weights never
//! change, so Algorithm 1 preprocessing can run **once, offline**, and
//! every inference process afterwards loads the finished block index
//! instead of recomputing it. A `.rsrz` file is that finished index —
//! an [`RsrIndex`] or [`TernaryRsrIndex`] plus the blocking metadata
//! (`k`, the per-tensor scale β, the layer name) — wrapped in a header
//! that makes offline deployment safe: a format version, and an
//! FNV-1a 64 checksum over the payload *and* the header metadata
//! (shape, k, scale, fingerprint, name) so bit rot or truncated copies
//! anywhere in the file are rejected at load instead of corrupting
//! inference.
//!
//! ## On-disk layout (version 2, all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "RSRZ"
//! 4       4     format version (u32) — currently 2 (v1 still readable)
//! 8       4     kind (u32): 1 = binary RsrIndex, 2 = ternary pair
//! 12      4     rows (u32)
//! 16      4     cols (u32)
//! 20      4     blocking parameter k (u32)
//! 24      4     scale β (f32)
//! 28      4     elem width (u32): bytes per index entry, 2 or 4
//! 32      8     weights fingerprint (u64, 0 = unbound) — FNV-1a of the
//!               source matrix ([`ternary_fingerprint`]); binds a plan
//!               to the exact weights it was compiled from
//! 40      8     payload length (u64)
//! 48      8     FNV-1a 64 checksum (u64) over the payload followed by
//!               every other header field (version, kind, shape, k,
//!               scale, elem width, fingerprint, length, name) — a
//!               flipped bit in the scale is as fatal as one in a
//!               segmentation entry
//! 56      4     name length (u32), then that many UTF-8 bytes
//! …             payload
//! ```
//!
//! **Version 2 payload** is the [`FlatPlan`] arena, serialized
//! directly: the whole `sigma_all` arena (every block's `σ`,
//! concatenated), then the whole `seg_all` arena (every block's `L`,
//! concatenated). Loading is therefore a checksum pass, **two bulk
//! widening copies**, and one structural validation — the decoded plan
//! *is* the execution-time layout, with no per-block `Vec` assembly.
//! **Version 1** (still read, never written) interleaved the two per
//! block: `σ₀ L₀ σ₁ L₁ …`. Both versions carry exactly the same
//! entries, so `payload_bytes` is version-independent.
//!
//! Block geometry (`col_start`, `width`) is *derived* from `(cols, k)`
//! — not stored — and entries are written at the narrowest width that
//! fits (`u16` whenever `rows < 2^16`), which is what gets the
//! artifact to ≲ dense-f32 / 4 at `n ≥ 1024` instead of the ~0.4× a
//! naive u32 dump achieves. A ternary artifact stores the `B⁽¹⁾`
//! (plus) payload followed by `B⁽²⁾` (minus), same geometry.
//!
//! Decoding re-validates every structural invariant
//! ([`FlatPlan::from_arena`]) after the checksum passes, so a loaded
//! plan is exactly as trustworthy as a freshly preprocessed one — the
//! bounds-check-free hot path relies on this.

use std::io::{Read, Write};
use std::path::Path;

use super::blocking::column_blocks;
use super::flat::{FlatPlan, TernaryFlatPlan};
use super::index::{RsrIndex, TernaryRsrIndex};
use super::ternary::TernaryMatrix;
use crate::error::{Error, Result};

/// The `.rsrz` magic bytes.
pub const RSRZ_MAGIC: &[u8; 4] = b"RSRZ";

/// The format version this build writes (v2: arena-ordered payload).
pub const RSRZ_VERSION: u32 = 2;

/// The oldest format version this build still reads (v1: per-block
/// interleaved payload).
pub const RSRZ_MIN_VERSION: u32 = 1;

/// Reject implausible header dimensions before any allocation. The
/// paper's largest evaluation size is `n = 2^16`; 2^20 leaves headroom
/// while keeping every size computation far from usize overflow.
const MAX_DIM: usize = 1 << 20;

/// Largest payload a header may declare (a ternary `n = 2^16`, `k = 16`
/// artifact is ≈ 4.3 GB; 16 GiB bounds what a corrupt header can ask
/// the allocator for).
const MAX_PAYLOAD: usize = 1 << 34;

/// Longest accepted artifact name.
const MAX_NAME: usize = 4096;

/// What kind of index an artifact carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// A single binary-matrix index ([`RsrIndex`]).
    Binary,
    /// A ternary pair ([`TernaryRsrIndex`]: both Prop 2.1 halves).
    Ternary,
}

impl ArtifactKind {
    fn code(self) -> u32 {
        match self {
            ArtifactKind::Binary => 1,
            ArtifactKind::Ternary => 2,
        }
    }

    fn from_code(c: u32) -> Result<Self> {
        match c {
            1 => Ok(ArtifactKind::Binary),
            2 => Ok(ArtifactKind::Ternary),
            other => Err(Error::Artifact(format!("unknown artifact kind {other}"))),
        }
    }

    /// Human-readable kind name (used by `rsr inspect`).
    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::Binary => "binary",
            ArtifactKind::Ternary => "ternary",
        }
    }
}

/// Everything the `.rsrz` header records about an artifact — readable
/// without decoding the payload (see [`PlanArtifact::peek`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    /// Layer name (e.g. `layer0.wq`, `lm_head`).
    pub name: String,
    /// Format version the file was written with.
    pub version: u32,
    /// Binary or ternary.
    pub kind: ArtifactKind,
    /// Rows of the indexed matrix (`n`, the activation length).
    pub rows: usize,
    /// Columns of the indexed matrix (`m`, the output length).
    pub cols: usize,
    /// Blocking parameter the index was preprocessed with.
    pub k: usize,
    /// Per-tensor scale β applied after the multiply.
    pub scale: f32,
    /// Bytes per index entry in the payload (2 or 4).
    pub elem_width: usize,
    /// FNV-1a fingerprint of the source weight matrix
    /// ([`ternary_fingerprint`]); `0` means unbound. Lets serve-time
    /// detect plans packed from *different* weights that happen to
    /// share the architecture's shapes.
    pub weights_fp: u64,
    /// Payload size on disk — the serve-time index footprint.
    pub payload_bytes: usize,
}

impl ArtifactMeta {
    /// Bytes a dense f32 copy of the same matrix would occupy — the
    /// Fig 5 baseline `rsr inspect` compares against.
    pub fn dense_f32_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Bytes of the most compact raw form (bit-packed binary / 2-bit
    /// packed ternary) — the honest non-index baseline.
    pub fn packed_bytes(&self) -> usize {
        match self.kind {
            ArtifactKind::Binary => (self.rows * self.cols).div_ceil(8),
            ArtifactKind::Ternary => (self.rows * self.cols).div_ceil(4),
        }
    }

    /// `payload_bytes / dense_f32_bytes` — the compression ratio
    /// reported by `rsr inspect`.
    pub fn ratio_vs_dense(&self) -> f64 {
        self.payload_bytes as f64 / self.dense_f32_bytes() as f64
    }
}

/// The decoded plan an artifact carries — already in the contiguous
/// [`FlatPlan`] execution form (the v2 payload *is* the arena).
#[derive(Debug, Clone, PartialEq)]
pub enum ArtifactPayload {
    /// A binary-matrix plan.
    Binary(FlatPlan),
    /// A ternary plan pair.
    Ternary(TernaryFlatPlan),
}

/// A plan artifact: header metadata + decoded index, ready to be
/// written to or read from a `.rsrz` stream.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanArtifact {
    /// Header metadata.
    pub meta: ArtifactMeta,
    /// The index itself.
    pub payload: ArtifactPayload,
}

impl PlanArtifact {
    /// Wrap a validated binary index for serialization (flattened into
    /// the arena form the payload serializes directly).
    pub fn binary(name: impl Into<String>, index: RsrIndex, scale: f32) -> Result<Self> {
        let plan = FlatPlan::from_index(&index)?;
        Self::binary_flat(name, plan, scale)
    }

    /// Wrap an already-flat binary plan for serialization.
    pub fn binary_flat(
        name: impl Into<String>,
        plan: FlatPlan,
        scale: f32,
    ) -> Result<Self> {
        check_writable(plan.rows(), plan.cols(), plan.k())?;
        let elem_width = elem_width_for(plan.rows());
        let meta = ArtifactMeta {
            name: name.into(),
            version: RSRZ_VERSION,
            kind: ArtifactKind::Binary,
            rows: plan.rows(),
            cols: plan.cols(),
            k: plan.k(),
            scale,
            elem_width,
            weights_fp: 0,
            payload_bytes: expected_payload_bytes(
                plan.rows(),
                plan.cols(),
                plan.k(),
                elem_width,
                ArtifactKind::Binary,
            ),
        };
        check_name(&meta.name)?;
        check_payload_cap(meta.payload_bytes)?;
        Ok(Self { meta, payload: ArtifactPayload::Binary(plan) })
    }

    /// Wrap a validated ternary index pair for serialization.
    pub fn ternary(
        name: impl Into<String>,
        index: TernaryRsrIndex,
        scale: f32,
    ) -> Result<Self> {
        let plan = TernaryFlatPlan::from_index(&index)?;
        Self::ternary_flat(name, plan, scale)
    }

    /// Wrap an already-flat ternary plan pair for serialization.
    pub fn ternary_flat(
        name: impl Into<String>,
        plan: TernaryFlatPlan,
        scale: f32,
    ) -> Result<Self> {
        plan.check_geometry()?;
        let p = &plan.plus;
        check_writable(p.rows(), p.cols(), p.k())?;
        let elem_width = elem_width_for(p.rows());
        let meta = ArtifactMeta {
            name: name.into(),
            version: RSRZ_VERSION,
            kind: ArtifactKind::Ternary,
            rows: p.rows(),
            cols: p.cols(),
            k: p.k(),
            scale,
            elem_width,
            weights_fp: 0,
            payload_bytes: expected_payload_bytes(
                p.rows(),
                p.cols(),
                p.k(),
                elem_width,
                ArtifactKind::Ternary,
            ),
        };
        check_name(&meta.name)?;
        check_payload_cap(meta.payload_bytes)?;
        Ok(Self { meta, payload: ArtifactPayload::Ternary(plan) })
    }

    /// Bind this artifact to the weights it was compiled from (see
    /// [`ternary_fingerprint`]); serve-time loaders reject the plan if
    /// the model's matrix no longer matches.
    pub fn with_weights_fingerprint(mut self, fp: u64) -> Self {
        self.meta.weights_fp = fp;
        self
    }

    /// In-memory bytes of the decoded flat plan (arenas + descriptors)
    /// — what a process actually holds after loading; contrast with
    /// [`ArtifactMeta::payload_bytes`], the on-disk footprint.
    pub fn in_memory_bytes(&self) -> usize {
        match &self.payload {
            ArtifactPayload::Binary(p) => p.bytes(),
            ArtifactPayload::Ternary(t) => t.bytes(),
        }
    }

    /// Serialize to a `.rsrz` stream. Always writes the current format
    /// version (a v1-loaded artifact is upgraded on re-save).
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let mut m = self.meta.clone();
        m.version = RSRZ_VERSION;
        let mut payload = Vec::with_capacity(m.payload_bytes);
        match &self.payload {
            ArtifactPayload::Binary(p) => encode_flat(p, m.elem_width, &mut payload),
            ArtifactPayload::Ternary(t) => {
                encode_flat(&t.plus, m.elem_width, &mut payload);
                encode_flat(&t.minus, m.elem_width, &mut payload);
            }
        }
        debug_assert_eq!(payload.len(), m.payload_bytes);
        w.write_all(RSRZ_MAGIC)?;
        for v in [m.version, m.kind.code(), m.rows as u32, m.cols as u32, m.k as u32] {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&m.scale.to_le_bytes())?;
        w.write_all(&(m.elem_width as u32).to_le_bytes())?;
        w.write_all(&m.weights_fp.to_le_bytes())?;
        w.write_all(&(payload.len() as u64).to_le_bytes())?;
        w.write_all(&artifact_checksum(&m, &payload).to_le_bytes())?;
        w.write_all(&(m.name.len() as u32).to_le_bytes())?;
        w.write_all(m.name.as_bytes())?;
        w.write_all(&payload)?;
        Ok(())
    }

    /// Deserialize from a `.rsrz` stream: header checks → checksum →
    /// decode → full structural validation.
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let (meta, checksum) = read_header(r)?;
        // try_reserve instead of vec![0; n]: a corrupt-but-plausible
        // header must surface as Err, never as an allocator abort.
        let mut payload = Vec::new();
        payload.try_reserve_exact(meta.payload_bytes).map_err(|_| {
            Error::Artifact(format!(
                "cannot allocate {} payload bytes",
                meta.payload_bytes
            ))
        })?;
        payload.resize(meta.payload_bytes, 0);
        r.read_exact(&mut payload)?;
        if artifact_checksum(&meta, &payload) != checksum {
            return Err(Error::Artifact(
                "checksum mismatch (corrupt artifact header or payload)".into(),
            ));
        }
        let mut off = 0;
        let decoded = match meta.kind {
            ArtifactKind::Binary => {
                ArtifactPayload::Binary(decode_flat(&meta, &payload, &mut off)?)
            }
            ArtifactKind::Ternary => {
                let plus = decode_flat(&meta, &payload, &mut off)?;
                let minus = decode_flat(&meta, &payload, &mut off)?;
                let t = TernaryFlatPlan { plus, minus };
                t.check_geometry()?;
                ArtifactPayload::Ternary(t)
            }
        };
        debug_assert_eq!(off, payload.len());
        Ok(Self { meta, payload: decoded })
    }

    /// Read only the header of a `.rsrz` file — artifact stats without
    /// paying for payload decode (what `rsr inspect` uses).
    pub fn peek(path: impl AsRef<Path>) -> Result<ArtifactMeta> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let (meta, _checksum) = read_header(&mut f)?;
        Ok(meta)
    }

    /// Write to a file crash-safely (tmp + fsync + atomic rename): a
    /// kill mid-`rsr pack` leaves the old artifact, the complete new
    /// one, or a stray `*.tmp` that loaders refuse — never a
    /// loadable-but-corrupt file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        crate::util::atomicfile::write_atomic(path, |w| self.write_to(w))
    }

    /// Read + validate from a file. In-flight `*.tmp` names are
    /// refused outright — only a finished, renamed artifact is
    /// trustworthy, whatever its bytes parse as.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if crate::util::atomicfile::is_tmp(path) {
            return Err(Error::Artifact(format!(
                "{} is an in-flight temporary from an interrupted write, \
                 not a finished artifact",
                path.display()
            )));
        }
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Self::read_from(&mut f)
    }
}

/// Narrowest entry width that can hold every `σ`/`L` value (both are
/// bounded by `rows`).
fn elem_width_for(rows: usize) -> usize {
    if rows < 1 << 16 {
        2
    } else {
        4
    }
}

fn check_name(name: &str) -> Result<()> {
    if name.len() > MAX_NAME {
        return Err(Error::Artifact(format!("artifact name too long ({})", name.len())));
    }
    Ok(())
}

/// Writers must refuse anything the reader's bounds would reject —
/// never sink preprocessing cost into a file this build cannot load.
/// (Dimensions and k here; the payload cap is checked once the size is
/// known, in [`check_payload_cap`].)
fn check_writable(rows: usize, cols: usize, k: usize) -> Result<()> {
    if k == 0 || k > 16 {
        return Err(Error::Artifact(format!(
            "blocking parameter k={k} is outside the writable range 1..=16"
        )));
    }
    if rows == 0 || cols == 0 || rows > MAX_DIM || cols > MAX_DIM {
        return Err(Error::Artifact(format!(
            "dimensions {rows}x{cols} exceed the .rsrz limit of {MAX_DIM}"
        )));
    }
    Ok(())
}

/// The same payload cap the reader enforces, applied at write time.
fn check_payload_cap(payload_bytes: usize) -> Result<()> {
    if payload_bytes > MAX_PAYLOAD {
        return Err(Error::Artifact(format!(
            "payload of {payload_bytes} bytes exceeds the {MAX_PAYLOAD}-byte cap \
             (choose a larger k: tiny k makes the index larger than the matrix)"
        )));
    }
    Ok(())
}

/// Exact payload size implied by the header geometry.
fn expected_payload_bytes(
    rows: usize,
    cols: usize,
    k: usize,
    elem_width: usize,
    kind: ArtifactKind,
) -> usize {
    let entries: usize = column_blocks(cols, k)
        .iter()
        .map(|cb| rows + (1usize << cb.width) + 1)
        .sum();
    let per_index = entries * elem_width;
    match kind {
        ArtifactKind::Binary => per_index,
        ArtifactKind::Ternary => per_index * 2,
    }
}

/// v2 encoding: the flat arena, serialized directly — all of
/// `sigma_all`, then all of `seg_all`.
fn encode_flat(plan: &FlatPlan, elem_width: usize, out: &mut Vec<u8>) {
    for &v in plan.sigma_all().iter().chain(plan.seg_all().iter()) {
        if elem_width == 2 {
            out.extend_from_slice(&(v as u16).to_le_bytes());
        } else {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Bulk widening copy of `n` entries from the payload into `out`.
fn decode_entries_into(
    payload: &[u8],
    off: &mut usize,
    n: usize,
    elem_width: usize,
    out: &mut Vec<u32>,
) -> Result<()> {
    let need = n * elem_width;
    if *off + need > payload.len() {
        return Err(Error::Artifact("payload truncated".into()));
    }
    let slice = &payload[*off..*off + need];
    *off += need;
    out.reserve(n);
    if elem_width == 2 {
        for c in slice.chunks_exact(2) {
            out.push(u16::from_le_bytes([c[0], c[1]]) as u32);
        }
    } else {
        for c in slice.chunks_exact(4) {
            out.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
    }
    Ok(())
}

/// Decode one index's payload into a validated [`FlatPlan`].
///
/// v2 is the fast path: the payload *is* the arena, so this is two
/// bulk copies plus [`FlatPlan::from_arena`] validation. v1 assembles
/// the same arenas from the per-block interleaved ordering.
fn decode_flat(meta: &ArtifactMeta, payload: &[u8], off: &mut usize) -> Result<FlatPlan> {
    let geom = column_blocks(meta.cols, meta.k);
    let sigma_entries = geom.len() * meta.rows;
    let seg_entries: usize = geom.iter().map(|cb| (1usize << cb.width) + 1).sum();
    let mut sigma_all = Vec::new();
    let mut seg_all = Vec::new();
    if meta.version == 1 {
        for cb in &geom {
            decode_entries_into(payload, off, meta.rows, meta.elem_width, &mut sigma_all)?;
            decode_entries_into(
                payload,
                off,
                (1usize << cb.width) + 1,
                meta.elem_width,
                &mut seg_all,
            )?;
        }
    } else {
        decode_entries_into(payload, off, sigma_entries, meta.elem_width, &mut sigma_all)?;
        decode_entries_into(payload, off, seg_entries, meta.elem_width, &mut seg_all)?;
    }
    FlatPlan::from_arena(meta.rows, meta.cols, meta.k, sigma_all, seg_all)
}

fn read_header(r: &mut impl Read) -> Result<(ArtifactMeta, u64)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != RSRZ_MAGIC {
        return Err(Error::Artifact("bad magic (not a .rsrz plan artifact)".into()));
    }
    let version = read_u32(r)?;
    if !(RSRZ_MIN_VERSION..=RSRZ_VERSION).contains(&version) {
        return Err(Error::Artifact(format!(
            "unsupported .rsrz version {version} (this build reads versions \
             {RSRZ_MIN_VERSION}..={RSRZ_VERSION})"
        )));
    }
    let kind = ArtifactKind::from_code(read_u32(r)?)?;
    let rows = read_u32(r)? as usize;
    let cols = read_u32(r)? as usize;
    let k = read_u32(r)? as usize;
    let scale = f32::from_le_bytes(read_arr(r)?);
    let elem_width = read_u32(r)? as usize;
    let weights_fp = u64::from_le_bytes(read_arr(r)?);
    let payload_len = u64::from_le_bytes(read_arr(r)?);
    let checksum = u64::from_le_bytes(read_arr(r)?);
    let name_len = read_u32(r)? as usize;

    if rows == 0 || cols == 0 || rows > MAX_DIM || cols > MAX_DIM {
        return Err(Error::Artifact(format!("implausible dimensions {rows}x{cols}")));
    }
    if k == 0 || k > 16 {
        return Err(Error::Artifact(format!("blocking parameter k={k} out of range")));
    }
    if elem_width != 2 && elem_width != 4 {
        return Err(Error::Artifact(format!("bad element width {elem_width}")));
    }
    if elem_width == 2 && rows >= 1 << 16 {
        return Err(Error::Artifact(
            "element width 2 cannot encode indices for rows >= 65536".into(),
        ));
    }
    if name_len > MAX_NAME {
        return Err(Error::Artifact(format!("artifact name too long ({name_len})")));
    }
    let mut name_bytes = vec![0u8; name_len];
    r.read_exact(&mut name_bytes)?;
    let name =
        String::from_utf8(name_bytes).map_err(|e| Error::Artifact(e.to_string()))?;

    // With rows/cols ≤ MAX_DIM = 2^20 and k ≥ 1 this sum stays well
    // below 2^63, so the usize arithmetic cannot overflow (64-bit).
    let expected = expected_payload_bytes(rows, cols, k, elem_width, kind);
    if expected > MAX_PAYLOAD {
        return Err(Error::Artifact(format!(
            "payload of {expected} bytes exceeds the {MAX_PAYLOAD}-byte cap"
        )));
    }
    if payload_len != expected as u64 {
        return Err(Error::Artifact(format!(
            "payload length {payload_len} does not match geometry (expected {expected})"
        )));
    }
    let meta = ArtifactMeta {
        name,
        version,
        kind,
        rows,
        cols,
        k,
        scale,
        elem_width,
        weights_fp,
        payload_bytes: expected,
    };
    Ok((meta, checksum))
}

/// Fingerprint of a ternary weight matrix: FNV-1a 64 over the raw
/// `{−1,0,1}` entries plus the shape. Stored in `.rsrz` headers (and
/// computed by serve-time loaders) so a plans directory packed from
/// *other* weights with the same shapes is rejected instead of silently
/// producing wrong logits. Never returns `0` — that value is reserved
/// to mean "unbound".
pub fn ternary_fingerprint(m: &TernaryMatrix) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut step = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for &v in m.data() {
        step(v as u8);
    }
    for d in [m.rows() as u64, m.cols() as u64] {
        for b in d.to_le_bytes() {
            step(b);
        }
    }
    if h == 0 {
        1
    } else {
        h
    }
}

/// Shared with the `.rsrt` reader ([`crate::tune::profile`]), like the
/// FNV helpers below.
pub(crate) fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_arr(r)?))
}

pub(crate) fn read_arr<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut b = [0u8; N];
    r.read_exact(&mut b)?;
    Ok(b)
}

/// FNV-1a 64-bit over a byte slice — small, dependency-free, and
/// plenty for detecting bit rot / truncation (not a cryptographic MAC).
/// Shared with the `.rsrt` tuning-profile format
/// ([`crate::tune::profile`]).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_continue(0xcbf2_9ce4_8422_2325, bytes)
}

pub(crate) fn fnv1a64_continue(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The stored checksum: FNV-1a over the payload, continued over every
/// other header field. Computed from *parsed* values on read, so any
/// header corruption that survives the structural checks (a flipped
/// scale bit, a zeroed fingerprint) still fails the comparison.
fn artifact_checksum(meta: &ArtifactMeta, payload: &[u8]) -> u64 {
    let mut h = fnv1a64(payload);
    for v in [
        meta.version,
        meta.kind.code(),
        meta.rows as u32,
        meta.cols as u32,
        meta.k as u32,
        meta.elem_width as u32,
    ] {
        h = fnv1a64_continue(h, &v.to_le_bytes());
    }
    h = fnv1a64_continue(h, &meta.scale.to_le_bytes());
    h = fnv1a64_continue(h, &meta.weights_fp.to_le_bytes());
    h = fnv1a64_continue(h, &(payload.len() as u64).to_le_bytes());
    fnv1a64_continue(h, meta.name.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{BinaryMatrix, TernaryMatrix};
    use crate::util::rng::Rng;

    #[test]
    fn binary_round_trip() {
        let mut rng = Rng::new(301);
        let b = BinaryMatrix::random(97, 50, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 5);
        let flat = FlatPlan::from_index(&idx).unwrap();
        let art = PlanArtifact::binary("layer0.wq", idx, 0.25).unwrap();
        let mut buf = Vec::new();
        art.write_to(&mut buf).unwrap();
        let back = PlanArtifact::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.meta.name, "layer0.wq");
        assert_eq!(back.meta.version, RSRZ_VERSION);
        assert_eq!(back.meta.k, 5);
        assert_eq!(back.meta.scale, 0.25);
        assert_eq!(back.meta.elem_width, 2);
        match back.payload {
            ArtifactPayload::Binary(ref got) => assert_eq!(got, &flat),
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn ternary_round_trip() {
        let mut rng = Rng::new(307);
        let a = TernaryMatrix::random(64, 40, 1.0 / 3.0, &mut rng);
        let idx = TernaryRsrIndex::preprocess(&a, 4);
        let flat = TernaryFlatPlan::from_index(&idx).unwrap();
        let art = PlanArtifact::ternary("lm_head", idx, 1.5).unwrap();
        let mut buf = Vec::new();
        art.write_to(&mut buf).unwrap();
        let back = PlanArtifact::read_from(&mut buf.as_slice()).unwrap();
        match back.payload {
            ArtifactPayload::Ternary(ref got) => assert_eq!(got, &flat),
            _ => panic!("wrong kind"),
        }
        assert_eq!(back.meta.kind.name(), "ternary");
    }

    /// Hand-assemble a version-1 stream (per-block interleaved payload)
    /// for `idx` and check this build still reads it — and that the
    /// decoded plan is identical to the v2 decode of the same index.
    #[test]
    fn v1_artifacts_still_load() {
        let mut rng = Rng::new(331);
        let b = BinaryMatrix::random(45, 26, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 3);
        let flat = FlatPlan::from_index(&idx).unwrap();
        let elem_width = elem_width_for(idx.rows);

        // v1 payload: σ then L per block, in block order.
        let mut payload = Vec::new();
        for blk in &idx.blocks {
            for &v in blk.sigma.iter().chain(blk.seg.iter()) {
                payload.extend_from_slice(&(v as u16).to_le_bytes());
            }
        }
        let meta = ArtifactMeta {
            name: "legacy".into(),
            version: 1,
            kind: ArtifactKind::Binary,
            rows: idx.rows,
            cols: idx.cols,
            k: idx.k,
            scale: 0.75,
            elem_width,
            weights_fp: 0,
            payload_bytes: payload.len(),
        };
        let mut buf = Vec::new();
        buf.extend_from_slice(RSRZ_MAGIC);
        for v in [1u32, meta.kind.code(), meta.rows as u32, meta.cols as u32, meta.k as u32]
        {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&meta.scale.to_le_bytes());
        buf.extend_from_slice(&(meta.elem_width as u32).to_le_bytes());
        buf.extend_from_slice(&meta.weights_fp.to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        buf.extend_from_slice(&artifact_checksum(&meta, &payload).to_le_bytes());
        buf.extend_from_slice(&(meta.name.len() as u32).to_le_bytes());
        buf.extend_from_slice(meta.name.as_bytes());
        buf.extend_from_slice(&payload);

        let back = PlanArtifact::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.meta.version, 1);
        assert_eq!(back.meta.scale, 0.75);
        match back.payload {
            ArtifactPayload::Binary(ref got) => assert_eq!(got, &flat),
            _ => panic!("wrong kind"),
        }

        // Re-saving a v1 artifact upgrades it to the current version.
        let mut upgraded = Vec::new();
        back.write_to(&mut upgraded).unwrap();
        let again = PlanArtifact::read_from(&mut upgraded.as_slice()).unwrap();
        assert_eq!(again.meta.version, RSRZ_VERSION);
        match again.payload {
            ArtifactPayload::Binary(ref got) => assert_eq!(got, &flat),
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn version_mismatch_is_rejected_with_clear_error() {
        let mut rng = Rng::new(311);
        let b = BinaryMatrix::random(16, 8, 0.5, &mut rng);
        let art = PlanArtifact::binary("x", RsrIndex::preprocess(&b, 3), 1.0).unwrap();
        let mut buf = Vec::new();
        art.write_to(&mut buf).unwrap();
        // Version field lives at offset 4.
        buf[4] = 99;
        let err = PlanArtifact::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn corruption_is_rejected() {
        let mut rng = Rng::new(313);
        let b = BinaryMatrix::random(32, 20, 0.5, &mut rng);
        let art = PlanArtifact::binary("x", RsrIndex::preprocess(&b, 3), 1.0).unwrap();
        let mut buf = Vec::new();
        art.write_to(&mut buf).unwrap();
        // Bad magic.
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(PlanArtifact::read_from(&mut bad.as_slice()).is_err());
        // Payload bit flip → checksum mismatch.
        let mut bad = buf.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        let err = PlanArtifact::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Truncation.
        let bad = &buf[..buf.len() - 5];
        assert!(PlanArtifact::read_from(&mut &bad[..]).is_err());
        // Header corruption that passes structural checks — a flipped
        // scale bit (offset 24) — must still fail the checksum.
        let mut bad = buf.clone();
        bad[24] ^= 0x01;
        let err = PlanArtifact::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Same for the weights fingerprint (offset 32).
        let mut bad = buf;
        bad[32] ^= 0x01;
        let err = PlanArtifact::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn wide_matrices_use_u32_entries() {
        // elem_width must widen when rows >= 2^16 (sigma can't fit u16).
        assert_eq!(elem_width_for(65535), 2);
        assert_eq!(elem_width_for(65536), 4);
    }

    #[test]
    fn meta_ratios_are_consistent() {
        let mut rng = Rng::new(317);
        let a = TernaryMatrix::random(128, 128, 1.0 / 3.0, &mut rng);
        let art =
            PlanArtifact::ternary("t", TernaryRsrIndex::preprocess(&a, 4), 1.0).unwrap();
        let m = &art.meta;
        assert_eq!(m.dense_f32_bytes(), 128 * 128 * 4);
        assert_eq!(m.packed_bytes(), 128 * 128 / 4);
        let mut buf = Vec::new();
        art.write_to(&mut buf).unwrap();
        // Header (60 bytes fixed) + name + payload; payload dominates.
        assert_eq!(buf.len(), 60 + 1 + m.payload_bytes);
        assert!((m.ratio_vs_dense() - m.payload_bytes as f64 / (128.0 * 128.0 * 4.0)).abs() < 1e-12);
    }

    #[test]
    fn fnv_vector() {
        // Known FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn weights_fingerprint_round_trips_and_discriminates() {
        let mut rng = Rng::new(331);
        let a = TernaryMatrix::random(32, 24, 1.0 / 3.0, &mut rng);
        let b = TernaryMatrix::random(32, 24, 1.0 / 3.0, &mut rng);
        let fa = ternary_fingerprint(&a);
        assert_ne!(fa, 0, "0 is reserved for unbound");
        assert_eq!(fa, ternary_fingerprint(&a), "deterministic");
        assert_ne!(fa, ternary_fingerprint(&b), "different weights, different fp");

        let art = PlanArtifact::ternary("t", TernaryRsrIndex::preprocess(&a, 3), 1.0)
            .unwrap()
            .with_weights_fingerprint(fa);
        let mut buf = Vec::new();
        art.write_to(&mut buf).unwrap();
        let back = PlanArtifact::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.meta.weights_fp, fa);
    }
}
