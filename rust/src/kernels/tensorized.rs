//! The tensorized formulation (paper Appendix C.1.II / E.2–E.3).
//!
//! For each block `j` the paper defines a one-hot segmentation matrix
//! `M_j ∈ {0,1}^{n×2^k}` with `M_j[r, key_j(r)] = 1`, so the segmented
//! sum becomes the matmul `u = v·M_j` and the whole inference is one
//! (batched) tensor contraction — the formulation that maps onto GPU
//! matmul units and, in our TPU adaptation, onto the MXU (see
//! DESIGN.md §Hardware-Adaptation; the Pallas kernel in
//! `python/compile/kernels/rsr_pallas.py` is this same formulation).
//!
//! On CPU we store `M_j` compactly as the key-per-row vector (its
//! one-hot row index), so `v·M_j` is a *scatter-add*:
//! `u[key[r]] += v[r]` — note this needs **no permutation at all**,
//! which is exactly why the GPU path skips `σ`. The follow-up product
//! with `Bin_[k]` is shared with RSR/RSR++.
//!
//! This is also an ablation point: scatter-by-key (this module) versus
//! gather-by-permutation (`rsr.rs`) — same math, different memory
//! access pattern; see `benches/ablations.rs`.

use super::binary::BinaryMatrix;
use super::blocking::column_blocks;
use super::rsrpp::block_product_fold;
use super::ternary::TernaryMatrix;
use crate::error::{Error, Result};
use crate::util::threadpool::parallel_for;

/// Compact tensorized index: per block, the per-row segment key
/// (the one-hot column index of `M_j`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorizedIndex {
    /// Rows (`n`).
    pub rows: usize,
    /// Columns (`m`).
    pub cols: usize,
    /// Blocking parameter `k`.
    pub k: usize,
    /// Block geometry: `(col_start, width)` per block.
    pub blocks: Vec<(u32, u32)>,
    /// `keys[block][r]` = k-bit key of row `r` in that block.
    pub keys: Vec<Vec<u16>>,
}

impl TensorizedIndex {
    /// Build from a binary matrix (the M-matrix construction of App E.2).
    pub fn preprocess(b: &BinaryMatrix, k: usize) -> Self {
        let geom = column_blocks(b.cols(), k);
        let mut blocks = Vec::with_capacity(geom.len());
        let mut keys = Vec::with_capacity(geom.len());
        for cb in &geom {
            blocks.push((cb.col_start as u32, cb.width as u32));
            let mut ks = Vec::with_capacity(b.rows());
            for r in 0..b.rows() {
                ks.push(b.row_key(r, cb.col_start, cb.width) as u16);
            }
            keys.push(ks);
        }
        Self { rows: b.rows(), cols: b.cols(), k, blocks, keys }
    }

    /// Index bytes (keys are u16).
    pub fn bytes(&self) -> usize {
        self.keys.iter().map(|k| k.len() * 2).sum::<usize>() + self.blocks.len() * 8 + 16
    }

    /// `out = v · B` via scatter-add segmented sums.
    pub fn execute(&self, v: &[f32], out: &mut [f32]) -> Result<()> {
        self.check(v, out)?;
        let max_u = self.blocks.iter().map(|&(_, w)| 1usize << w).max().unwrap_or(0);
        let mut u = vec![0.0f32; max_u];
        let mut fold = vec![0.0f32; max_u];
        for (bi, &(col, w)) in self.blocks.iter().enumerate() {
            let w = w as usize;
            let u = &mut u[..1 << w];
            u.fill(0.0);
            for (r, &key) in self.keys[bi].iter().enumerate() {
                u[key as usize] += v[r];
            }
            let col = col as usize;
            block_product_fold(u, w, &mut out[col..col + w], &mut fold);
        }
        Ok(())
    }

    /// Batched execution across blocks on `threads` workers — the CPU
    /// stand-in for the paper's single 3D-tensor GPU launch.
    pub fn execute_parallel(&self, v: &[f32], out: &mut [f32], threads: usize) -> Result<()> {
        self.check(v, out)?;
        // Disjoint output slices per block.
        let mut slices: Vec<&mut [f32]> = Vec::with_capacity(self.blocks.len());
        let mut rest = out;
        for &(_, w) in &self.blocks {
            let (head, tail) = rest.split_at_mut(w as usize);
            slices.push(head);
            rest = tail;
        }
        let slices: Vec<std::sync::Mutex<Option<&mut [f32]>>> =
            slices.into_iter().map(|s| std::sync::Mutex::new(Some(s))).collect();
        parallel_for(threads, self.blocks.len(), |bi| {
            let (_, w) = self.blocks[bi];
            let w = w as usize;
            let mut u = vec![0.0f32; 1 << w];
            let mut fold = vec![0.0f32; 1 << w];
            for (r, &key) in self.keys[bi].iter().enumerate() {
                u[key as usize] += v[r];
            }
            let mut guard = slices[bi].lock().unwrap();
            let slice = guard.take().expect("block claimed once");
            block_product_fold(&u, w, slice, &mut fold);
        });
        Ok(())
    }

    fn check(&self, v: &[f32], out: &[f32]) -> Result<()> {
        if v.len() != self.rows {
            return Err(Error::ShapeMismatch(format!(
                "vector len {} != rows {}",
                v.len(),
                self.rows
            )));
        }
        if out.len() != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "output len {} != cols {}",
                out.len(),
                self.cols
            )));
        }
        Ok(())
    }
}

/// Tensorized ternary index (both Prop 2.1 halves).
#[derive(Debug, Clone)]
pub struct TernaryTensorizedIndex {
    /// Index of `[A == +1]`.
    pub plus: TensorizedIndex,
    /// Index of `[A == −1]`.
    pub minus: TensorizedIndex,
}

impl TernaryTensorizedIndex {
    /// Decompose and preprocess both halves.
    pub fn preprocess(a: &TernaryMatrix, k: usize) -> Self {
        let (p, m) = a.decompose();
        Self {
            plus: TensorizedIndex::preprocess(&p, k),
            minus: TensorizedIndex::preprocess(&m, k),
        }
    }

    /// `out = v · A`.
    pub fn execute(&self, v: &[f32], out: &mut [f32]) -> Result<()> {
        self.plus.execute(v, out)?;
        let mut tmp = vec![0.0f32; out.len()];
        self.minus.execute(v, &mut tmp)?;
        for (o, t) in out.iter_mut().zip(tmp.iter()) {
            *o -= t;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::standard::{standard_mul_binary, standard_mul_ternary};
    use crate::util::rng::Rng;

    #[test]
    fn tensorized_matches_standard() {
        let mut rng = Rng::new(127);
        for (n, m, k) in [(64, 48, 4), (100, 30, 5), (17, 5, 3)] {
            let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
            let v = rng.f32_vec(n, -1.0, 1.0);
            let idx = TensorizedIndex::preprocess(&b, k);
            let mut out = vec![0.0; m];
            idx.execute(&v, &mut out).unwrap();
            let expect = standard_mul_binary(&v, &b);
            for (g, e) in out.iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(131);
        let b = BinaryMatrix::random(256, 128, 0.5, &mut rng);
        let v = rng.f32_vec(256, -1.0, 1.0);
        let idx = TensorizedIndex::preprocess(&b, 6);
        let mut serial = vec![0.0; 128];
        let mut par = vec![0.0; 128];
        idx.execute(&v, &mut serial).unwrap();
        idx.execute_parallel(&v, &mut par, 4).unwrap();
        assert_eq!(serial, par);
    }

    #[test]
    fn ternary_tensorized_matches_standard() {
        let mut rng = Rng::new(137);
        let a = TernaryMatrix::random(90, 60, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(90, -1.0, 1.0);
        let idx = TernaryTensorizedIndex::preprocess(&a, 4);
        let mut out = vec![0.0; 60];
        idx.execute(&v, &mut out).unwrap();
        let expect = standard_mul_ternary(&v, &a);
        for (g, e) in out.iter().zip(expect.iter()) {
            assert!((g - e).abs() < 1e-3);
        }
    }

    #[test]
    fn keys_equal_rsr_segment_membership() {
        // The scatter keys and the gather permutation describe the same
        // partition: row r lands in segment key(r).
        let mut rng = Rng::new(139);
        let b = BinaryMatrix::random(50, 12, 0.5, &mut rng);
        let tens = TensorizedIndex::preprocess(&b, 4);
        let rsr = super::super::index::RsrIndex::preprocess(&b, 4);
        for (blk, keys) in rsr.blocks.iter().zip(tens.keys.iter()) {
            for (pos, &r) in blk.sigma.iter().enumerate() {
                let key = keys[r as usize] as usize;
                assert!(
                    (blk.seg[key] as usize) <= pos && pos < (blk.seg[key + 1] as usize),
                    "row {r} key {key} pos {pos}"
                );
            }
        }
    }
}
