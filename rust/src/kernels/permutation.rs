//! Step 2 of preprocessing — *binary row order* (paper Def 3.2).
//!
//! For one column block, rows are sorted by the k-bit value of the row
//! (MSB = first column of the block, matching `B_i[r,:]₂`). The sort is
//! a stable counting sort on the `2^k` possible keys — `O(n + 2^k)` per
//! block, which is the `O(n)` bucket sort the proof of Thm 3.6 uses.
//!
//! The output `sigma` is the permutation as the paper uses it:
//! `sigma[pos] = r` means row `r` of `B` lands at sorted position `pos`
//! (`π_σ(v)[pos] = v[σ(pos)]`).

use super::binary::BinaryMatrix;

/// Result of binary-row-ordering one block: the permutation and the
/// per-key counts (which Step 3 turns into the segmentation list).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowOrder {
    /// `sigma[pos] = original_row`, length `n`.
    pub sigma: Vec<u32>,
    /// `counts[key]` = number of rows whose block-key equals `key`,
    /// length `2^width`.
    pub counts: Vec<u32>,
}

/// Compute the binary row order of the block `B[:, col_start .. col_start+width]`.
pub fn binary_row_order(b: &BinaryMatrix, col_start: usize, width: usize) -> RowOrder {
    let n = b.rows();
    let buckets = 1usize << width;
    let mut counts = vec![0u32; buckets];

    // Pass 1: histogram of row keys.
    let mut keys = Vec::with_capacity(n);
    for r in 0..n {
        let key = b.row_key(r, col_start, width);
        keys.push(key);
        counts[key as usize] += 1;
    }

    // Exclusive prefix sum → first write position per key.
    let mut pos = vec![0u32; buckets];
    let mut acc = 0u32;
    for (p, &c) in pos.iter_mut().zip(counts.iter()) {
        *p = acc;
        acc += c;
    }

    // Pass 2: stable placement.
    let mut sigma = vec![0u32; n];
    for (r, &key) in keys.iter().enumerate() {
        let p = &mut pos[key as usize];
        sigma[*p as usize] = r as u32;
        *p += 1;
    }

    RowOrder { sigma, counts }
}

/// Check that `sigma` is a bijection on `0..n` (used by tests and the
/// index deserializer's validation).
pub fn is_permutation(sigma: &[u32], n: usize) -> bool {
    if sigma.len() != n {
        return false;
    }
    let mut seen = vec![false; n];
    for &s in sigma {
        let s = s as usize;
        if s >= n || seen[s] {
            return false;
        }
        seen[s] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The paper's Example 3.3 block (6×2).
    fn example_block() -> BinaryMatrix {
        BinaryMatrix::from_rows(&[
            &[0, 1],
            &[0, 0],
            &[0, 1],
            &[1, 1],
            &[0, 0],
            &[0, 0],
        ])
    }

    #[test]
    fn matches_paper_example_3_3() {
        let b = example_block();
        let ro = binary_row_order(&b, 0, 2);
        // Paper: σ = ⟨2,5,6,1,3,4⟩ in 1-based = [1,4,5,0,2,3] 0-based.
        assert_eq!(ro.sigma, vec![1, 4, 5, 0, 2, 3]);
        // counts per key 00,01,10,11 = 3,2,0,1
        assert_eq!(ro.counts, vec![3, 2, 0, 1]);
    }

    #[test]
    fn sorted_keys_are_nondecreasing_and_stable() {
        let mut rng = Rng::new(31);
        let b = BinaryMatrix::random(200, 8, 0.5, &mut rng);
        let ro = binary_row_order(&b, 0, 8);
        assert!(is_permutation(&ro.sigma, 200));
        let keys: Vec<u32> = ro.sigma.iter().map(|&r| b.row_key(r as usize, 0, 8)).collect();
        for w in keys.windows(2) {
            assert!(w[0] <= w[1], "keys not sorted");
        }
        // Stability: equal keys keep original row order.
        for w in ro.sigma.windows(2) {
            let (r0, r1) = (w[0] as usize, w[1] as usize);
            if b.row_key(r0, 0, 8) == b.row_key(r1, 0, 8) {
                assert!(r0 < r1, "counting sort must be stable");
            }
        }
    }

    #[test]
    fn counts_sum_to_n() {
        let mut rng = Rng::new(37);
        for width in [1usize, 3, 5] {
            let b = BinaryMatrix::random(77, 6 * width, 0.3, &mut rng);
            let ro = binary_row_order(&b, width, width);
            assert_eq!(ro.counts.iter().sum::<u32>(), 77);
            assert_eq!(ro.counts.len(), 1 << width);
        }
    }

    #[test]
    fn is_permutation_rejects_bad_inputs() {
        assert!(is_permutation(&[2, 0, 1], 3));
        assert!(!is_permutation(&[0, 0, 1], 3)); // duplicate
        assert!(!is_permutation(&[0, 3, 1], 3)); // out of range
        assert!(!is_permutation(&[0, 1], 3)); // wrong length
    }
}
