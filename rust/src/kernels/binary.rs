//! Binary matrices `B ∈ {0,1}^{n×m}` — the object Problem 2 multiplies
//! against. Stored bit-packed (one u64 word per 64 columns, row-major),
//! which is both the compact on-disk form and what the preprocessing
//! pass reads.

use crate::util::bitops;
use crate::util::rng::Rng;

/// A bit-packed binary matrix, row-major.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BinaryMatrix {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl BinaryMatrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let words_per_row = bitops::words_for_bits(cols);
        Self { rows, cols, words_per_row, words: vec![0; rows * words_per_row] }
    }

    /// Build from a dense 0/1 byte buffer (row-major, `rows*cols` long).
    pub fn from_dense(rows: usize, cols: usize, data: &[u8]) -> Self {
        assert_eq!(data.len(), rows * cols, "dense buffer size mismatch");
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if data[r * cols + c] != 0 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    /// Build from rows of `&[u8]` 0/1 values (test convenience).
    pub fn from_rows(rows: &[&[u8]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let flat: Vec<u8> = rows.iter().flat_map(|x| x.iter().copied()).collect();
        Self::from_dense(r, c, &flat)
    }

    /// Uniform random matrix with density `p` of ones.
    ///
    /// `p = 0.5` takes a fast word-at-a-time path (one `u64` draw per
    /// 64 entries) so the paper's full `n = 2^16` benches can generate
    /// half-gigabyte matrices in well under a second.
    pub fn random(rows: usize, cols: usize, p: f64, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(rows, cols);
        if (p - 0.5).abs() < 1e-12 {
            let tail_bits = cols & 63;
            let tail_mask =
                if tail_bits == 0 { u64::MAX } else { (1u64 << tail_bits) - 1 };
            for r in 0..rows {
                let row =
                    &mut m.words[r * m.words_per_row..(r + 1) * m.words_per_row];
                for (wi, w) in row.iter_mut().enumerate() {
                    *w = rng.next_u64();
                    if wi + 1 == cols.div_ceil(64) {
                        *w &= tail_mask;
                    }
                }
            }
            return m;
        }
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(p) {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read element `(r, c)`.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        bitops::get_bit(self.row_words(r), c)
    }

    /// Write element `(r, c)`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = &mut self.words[r * self.words_per_row..(r + 1) * self.words_per_row];
        if v {
            bitops::set_bit(w, c);
        } else {
            w[c >> 6] &= !(1u64 << (c & 63));
        }
    }

    /// The packed words of row `r`.
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// The k-bit row key for the column block starting at `col_start`
    /// with `width` columns — MSB-first per the paper's Def 3.2.
    #[inline]
    pub fn row_key(&self, r: usize, col_start: usize, width: usize) -> u32 {
        debug_assert!(width <= 16 && col_start + width <= self.cols);
        bitops::extract_key_msb_first(self.row_words(r), col_start, width)
    }

    /// Count of ones in the whole matrix.
    pub fn count_ones(&self) -> u64 {
        bitops::popcount(&self.words)
    }

    /// Heap bytes used by the packed representation.
    pub fn packed_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Bytes a dense u8 representation would use (baseline for Fig 5).
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols
    }

    /// Densify to a 0/1 byte buffer (tests, python interop).
    pub fn to_dense(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[r * self.cols + c] = self.get(r, c) as u8;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut m = BinaryMatrix::zeros(5, 70);
        m.set(0, 0, true);
        m.set(4, 69, true);
        m.set(2, 63, true);
        m.set(2, 64, true);
        assert!(m.get(0, 0));
        assert!(m.get(4, 69));
        assert!(m.get(2, 63) && m.get(2, 64));
        assert!(!m.get(1, 1));
        m.set(2, 63, false);
        assert!(!m.get(2, 63));
        assert_eq!(m.count_ones(), 3);
    }

    #[test]
    fn from_dense_matches_get() {
        let data = [1u8, 0, 1, 0, 1, 1];
        let m = BinaryMatrix::from_dense(2, 3, &data);
        assert!(m.get(0, 0) && !m.get(0, 1) && m.get(0, 2));
        assert!(!m.get(1, 0) && m.get(1, 1) && m.get(1, 2));
        assert_eq!(m.to_dense(), data);
    }

    #[test]
    fn row_key_is_msb_first() {
        // Paper example: row [1,0,1,1] → (1011)₂ = 11.
        let m = BinaryMatrix::from_rows(&[&[1, 0, 1, 1]]);
        assert_eq!(m.row_key(0, 0, 4), 0b1011);
        assert_eq!(m.row_key(0, 1, 3), 0b011);
    }

    #[test]
    fn random_density_is_plausible() {
        let mut rng = Rng::new(5);
        let m = BinaryMatrix::random(64, 64, 0.5, &mut rng);
        let ones = m.count_ones() as f64 / (64.0 * 64.0);
        assert!((0.4..0.6).contains(&ones), "density {ones}");
    }

    #[test]
    fn memory_accounting() {
        let m = BinaryMatrix::zeros(128, 128);
        assert_eq!(m.packed_bytes(), 128 * 2 * 8); // 2 words per row
        assert_eq!(m.dense_bytes(), 128 * 128);
    }
}
