//! The dense baselines RSR is measured against.
//!
//! * [`standard_mul_binary`] / [`standard_mul_ternary`] — the paper's
//!   "Standard" `O(n²)` vector–matrix multiply (Fig 4's baseline),
//! * [`standard_mul_ternary_i8`] — the same loop over the raw i8
//!   buffer, which is how a straightforward C/PyTorch-CPU
//!   implementation reads the weights,
//! * [`packed_mul_binary`] — a *stronger* baseline than the paper uses:
//!   the bit-packed matrix drives word-at-a-time accumulation.

use super::binary::BinaryMatrix;
use super::ternary::TernaryMatrix;

/// Standard `v·B` for binary `B` — the paper's baseline: for each row,
/// add `v[r]` into every column where `B[r,c] = 1`.
pub fn standard_mul_binary(v: &[f32], b: &BinaryMatrix) -> Vec<f32> {
    assert_eq!(v.len(), b.rows());
    let mut out = vec![0.0f32; b.cols()];
    for (r, &vr) in v.iter().enumerate() {
        if vr == 0.0 {
            continue;
        }
        for c in 0..b.cols() {
            if b.get(r, c) {
                out[c] += vr;
            }
        }
    }
    out
}

/// Standard `v·A` for ternary `A` over the i8 representation.
pub fn standard_mul_ternary(v: &[f32], a: &TernaryMatrix) -> Vec<f32> {
    assert_eq!(v.len(), a.rows());
    let mut out = vec![0.0f32; a.cols()];
    for (r, &vr) in v.iter().enumerate() {
        let row = a.row(r);
        for (c, &w) in row.iter().enumerate() {
            out[c] += vr * w as f32;
        }
    }
    out
}

/// Same as [`standard_mul_ternary`] but branching on the weight value
/// instead of multiplying — the common hand-optimized ternary inner
/// loop (add / subtract / skip).
pub fn standard_mul_ternary_i8(v: &[f32], a: &TernaryMatrix) -> Vec<f32> {
    assert_eq!(v.len(), a.rows());
    let mut out = vec![0.0f32; a.cols()];
    for (r, &vr) in v.iter().enumerate() {
        let row = a.row(r);
        for (c, &w) in row.iter().enumerate() {
            match w {
                1 => out[c] += vr,
                -1 => out[c] -= vr,
                _ => {}
            }
        }
    }
    out
}

/// Word-at-a-time baseline over the packed binary matrix: for each row,
/// iterate set bits of each 64-bit word (`trailing_zeros` loop). Much
/// faster than the byte-wise standard loop at density 0.5 it is still
/// `O(n²)` work in the dense regime — included as the strongest honest
/// "no preprocessing" CPU baseline for the ablation bench.
pub fn packed_mul_binary(v: &[f32], b: &BinaryMatrix) -> Vec<f32> {
    assert_eq!(v.len(), b.rows());
    let cols = b.cols();
    let mut out = vec![0.0f32; cols];
    for (r, &vr) in v.iter().enumerate() {
        if vr == 0.0 {
            continue;
        }
        let words = b.row_words(r);
        for (wi, &word) in words.iter().enumerate() {
            let mut bits = word;
            let base = wi * 64;
            while bits != 0 {
                let c = base + bits.trailing_zeros() as usize;
                out[c] += vr;
                bits &= bits - 1;
            }
        }
    }
    out
}

/// Packed ternary baseline via Prop 2.1: `v·B⁽¹⁾ − v·B⁽²⁾` with the
/// word-at-a-time binary loop.
pub fn packed_mul_ternary(v: &[f32], plus: &BinaryMatrix, minus: &BinaryMatrix) -> Vec<f32> {
    let mut out = packed_mul_binary(v, plus);
    let neg = packed_mul_binary(v, minus);
    for (o, n) in out.iter_mut().zip(neg.iter()) {
        *o -= n;
    }
    out
}

/// The paper's Fig 4 "Standard" baseline exactly: a plain double loop
/// over a dense byte array (`B[r*cols + c] ∈ {0,1}`) — no bit
/// unpacking in the inner loop, matching the native C++ reference.
pub fn standard_mul_binary_u8(v: &[f32], dense: &[u8], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(v.len(), rows);
    assert_eq!(dense.len(), rows * cols);
    let mut out = vec![0.0f32; cols];
    for (r, &vr) in v.iter().enumerate() {
        let row = &dense[r * cols..(r + 1) * cols];
        for (o, &b) in out.iter_mut().zip(row.iter()) {
            if b != 0 {
                *o += vr;
            }
        }
    }
    out
}

/// Dense f32 matmul `v·W` for an unquantized weight matrix (used by the
/// transformer substrate's embedding / norm layers and as the fp32
/// reference in model tests). Row-major `W: rows×cols`.
pub fn dense_mul_f32(v: &[f32], w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(v.len(), rows);
    assert_eq!(w.len(), rows * cols);
    let mut out = vec![0.0f32; cols];
    for (r, &vr) in v.iter().enumerate() {
        if vr == 0.0 {
            continue;
        }
        let row = &w[r * cols..(r + 1) * cols];
        for (o, &x) in out.iter_mut().zip(row.iter()) {
            *o += vr * x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn binary_standard_small_hand_checked() {
        // B = [[1,0],[1,1],[0,1]], v = [1,2,3] → [3, 5].
        let b = BinaryMatrix::from_rows(&[&[1, 0], &[1, 1], &[0, 1]]);
        assert_eq!(standard_mul_binary(&[1.0, 2.0, 3.0], &b), vec![3.0, 5.0]);
    }

    #[test]
    fn ternary_standard_small_hand_checked() {
        // A = [[1,-1],[0,1]], v = [2,3] → [2, 1].
        let a = TernaryMatrix::from_dense(2, 2, vec![1, -1, 0, 1]);
        assert_eq!(standard_mul_ternary(&[2.0, 3.0], &a), vec![2.0, 1.0]);
        assert_eq!(standard_mul_ternary_i8(&[2.0, 3.0], &a), vec![2.0, 1.0]);
    }

    #[test]
    fn packed_matches_standard() {
        let mut rng = Rng::new(101);
        let b = BinaryMatrix::random(130, 200, 0.4, &mut rng);
        let v = rng.f32_vec(130, -1.0, 1.0);
        let a = standard_mul_binary(&v, &b);
        let p = packed_mul_binary(&v, &b);
        for (x, y) in a.iter().zip(p.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_ternary_matches_standard() {
        let mut rng = Rng::new(103);
        let a = TernaryMatrix::random(70, 90, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(70, -1.0, 1.0);
        let (p, m) = a.decompose();
        let got = packed_mul_ternary(&v, &p, &m);
        let expect = standard_mul_ternary(&v, &a);
        for (x, y) in got.iter().zip(expect.iter()) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn dense_f32_matches_manual() {
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let out = dense_mul_f32(&[10.0, 100.0], &w, 2, 3);
        assert_eq!(out, vec![410.0, 520.0, 630.0]);
    }
}
