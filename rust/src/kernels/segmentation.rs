//! Step 3 of preprocessing — *full segmentation* (paper Def 3.4).
//!
//! For a binary-row-ordered block, the full segmentation list has one
//! entry per possible k-bit value `j ∈ [0, 2^k)`: the first sorted
//! position whose row-key is `j`. Keys with no rows reuse the next
//! boundary (paper Fig 2). We store one extra sentinel entry `L[2^k] = n`
//! so the segment for key `j` is always `[L[j], L[j+1])` — this removes
//! the paper's `j = |L|` special case from the inner loop (Eq 3/5).

/// Build the full segmentation list (with sentinel) from per-key counts.
///
/// `counts[j]` is the number of rows whose key is `j` (from
/// [`super::permutation::binary_row_order`]); the result has
/// `counts.len() + 1` entries, is non-decreasing, starts at 0 and ends
/// at `n`.
pub fn full_segmentation(counts: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(counts.len() + 1);
    let mut acc = 0u32;
    out.push(0);
    for &c in counts {
        acc += c;
        out.push(acc);
    }
    out
}

/// Per Proposition 3.5: the number of rows whose key is `j`.
#[inline]
pub fn segment_len(seg: &[u32], j: usize) -> u32 {
    seg[j + 1] - seg[j]
}

/// Validate the structural invariants of a full segmentation list for a
/// block of width `width` over `n` rows.
pub fn validate(seg: &[u32], width: usize, n: usize) -> Result<(), String> {
    let expect_len = (1usize << width) + 1;
    if seg.len() != expect_len {
        return Err(format!("segmentation length {} != 2^{width}+1", seg.len()));
    }
    if seg[0] != 0 {
        return Err("segmentation must start at 0".into());
    }
    if *seg.last().unwrap() as usize != n {
        return Err(format!("segmentation must end at n={n}"));
    }
    if seg.windows(2).any(|w| w[0] > w[1]) {
        return Err("segmentation must be non-decreasing".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_example_3_3() {
        // counts for keys 00,01,10,11 = 3,2,0,1 (Example 3.3).
        let seg = full_segmentation(&[3, 2, 0, 1]);
        // Paper (1-based): [1,4,6,6]; ours (0-based + sentinel): [0,3,5,5,6].
        assert_eq!(seg, vec![0, 3, 5, 5, 6]);
        // Empty key 10 has zero length (Prop 3.5).
        assert_eq!(segment_len(&seg, 2), 0);
        assert_eq!(segment_len(&seg, 0), 3);
        assert_eq!(segment_len(&seg, 3), 1);
        validate(&seg, 2, 6).unwrap();
    }

    #[test]
    fn lengths_recover_counts() {
        let counts = vec![0u32, 7, 0, 0, 3, 1, 0, 2];
        let seg = full_segmentation(&counts);
        for (j, &c) in counts.iter().enumerate() {
            assert_eq!(segment_len(&seg, j), c);
        }
        validate(&seg, 3, 13).unwrap();
    }

    #[test]
    fn validate_catches_violations() {
        assert!(validate(&[0, 1, 2], 2, 2).is_err()); // wrong length
        assert!(validate(&[1, 1, 1, 1, 2], 2, 2).is_err()); // doesn't start at 0
        assert!(validate(&[0, 1, 1, 1, 3], 2, 2).is_err()); // doesn't end at n
        assert!(validate(&[0, 2, 1, 2, 2], 2, 2).is_err()); // decreasing
        assert!(validate(&[0, 1, 1, 2, 2], 2, 2).is_ok());
    }
}
