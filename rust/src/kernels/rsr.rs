//! RSR — Algorithm 2 of the paper (inference time).
//!
//! For each k-column block `Bᵢ` with index `(σᵢ, Lᵢ)`:
//!
//! 1. **Segmented sum** (Eq 5, in place — never materializes the
//!    permuted vector): `u[j] = Σ_{pos ∈ [L[j], L[j+1])} v[σ(pos)]`,
//!    `O(n)` per block.
//! 2. **Block product**: `rᵢ = u · Bin_[k]`, `O(k·2^k)`.
//!
//! Total `O((n/k)(n + k·2^k))`; with `k = log(n/log n)` that is
//! `O(n²/(log n − log log n))` (Theorem 4.3) — strictly below the
//! `O(n²)` of a dense multiply, and within a log-log factor of the
//! `O(n²/log n)` RSR++ achieves by replacing step 2 with Algorithm 3
//! ([`super::rsrpp`]). Preprocessing runs once per fixed weight matrix
//! ([`RsrIndex::preprocess`]); plans amortize it over every inference.
//!
//! The checked kernels here ([`segmented_sum`], [`block_product_dense`])
//! operate on the boxed [`BlockIndex`] form and are the *reference*
//! implementations the property tests pit every optimized path against.
//! The plans themselves execute on the contiguous [`FlatPlan`] arena
//! ([`super::flat`]).

use super::flat::{execute_rsr_flat, FlatPlan};
use super::index::{BlockIndex, RsrIndex, TernaryRsrIndex};
use crate::error::{Error, Result};

/// Step 1: segmented sums of `v` under `(σ, L)` without materializing
/// the permuted vector (paper Eq 5). Writes `2^width` sums into `u`.
///
/// Fully bounds-checked, strictly sequential accumulation — the
/// reference the flat/SIMD kernels are verified against.
#[inline]
pub fn segmented_sum(blk: &BlockIndex, v: &[f32], u: &mut [f32]) {
    let seg = &blk.seg;
    let sigma = &blk.sigma;
    debug_assert_eq!(u.len() + 1, seg.len());
    for j in 0..u.len() {
        let lo = seg[j] as usize;
        let hi = seg[j + 1] as usize;
        let mut acc = 0.0f32;
        for &s in &sigma[lo..hi] {
            acc += v[s as usize];
        }
        u[j] = acc;
    }
}

/// Bounds-check-free variant of [`segmented_sum`], kept for the boxed
/// index form (same serial accumulation order as the checked kernel).
///
/// # Safety contract (validated at plan build time)
/// `blk` passed index validation: `sigma` is a permutation of
/// `0..v.len()` and `seg` is monotone with last entry `v.len()`.
#[inline]
pub fn segmented_sum_unchecked(blk: &BlockIndex, v: &[f32], u: &mut [f32]) {
    let seg = &blk.seg;
    let sigma = &blk.sigma;
    debug_assert_eq!(u.len() + 1, seg.len());
    for j in 0..u.len() {
        let lo = seg[j] as usize;
        let hi = seg[j + 1] as usize;
        let mut acc = 0.0f32;
        unsafe {
            for pos in lo..hi {
                let s = *sigma.get_unchecked(pos) as usize;
                acc += *v.get_unchecked(s);
            }
        }
        u[j] = acc;
    }
}

/// Step 2 (RSR's dense form): `r += u · Bin_[width]`, writing `width`
/// outputs. `O(width · 2^width)` — iterate values `l`, scatter `u[l]`
/// into each set bit's column.
#[inline]
pub fn block_product_dense(u: &[f32], width: usize, out: &mut [f32]) {
    debug_assert_eq!(u.len(), 1 << width);
    debug_assert_eq!(out.len(), width);
    out.fill(0.0);
    for (l, &ul) in u.iter().enumerate() {
        if ul == 0.0 {
            continue; // empty segments are common (2^k close to n)
        }
        // Column j of Bin_[k] holds bit (width-1-j) of l.
        let mut bits = l;
        let mut j = width;
        while bits != 0 {
            j -= 1;
            if bits & 1 == 1 {
                out[j] += ul;
            }
            bits >>= 1;
        }
    }
}

/// A reusable execution plan: the flat arena plus scratch for `u`, so
/// the per-call hot path does no allocation.
#[derive(Debug, Clone)]
pub struct RsrPlan {
    plan: FlatPlan,
    scratch: Vec<f32>,
}

impl RsrPlan {
    /// Build (and validate) a plan from a preprocessed index. The index
    /// is flattened into the contiguous arena form and dropped.
    pub fn new(index: RsrIndex) -> Result<Self> {
        let plan = FlatPlan::from_index(&index)?;
        let max_u = plan.max_u();
        Ok(Self { plan, scratch: vec![0.0; max_u] })
    }

    /// The underlying flat plan.
    pub fn flat(&self) -> &FlatPlan {
        &self.plan
    }

    /// Index bytes held by this plan.
    pub fn index_bytes(&self) -> usize {
        self.plan.bytes()
    }

    /// `out = v · B` using RSR (Algorithm 2). `v.len() == rows`,
    /// `out.len() == cols`; shapes are checked, the hot loop is not.
    ///
    /// Preprocess once, execute many times:
    ///
    /// ```
    /// use rsr::kernels::standard::standard_mul_binary;
    /// use rsr::kernels::{BinaryMatrix, RsrIndex, RsrPlan};
    /// use rsr::util::rng::Rng;
    ///
    /// let mut rng = Rng::new(7);
    /// let b = BinaryMatrix::random(64, 64, 0.5, &mut rng);
    /// let mut plan = RsrPlan::new(RsrIndex::preprocess(&b, 4)).unwrap();
    ///
    /// let mut out = vec![0.0; 64];
    /// for _ in 0..3 {
    ///     let v = rng.f32_vec(64, -1.0, 1.0);
    ///     plan.execute(&v, &mut out).unwrap();
    ///     let expect = standard_mul_binary(&v, &b);
    ///     for (g, e) in out.iter().zip(&expect) {
    ///         assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()));
    ///     }
    /// }
    /// ```
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        check_shapes(self.plan.rows(), self.plan.cols(), v, out)?;
        execute_rsr_flat(&self.plan, v, out, &mut self.scratch);
        Ok(())
    }
}

/// Shape check shared by every executing plan type.
pub(crate) fn check_shapes(rows: usize, cols: usize, v: &[f32], out: &[f32]) -> Result<()> {
    if v.len() != rows {
        return Err(Error::ShapeMismatch(format!(
            "vector len {} != rows {}",
            v.len(),
            rows
        )));
    }
    if out.len() != cols {
        return Err(Error::ShapeMismatch(format!(
            "output len {} != cols {}",
            out.len(),
            cols
        )));
    }
    Ok(())
}

/// One-shot convenience: preprocess + execute RSR on a binary matrix.
pub fn rsr_mul(v: &[f32], b: &super::binary::BinaryMatrix, k: usize) -> Vec<f32> {
    let mut plan = RsrPlan::new(RsrIndex::preprocess(b, k)).expect("fresh index is valid");
    let mut out = vec![0.0; b.cols()];
    plan.execute(v, &mut out).expect("shapes match");
    out
}

/// Ternary RSR: `v·A = v·B⁽¹⁾ − v·B⁽²⁾` (Prop 2.1).
#[derive(Debug, Clone)]
pub struct TernaryRsrPlan {
    plus: RsrPlan,
    minus: RsrPlan,
    tmp: Vec<f32>,
}

impl TernaryRsrPlan {
    /// Build from a preprocessed ternary index.
    pub fn new(index: TernaryRsrIndex) -> Result<Self> {
        let cols = index.plus.cols;
        Ok(Self {
            plus: RsrPlan::new(index.plus)?,
            minus: RsrPlan::new(index.minus)?,
            tmp: vec![0.0; cols],
        })
    }

    /// `out = v · A`.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        self.plus.execute(v, out)?;
        self.minus.execute(v, &mut self.tmp)?;
        for (o, t) in out.iter_mut().zip(self.tmp.iter()) {
            *o -= t;
        }
        Ok(())
    }

    /// Index bytes across both halves.
    pub fn bytes(&self) -> usize {
        self.plus.index_bytes() + self.minus.index_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::binary::BinaryMatrix;
    use super::super::standard::{standard_mul_binary, standard_mul_ternary};
    use super::super::ternary::TernaryMatrix;
    use crate::util::rng::Rng;

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            let tol = 1e-3 * (1.0 + x.abs().max(y.abs()));
            assert!((x - y).abs() <= tol, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matches_paper_segmented_sum_example() {
        // Example under Def 4.1: the *permuted* vector v_π =
        // [3,2,4,5,9,1] on Example 3.3's block → SS = [9, 14, 0, 1]
        // (9 = 3+2+4, 14 = 5+9, empty segment 10, 1 = 1). Eq 5 computes
        // the same sums in place from the unpermuted v, so build v with
        // v[σ(pos)] = v_π[pos].
        let b = super::super::index::paper_matrix();
        let idx = RsrIndex::preprocess(&b, 2);
        let blk = &idx.blocks[0];
        let v_pi = [3.0f32, 2.0, 4.0, 5.0, 9.0, 1.0];
        let mut v = [0.0f32; 6];
        for (pos, &r) in blk.sigma.iter().enumerate() {
            v[r as usize] = v_pi[pos];
        }
        let mut u = [0.0f32; 4];
        segmented_sum(blk, &v, &mut u);
        assert_eq!(u, [9.0, 14.0, 0.0, 1.0]);
    }

    #[test]
    fn unchecked_matches_checked() {
        let mut rng = Rng::new(59);
        let b = BinaryMatrix::random(100, 30, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 4);
        let v = rng.f32_vec(100, -1.0, 1.0);
        for blk in &idx.blocks {
            let mut u1 = vec![0.0; 1 << blk.width];
            let mut u2 = vec![0.0; 1 << blk.width];
            segmented_sum(blk, &v, &mut u1);
            segmented_sum_unchecked(blk, &v, &mut u2);
            assert_eq!(u1, u2);
        }
    }

    #[test]
    fn rsr_matches_standard_binary() {
        let mut rng = Rng::new(61);
        for (n, m, k) in [(64, 64, 3), (100, 60, 4), (33, 7, 5), (128, 128, 1)] {
            let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
            let v = rng.f32_vec(n, -2.0, 2.0);
            let expect = standard_mul_binary(&v, &b);
            let got = rsr_mul(&v, &b, k);
            assert_close(&got, &expect);
        }
    }

    #[test]
    fn rsr_matches_standard_ternary() {
        let mut rng = Rng::new(67);
        let a = TernaryMatrix::random(80, 48, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(80, -1.0, 1.0);
        let expect = standard_mul_ternary(&v, &a);
        let mut plan =
            TernaryRsrPlan::new(TernaryRsrIndex::preprocess(&a, 4)).unwrap();
        let mut out = vec![0.0; 48];
        plan.execute(&v, &mut out).unwrap();
        assert_close(&out, &expect);
    }

    #[test]
    fn plan_rejects_bad_shapes() {
        let mut rng = Rng::new(71);
        let b = BinaryMatrix::random(10, 10, 0.5, &mut rng);
        let mut plan = RsrPlan::new(RsrIndex::preprocess(&b, 2)).unwrap();
        let mut out = vec![0.0; 10];
        assert!(plan.execute(&[0.0; 9], &mut out).is_err());
        let v = vec![0.0; 10];
        let mut bad_out = vec![0.0; 9];
        assert!(plan.execute(&v, &mut bad_out).is_err());
    }

    #[test]
    fn edge_cases_all_zero_and_all_one() {
        let mut rng = Rng::new(73);
        let v = rng.f32_vec(32, -1.0, 1.0);
        let zero = BinaryMatrix::zeros(32, 16);
        assert_eq!(rsr_mul(&v, &zero, 4), vec![0.0; 16]);
        let mut ones = BinaryMatrix::zeros(32, 16);
        for r in 0..32 {
            for c in 0..16 {
                ones.set(r, c, true);
            }
        }
        let s: f32 = v.iter().sum();
        let got = rsr_mul(&v, &ones, 4);
        for g in got {
            assert!((g - s).abs() < 1e-3);
        }
    }

    #[test]
    fn block_product_dense_matches_naive() {
        let mut rng = Rng::new(79);
        for width in 1..=8usize {
            let u = rng.f32_vec(1 << width, -1.0, 1.0);
            let mut out = vec![0.0; width];
            block_product_dense(&u, width, &mut out);
            // naive: out[j] = Σ_l u[l]·bit(l, j)
            for j in 0..width {
                let expect: f32 = (0..1usize << width)
                    .filter(|l| (l >> (width - 1 - j)) & 1 == 1)
                    .map(|l| u[l])
                    .sum();
                assert!((out[j] - expect).abs() < 1e-4);
            }
        }
    }
}
