//! Block-parallel execution (paper Appendix C.1.I).
//!
//! Column blocks are independent: block `i` reads all of `v` but writes
//! only columns `[iₛ, iₛ+width)` of the output. With `c` cores the
//! time drops to `O(n²/(c·log n))` for RSR++.
//!
//! Each thread carries its own `u`/fold scratch; the output is split
//! into disjoint per-block slices up front so no synchronization is
//! needed beyond the work-stealing counter.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::index::{RsrIndex, TernaryRsrIndex};
use super::rsr::{check_shapes, segmented_sum_unchecked};
use super::rsrpp::block_product_fold;
use crate::error::Result;

/// Parallel RSR++ plan: validated index + thread count.
#[derive(Debug, Clone)]
pub struct ParallelRsrPlan {
    index: RsrIndex,
    threads: usize,
}

impl ParallelRsrPlan {
    /// Build with an explicit thread count (`0` → default).
    pub fn new(index: RsrIndex, threads: usize) -> Result<Self> {
        index.validate()?;
        let threads = if threads == 0 {
            crate::util::threadpool::default_threads()
        } else {
            threads
        };
        Ok(Self { index, threads })
    }

    /// The underlying index.
    pub fn index(&self) -> &RsrIndex {
        &self.index
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// `out = v · B`, blocks distributed across threads.
    pub fn execute(&self, v: &[f32], out: &mut [f32]) -> Result<()> {
        check_shapes(&self.index, v, out)?;
        let blocks = &self.index.blocks;
        if blocks.is_empty() {
            return Ok(());
        }

        // Split `out` into per-block disjoint slices.
        let mut slices: Vec<&mut [f32]> = Vec::with_capacity(blocks.len());
        let mut rest = out;
        for blk in blocks {
            let (head, tail) = rest.split_at_mut(blk.width as usize);
            slices.push(head);
            rest = tail;
        }

        let max_u = blocks.iter().map(|b| 1usize << b.width).max().unwrap();
        let next = AtomicUsize::new(0);
        let slices = std::sync::Mutex::new(slices.into_iter().map(Some).collect::<Vec<_>>());

        std::thread::scope(|scope| {
            for _ in 0..self.threads.min(blocks.len()) {
                scope.spawn(|| {
                    let mut u = vec![0.0f32; max_u];
                    let mut fold = vec![0.0f32; max_u];
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= blocks.len() {
                            break;
                        }
                        // Take ownership of this block's output slice.
                        let slice = {
                            let mut guard = slices.lock().unwrap();
                            guard[i].take().expect("block claimed once")
                        };
                        let blk = &blocks[i];
                        let w = blk.width as usize;
                        segmented_sum_unchecked(blk, v, &mut u[..1 << w]);
                        block_product_fold(&u[..1 << w], w, slice, &mut fold);
                    }
                });
            }
        });
        Ok(())
    }
}

/// Parallel ternary plan (`A = B⁽¹⁾ − B⁽²⁾`, both halves parallel).
#[derive(Debug, Clone)]
pub struct ParallelTernaryRsrPlan {
    plus: ParallelRsrPlan,
    minus: ParallelRsrPlan,
}

impl ParallelTernaryRsrPlan {
    /// Build with an explicit thread count (`0` → default).
    pub fn new(index: TernaryRsrIndex, threads: usize) -> Result<Self> {
        Ok(Self {
            plus: ParallelRsrPlan::new(index.plus, threads)?,
            minus: ParallelRsrPlan::new(index.minus, threads)?,
        })
    }

    /// `out = v · A`.
    pub fn execute(&self, v: &[f32], out: &mut [f32]) -> Result<()> {
        let mut tmp = vec![0.0f32; out.len()];
        self.plus.execute(v, out)?;
        self.minus.execute(v, &mut tmp)?;
        for (o, t) in out.iter_mut().zip(tmp.iter()) {
            *o -= t;
        }
        Ok(())
    }

    /// Index bytes across both Prop 2.1 halves.
    pub fn index_bytes(&self) -> usize {
        self.plus.index().bytes() + self.minus.index().bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::binary::BinaryMatrix;
    use super::super::standard::standard_mul_binary;
    use super::super::ternary::TernaryMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn parallel_matches_standard_across_thread_counts() {
        let mut rng = Rng::new(107);
        let b = BinaryMatrix::random(256, 96, 0.5, &mut rng);
        let v = rng.f32_vec(256, -1.0, 1.0);
        let expect = standard_mul_binary(&v, &b);
        for threads in [1usize, 2, 4, 8] {
            let plan =
                ParallelRsrPlan::new(RsrIndex::preprocess(&b, 4), threads).unwrap();
            let mut out = vec![0.0; 96];
            plan.execute(&v, &mut out).unwrap();
            for (g, e) in out.iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3, "threads={threads}");
            }
        }
    }

    #[test]
    fn parallel_ternary_matches_standard() {
        use super::super::standard::standard_mul_ternary;
        let mut rng = Rng::new(109);
        let a = TernaryMatrix::random(128, 64, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(128, -1.0, 1.0);
        let plan = ParallelTernaryRsrPlan::new(
            TernaryRsrIndex::preprocess(&a, 4),
            3,
        )
        .unwrap();
        let mut out = vec![0.0; 64];
        plan.execute(&v, &mut out).unwrap();
        let expect = standard_mul_ternary(&v, &a);
        for (g, e) in out.iter().zip(expect.iter()) {
            assert!((g - e).abs() < 1e-3);
        }
    }

    #[test]
    fn zero_threads_uses_default() {
        let mut rng = Rng::new(113);
        let b = BinaryMatrix::random(32, 16, 0.5, &mut rng);
        let plan = ParallelRsrPlan::new(RsrIndex::preprocess(&b, 3), 0).unwrap();
        assert!(plan.threads() >= 1);
    }
}
