//! Block-parallel execution (paper Appendix C.1.I).
//!
//! Column blocks are independent: block `i` reads all of `v` but writes
//! only columns `[iₛ, iₛ+width)` of the output. With `c` cores the
//! time drops to `O(n²/(c·log n))` for RSR++.
//!
//! The hot path is spawn-free and lock-free: a
//! [`PersistentPool`](crate::util::threadpool::PersistentPool) of
//! workers is checked out per execute through a
//! [`PoolHandle`](crate::util::threadpool::PoolHandle), every worker
//! lane owns a pre-allocated `u`/fold scratch slot, and the per-block
//! output ranges come straight from the flat-plan descriptors —
//! `(col_start, width)` are disjoint by construction (validated at
//! build), so each block writes its own output slice with no
//! synchronization at all.
//!
//! Pool ownership (ROADMAP item): plans built with `threads = 0` (the
//! default everywhere above the kernel layer) share the **process-wide**
//! pool via [`PoolHandle::global`] — N weight matrices cost one set of
//! parked workers, not N. An explicit `threads > 0` still gets a
//! dedicated pool for benches that pin parallelism. The executor body
//! lives in [`SharedParallelExec`] so the tuned runtime path
//! ([`crate::runtime::ExecutablePlan`]) can run **store-shared**
//! (`Arc`'d) flat plans through the same code.

use std::cell::UnsafeCell;

use super::flat::{segmented_sum_flat, FlatPlan, TernaryFlatPlan};
use super::index::{RsrIndex, TernaryRsrIndex};
use super::rsr::check_shapes;
use super::rsrpp::block_product_fold;
use crate::error::Result;
use crate::util::threadpool::PoolHandle;

/// One worker lane's `(u, fold)` scratch. Wrapped in an `UnsafeCell`
/// so the `Fn` closure handed to the pool can mutate it.
struct LaneScratch(UnsafeCell<(Vec<f32>, Vec<f32>)>);

// SAFETY: lane `w` is accessed only by the pool worker with index `w`
// (the pool guarantees worker indices are unique among concurrently
// running closure invocations), so no slot is ever aliased.
unsafe impl Sync for LaneScratch {}

impl LaneScratch {
    fn new(max_u: usize) -> Self {
        Self(UnsafeCell::new((vec![0.0; max_u], vec![0.0; max_u])))
    }
}

fn lanes(threads: usize, max_u: usize) -> Vec<LaneScratch> {
    (0..threads).map(|_| LaneScratch::new(max_u)).collect()
}

/// Raw output base pointer, sendable to pool workers. Each block writes
/// the disjoint `[col_start, col_start + width)` range.
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Execute one block of `plan` into `out_ptr` using lane `w`'s scratch.
///
/// # Safety
/// * `out_ptr` points at a live `[f32]` of length `plan.cols()`;
/// * no other concurrent invocation uses the same block index `i`
///   (disjoint output columns) or the same lane `w` (exclusive
///   scratch).
unsafe fn run_block(
    plan: &FlatPlan,
    v: &[f32],
    out_ptr: OutPtr,
    scratch: &[LaneScratch],
    w: usize,
    i: usize,
) {
    let blk = &plan.blocks()[i];
    let width = blk.width as usize;
    let (u, fold) = &mut *scratch[w].0.get();
    let u = &mut u[..1 << width];
    segmented_sum_flat(plan.block_sigma(i), plan.block_seg(i), v, u);
    let out =
        std::slice::from_raw_parts_mut(out_ptr.0.add(blk.col_start as usize), width);
    block_product_fold(u, width, out, fold);
}

/// The block-parallel executor body: a pool handle, per-lane scratch
/// and (for the ternary path) the minus-half temporary. Holds **no**
/// plan — callers pass borrowed [`FlatPlan`]s per execute, so the same
/// executor works for plan-owned arenas ([`ParallelRsrPlan`]) and
/// store-shared ones ([`crate::runtime::ExecutablePlan`]).
pub struct SharedParallelExec {
    pool: PoolHandle,
    scratch: Vec<LaneScratch>,
    tmp: Vec<f32>,
}

impl std::fmt::Debug for SharedParallelExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedParallelExec")
            .field("threads", &self.pool.threads())
            .finish()
    }
}

impl SharedParallelExec {
    /// An executor for plans needing at most `max_u` segmented sums per
    /// block and `cols` output columns (`cols` sizes the ternary
    /// temporary; pass 0 for binary-only use).
    pub fn new(pool: PoolHandle, max_u: usize, cols: usize) -> Self {
        let scratch = lanes(pool.threads(), max_u);
        Self { pool, scratch, tmp: vec![0.0; cols] }
    }

    /// Lanes of parallelism the checkout can use.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// `out = v · B`, blocks distributed across the pool.
    ///
    /// `plan` must need at most the `max_u` this executor was built
    /// with (callers construct the two together).
    pub fn execute(&mut self, plan: &FlatPlan, v: &[f32], out: &mut [f32]) -> Result<()> {
        check_shapes(plan.rows(), plan.cols(), v, out)?;
        debug_assert!(plan.max_u() <= self.scratch.first().map_or(0, |l|
            // SAFETY: construction-time read, no concurrent access.
            unsafe { (*l.0.get()).0.len() }));
        if plan.blocks().is_empty() {
            return Ok(());
        }
        let scratch = &self.scratch;
        let out_ptr = OutPtr(out.as_mut_ptr());
        self.pool.run(plan.blocks().len(), |w, i| {
            // SAFETY: chunk indices are unique (disjoint columns) and
            // worker lanes are unique; `out` outlives the call because
            // `run` blocks until every worker quiesces.
            unsafe { run_block(plan, v, out_ptr, scratch, w, i) };
        });
        Ok(())
    }

    /// `out = v · A = v·B⁽¹⁾ − v·B⁽²⁾`. Both halves are dispatched in a
    /// **single** pool generation — chunks `0..nb` run the plus half
    /// into `out`, chunks `nb..2·nb` run the minus half into the
    /// executor-owned `tmp` — followed by one vectorizable subtraction.
    /// No allocation on the execute path.
    pub fn execute_ternary(
        &mut self,
        plus: &FlatPlan,
        minus: &FlatPlan,
        v: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        check_shapes(plus.rows(), plus.cols(), v, out)?;
        check_shapes(minus.rows(), minus.cols(), v, &self.tmp)?;
        let nb_plus = plus.blocks().len();
        let chunks = nb_plus + minus.blocks().len();
        if chunks == 0 {
            return Ok(());
        }
        let scratch = &self.scratch;
        let out_ptr = OutPtr(out.as_mut_ptr());
        let tmp_ptr = OutPtr(self.tmp.as_mut_ptr());
        self.pool.run(chunks, |w, c| {
            // SAFETY: per half, chunk indices are unique and columns
            // disjoint; the two halves write to different buffers; lane
            // scratch is exclusive; both buffers outlive the call.
            unsafe {
                if c < nb_plus {
                    run_block(plus, v, out_ptr, scratch, w, c);
                } else {
                    run_block(minus, v, tmp_ptr, scratch, w, c - nb_plus);
                }
            }
        });
        for (o, t) in out.iter_mut().zip(self.tmp.iter()) {
            *o -= t;
        }
        Ok(())
    }
}

/// Resolve a `threads` request into a handle: `0` → the process-wide
/// shared pool; an explicit count → a dedicated pool of that size.
fn resolve_pool(threads: usize) -> PoolHandle {
    if threads == 0 {
        PoolHandle::global()
    } else {
        PoolHandle::new(threads)
    }
}

/// Parallel RSR++ plan: flat arena + the shared-pool executor.
pub struct ParallelRsrPlan {
    plan: FlatPlan,
    exec: SharedParallelExec,
}

impl std::fmt::Debug for ParallelRsrPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParallelRsrPlan")
            .field("rows", &self.plan.rows())
            .field("cols", &self.plan.cols())
            .field("threads", &self.exec.threads())
            .finish()
    }
}

impl ParallelRsrPlan {
    /// Build a parallel plan. `threads = 0` (the default above the
    /// kernel layer) checks the **process-wide** pool out per execute —
    /// no workers are spawned per plan; an explicit count spawns a
    /// dedicated pool here, once. `execute` never spawns.
    pub fn new(index: RsrIndex, threads: usize) -> Result<Self> {
        let plan = FlatPlan::from_index(&index)?;
        let exec = SharedParallelExec::new(resolve_pool(threads), plan.max_u(), 0);
        Ok(Self { plan, exec })
    }

    /// The underlying flat plan.
    pub fn flat(&self) -> &FlatPlan {
        &self.plan
    }

    /// Lanes of parallelism an execute can use.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// Index bytes held by this plan.
    pub fn index_bytes(&self) -> usize {
        self.plan.bytes()
    }

    /// `out = v · B`, blocks distributed across the pool.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        self.exec.execute(&self.plan, v, out)
    }
}

/// Parallel ternary plan (`A = B⁽¹⁾ − B⁽²⁾`). See
/// [`SharedParallelExec::execute_ternary`] for the dispatch shape.
pub struct ParallelTernaryRsrPlan {
    plan: TernaryFlatPlan,
    exec: SharedParallelExec,
}

impl std::fmt::Debug for ParallelTernaryRsrPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParallelTernaryRsrPlan")
            .field("rows", &self.plan.plus.rows())
            .field("cols", &self.plan.plus.cols())
            .field("threads", &self.exec.threads())
            .finish()
    }
}

impl ParallelTernaryRsrPlan {
    /// Build a ternary parallel plan; `threads` semantics as in
    /// [`ParallelRsrPlan::new`].
    pub fn new(index: TernaryRsrIndex, threads: usize) -> Result<Self> {
        let plan = TernaryFlatPlan::from_index(&index)?;
        let max_u = plan.plus.max_u().max(plan.minus.max_u());
        let exec =
            SharedParallelExec::new(resolve_pool(threads), max_u, plan.plus.cols());
        Ok(Self { plan, exec })
    }

    /// Lanes of parallelism an execute can use.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// `out = v · A`.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        self.exec.execute_ternary(&self.plan.plus, &self.plan.minus, v, out)
    }

    /// Index bytes across both Prop 2.1 halves.
    pub fn index_bytes(&self) -> usize {
        self.plan.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::binary::BinaryMatrix;
    use super::super::standard::standard_mul_binary;
    use super::super::ternary::TernaryMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn parallel_matches_standard_across_thread_counts() {
        let mut rng = Rng::new(107);
        let b = BinaryMatrix::random(256, 96, 0.5, &mut rng);
        let v = rng.f32_vec(256, -1.0, 1.0);
        let expect = standard_mul_binary(&v, &b);
        for threads in [1usize, 2, 4, 8] {
            let mut plan =
                ParallelRsrPlan::new(RsrIndex::preprocess(&b, 4), threads).unwrap();
            let mut out = vec![0.0; 96];
            // Repeated executes reuse the same pool generation machinery.
            for _ in 0..3 {
                plan.execute(&v, &mut out).unwrap();
                for (g, e) in out.iter().zip(expect.iter()) {
                    assert!((g - e).abs() < 1e-3, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn parallel_ternary_matches_standard() {
        use super::super::standard::standard_mul_ternary;
        let mut rng = Rng::new(109);
        let a = TernaryMatrix::random(128, 64, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(128, -1.0, 1.0);
        let mut plan = ParallelTernaryRsrPlan::new(
            TernaryRsrIndex::preprocess(&a, 4),
            3,
        )
        .unwrap();
        let expect = standard_mul_ternary(&v, &a);
        let mut out = vec![0.0; 64];
        for _ in 0..3 {
            plan.execute(&v, &mut out).unwrap();
            for (g, e) in out.iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn zero_threads_shares_the_global_pool() {
        let mut rng = Rng::new(113);
        let b = BinaryMatrix::random(32, 16, 0.5, &mut rng);
        let plan = ParallelRsrPlan::new(RsrIndex::preprocess(&b, 3), 0).unwrap();
        assert!(plan.threads() >= 1);
        // Two default-threaded plans report the same lane count — both
        // ride the one process-wide pool (no per-plan worker spawn).
        let plan2 = ParallelRsrPlan::new(RsrIndex::preprocess(&b, 3), 0).unwrap();
        assert_eq!(plan.threads(), plan2.threads());
    }

    #[test]
    fn concurrent_default_plans_stay_correct_under_contention() {
        // Several threads execute global-pool plans at once: whoever
        // loses the checkout runs serially, and every result must still
        // match the reference.
        let mut rng = Rng::new(127);
        let b = BinaryMatrix::random(96, 48, 0.5, &mut rng);
        let v = rng.f32_vec(96, -1.0, 1.0);
        let expect = standard_mul_binary(&v, &b);
        let idx = RsrIndex::preprocess(&b, 4);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let idx = idx.clone();
                let v = v.clone();
                std::thread::spawn(move || {
                    let mut plan = ParallelRsrPlan::new(idx, 0).unwrap();
                    let mut out = vec![0.0; 48];
                    for _ in 0..5 {
                        plan.execute(&v, &mut out).unwrap();
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            for (g, e) in out.iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3);
            }
        }
    }
}
