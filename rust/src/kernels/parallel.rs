//! Block-parallel execution (paper Appendix C.1.I).
//!
//! Column blocks are independent: block `i` reads all of `v` but writes
//! only columns `[iₛ, iₛ+width)` of the output. With `c` cores the
//! time drops to `O(n²/(c·log n))` for RSR++.
//!
//! The hot path is spawn-free and lock-free: a
//! [`PersistentPool`](crate::util::threadpool::PersistentPool) of
//! workers is built once per plan, every worker lane owns a
//! pre-allocated `u`/fold scratch slot, and the per-block output
//! ranges come straight from the flat-plan descriptors —
//! `(col_start, width)` are disjoint by construction (validated at
//! build), so each block writes its own output slice with no
//! synchronization at all. The previous implementation paid a
//! `thread::scope` spawn per worker per call, a `Vec` of output slices
//! and a `Mutex` lock per block.

use std::cell::UnsafeCell;

use super::flat::{segmented_sum_flat, FlatPlan, TernaryFlatPlan};
use super::index::{RsrIndex, TernaryRsrIndex};
use super::rsr::check_shapes;
use super::rsrpp::block_product_fold;
use crate::error::Result;
use crate::util::threadpool::PersistentPool;

/// One worker lane's `(u, fold)` scratch. Wrapped in an `UnsafeCell`
/// so the `Fn` closure handed to the pool can mutate it.
struct LaneScratch(UnsafeCell<(Vec<f32>, Vec<f32>)>);

// SAFETY: lane `w` is accessed only by the pool worker with index `w`
// (the pool guarantees worker indices are unique among concurrently
// running closure invocations), so no slot is ever aliased.
unsafe impl Sync for LaneScratch {}

impl LaneScratch {
    fn new(max_u: usize) -> Self {
        Self(UnsafeCell::new((vec![0.0; max_u], vec![0.0; max_u])))
    }
}

fn lanes(threads: usize, max_u: usize) -> Vec<LaneScratch> {
    (0..threads).map(|_| LaneScratch::new(max_u)).collect()
}

/// Raw output base pointer, sendable to pool workers. Each block writes
/// the disjoint `[col_start, col_start + width)` range.
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Execute one block of `plan` into `out_ptr` using lane `w`'s scratch.
///
/// # Safety
/// * `out_ptr` points at a live `[f32]` of length `plan.cols()`;
/// * no other concurrent invocation uses the same block index `i`
///   (disjoint output columns) or the same lane `w` (exclusive
///   scratch).
unsafe fn run_block(
    plan: &FlatPlan,
    v: &[f32],
    out_ptr: OutPtr,
    scratch: &[LaneScratch],
    w: usize,
    i: usize,
) {
    let blk = &plan.blocks()[i];
    let width = blk.width as usize;
    let (u, fold) = &mut *scratch[w].0.get();
    let u = &mut u[..1 << width];
    segmented_sum_flat(plan.block_sigma(i), plan.block_seg(i), v, u);
    let out =
        std::slice::from_raw_parts_mut(out_ptr.0.add(blk.col_start as usize), width);
    block_product_fold(u, width, out, fold);
}

/// Parallel RSR++ plan: flat arena + a persistent worker pool.
pub struct ParallelRsrPlan {
    plan: FlatPlan,
    pool: PersistentPool,
    scratch: Vec<LaneScratch>,
}

impl std::fmt::Debug for ParallelRsrPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParallelRsrPlan")
            .field("rows", &self.plan.rows())
            .field("cols", &self.plan.cols())
            .field("threads", &self.pool.threads())
            .finish()
    }
}

impl ParallelRsrPlan {
    /// Build with an explicit thread count (`0` → default). Workers are
    /// spawned here, once; `execute` never spawns. The pool is **owned
    /// by this plan** — threads beyond the block count would never get
    /// work, so the lane count is capped there; prefer the (shared,
    /// serial-per-thread) RSR++ backend when running many plans
    /// concurrently, or reuse one parallel plan per matrix.
    pub fn new(index: RsrIndex, threads: usize) -> Result<Self> {
        let plan = FlatPlan::from_index(&index)?;
        let threads = resolve_threads(threads).min(plan.blocks().len().max(1));
        let pool = PersistentPool::new(threads);
        let scratch = lanes(pool.threads(), plan.max_u());
        Ok(Self { plan, pool, scratch })
    }

    /// The underlying flat plan.
    pub fn flat(&self) -> &FlatPlan {
        &self.plan
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Index bytes held by this plan.
    pub fn index_bytes(&self) -> usize {
        self.plan.bytes()
    }

    /// `out = v · B`, blocks distributed across the persistent pool.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        check_shapes(self.plan.rows(), self.plan.cols(), v, out)?;
        if self.plan.blocks().is_empty() {
            return Ok(());
        }
        let plan = &self.plan;
        let scratch = &self.scratch;
        let out_ptr = OutPtr(out.as_mut_ptr());
        self.pool.run(plan.blocks().len(), |w, i| {
            // SAFETY: chunk indices are unique (disjoint columns) and
            // worker lanes are unique; `out` outlives the call because
            // `run` blocks until every worker quiesces.
            unsafe { run_block(plan, v, out_ptr, scratch, w, i) };
        });
        Ok(())
    }
}

/// Parallel ternary plan (`A = B⁽¹⁾ − B⁽²⁾`). Both halves are
/// dispatched in a **single** pool generation — chunks `0..nb` run the
/// plus half into `out`, chunks `nb..2·nb` run the minus half into the
/// plan-owned `tmp` — followed by one vectorizable subtraction. No
/// allocation on the execute path (the seed version allocated a
/// `cols`-sized `Vec` per call).
pub struct ParallelTernaryRsrPlan {
    plan: TernaryFlatPlan,
    pool: PersistentPool,
    scratch: Vec<LaneScratch>,
    tmp: Vec<f32>,
}

impl std::fmt::Debug for ParallelTernaryRsrPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParallelTernaryRsrPlan")
            .field("rows", &self.plan.plus.rows())
            .field("cols", &self.plan.plus.cols())
            .field("threads", &self.pool.threads())
            .finish()
    }
}

impl ParallelTernaryRsrPlan {
    /// Build with an explicit thread count (`0` → default). Lanes are
    /// capped at the total block count across both halves (see
    /// [`ParallelRsrPlan::new`] on pool ownership).
    pub fn new(index: TernaryRsrIndex, threads: usize) -> Result<Self> {
        let plan = TernaryFlatPlan::from_index(&index)?;
        let total_blocks = plan.plus.blocks().len() + plan.minus.blocks().len();
        let threads = resolve_threads(threads).min(total_blocks.max(1));
        let pool = PersistentPool::new(threads);
        let max_u = plan.plus.max_u().max(plan.minus.max_u());
        let scratch = lanes(pool.threads(), max_u);
        let tmp = vec![0.0; plan.plus.cols()];
        Ok(Self { plan, pool, scratch, tmp })
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// `out = v · A`.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        let (plus, minus) = (&self.plan.plus, &self.plan.minus);
        check_shapes(plus.rows(), plus.cols(), v, out)?;
        let nb_plus = plus.blocks().len();
        let chunks = nb_plus + minus.blocks().len();
        if chunks == 0 {
            return Ok(());
        }
        let scratch = &self.scratch;
        let out_ptr = OutPtr(out.as_mut_ptr());
        let tmp_ptr = OutPtr(self.tmp.as_mut_ptr());
        self.pool.run(chunks, |w, c| {
            // SAFETY: per half, chunk indices are unique and columns
            // disjoint; the two halves write to different buffers; lane
            // scratch is exclusive; both buffers outlive the call.
            unsafe {
                if c < nb_plus {
                    run_block(plus, v, out_ptr, scratch, w, c);
                } else {
                    run_block(minus, v, tmp_ptr, scratch, w, c - nb_plus);
                }
            }
        });
        for (o, t) in out.iter_mut().zip(self.tmp.iter()) {
            *o -= t;
        }
        Ok(())
    }

    /// Index bytes across both Prop 2.1 halves.
    pub fn index_bytes(&self) -> usize {
        self.plan.bytes()
    }
}

fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        crate::util::threadpool::default_threads()
    } else {
        threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::binary::BinaryMatrix;
    use super::super::standard::standard_mul_binary;
    use super::super::ternary::TernaryMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn parallel_matches_standard_across_thread_counts() {
        let mut rng = Rng::new(107);
        let b = BinaryMatrix::random(256, 96, 0.5, &mut rng);
        let v = rng.f32_vec(256, -1.0, 1.0);
        let expect = standard_mul_binary(&v, &b);
        for threads in [1usize, 2, 4, 8] {
            let mut plan =
                ParallelRsrPlan::new(RsrIndex::preprocess(&b, 4), threads).unwrap();
            let mut out = vec![0.0; 96];
            // Repeated executes reuse the same pool generation machinery.
            for _ in 0..3 {
                plan.execute(&v, &mut out).unwrap();
                for (g, e) in out.iter().zip(expect.iter()) {
                    assert!((g - e).abs() < 1e-3, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn parallel_ternary_matches_standard() {
        use super::super::standard::standard_mul_ternary;
        let mut rng = Rng::new(109);
        let a = TernaryMatrix::random(128, 64, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(128, -1.0, 1.0);
        let mut plan = ParallelTernaryRsrPlan::new(
            TernaryRsrIndex::preprocess(&a, 4),
            3,
        )
        .unwrap();
        let expect = standard_mul_ternary(&v, &a);
        let mut out = vec![0.0; 64];
        for _ in 0..3 {
            plan.execute(&v, &mut out).unwrap();
            for (g, e) in out.iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn zero_threads_uses_default() {
        let mut rng = Rng::new(113);
        let b = BinaryMatrix::random(32, 16, 0.5, &mut rng);
        let plan = ParallelRsrPlan::new(RsrIndex::preprocess(&b, 3), 0).unwrap();
        assert!(plan.threads() >= 1);
    }
}
