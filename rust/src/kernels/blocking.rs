//! Step 1 of preprocessing — *column blocking* (paper Def 3.1).
//!
//! `B` is split into `⌈m/k⌉` blocks of `k` consecutive columns; the last
//! block may be narrower ("ragged tail") when `k ∤ m`.

/// Geometry of one k-column block: which columns of `B` it covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColumnBlock {
    /// Index of this block (0-based; paper's `i − 1`).
    pub index: usize,
    /// First column covered (inclusive).
    pub col_start: usize,
    /// Number of columns covered (`k`, except possibly the tail).
    pub width: usize,
}

/// Enumerate the k-column blocks of an `_ × cols` matrix.
pub fn column_blocks(cols: usize, k: usize) -> Vec<ColumnBlock> {
    assert!(k >= 1, "block width must be at least 1");
    assert!(k <= 16, "block width > 16 would need >65536-entry segmentation lists");
    let mut out = Vec::with_capacity(cols.div_ceil(k));
    let mut col_start = 0;
    let mut index = 0;
    while col_start < cols {
        let width = k.min(cols - col_start);
        out.push(ColumnBlock { index, col_start, width });
        col_start += width;
        index += 1;
    }
    out
}

/// The number of blocks `⌈cols/k⌉`.
pub fn num_blocks(cols: usize, k: usize) -> usize {
    cols.div_ceil(k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_division() {
        let blocks = column_blocks(6, 2);
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[0], ColumnBlock { index: 0, col_start: 0, width: 2 });
        assert_eq!(blocks[2], ColumnBlock { index: 2, col_start: 4, width: 2 });
    }

    #[test]
    fn ragged_tail() {
        let blocks = column_blocks(7, 3);
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[2], ColumnBlock { index: 2, col_start: 6, width: 1 });
        assert_eq!(num_blocks(7, 3), 3);
    }

    #[test]
    fn k_larger_than_cols() {
        let blocks = column_blocks(3, 8);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].width, 3);
    }

    #[test]
    fn blocks_partition_all_columns() {
        for cols in [1usize, 5, 64, 100, 127] {
            for k in [1usize, 2, 3, 7, 8, 16] {
                let blocks = column_blocks(cols, k);
                let total: usize = blocks.iter().map(|b| b.width).sum();
                assert_eq!(total, cols, "cols={cols} k={k}");
                // contiguity
                let mut expect = 0;
                for b in &blocks {
                    assert_eq!(b.col_start, expect);
                    expect += b.width;
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least 1")]
    fn zero_k_rejected() {
        column_blocks(4, 0);
    }
}
