//! q-bit generalization (paper Appendix D.3).
//!
//! A q-bit signed integer weight matrix `W` (entries in
//! `[-(2^{q-1}-1), 2^{q-1}-1]`) decomposes into weighted binary planes
//! by applying Proposition 2.1 recursively: write each entry as
//! `w = Σ_b 2^b · t_b` with ternary digits `t_b ∈ {-1,0,1}` (the signed
//! bit planes of `|w|` carrying `sign(w)`), then each ternary plane
//! splits into two binary matrices. The product is
//!
//! `v·W = Σ_b 2^b · (v·B_b⁺ − v·B_b⁻)`
//!
//! — `2(q−1)` binary RSR++ multiplies, each `O(n²/log n)`, so the
//! generalization keeps the logarithmic advantage with a `2(q-1)`
//! constant, matching the paper's `2^{q-2}`-matrix sketch in spirit
//! while staying numerically exact.

use super::binary::BinaryMatrix;
use super::index::RsrIndex;
use super::rsrpp::RsrPlusPlusPlan;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// A q-bit signed integer matrix, row-major i32 storage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QbitMatrix {
    rows: usize,
    cols: usize,
    q: u32,
    data: Vec<i32>,
}

impl QbitMatrix {
    /// Build from a dense buffer, checking the q-bit range.
    pub fn from_dense(rows: usize, cols: usize, q: u32, data: Vec<i32>) -> Result<Self> {
        if !(2..=8).contains(&q) {
            return Err(Error::Config(format!("q={q} out of supported range 2..=8")));
        }
        let lim = (1i32 << (q - 1)) - 1;
        if data.len() != rows * cols {
            return Err(Error::ShapeMismatch("qbit buffer size".into()));
        }
        if data.iter().any(|&x| x.abs() > lim) {
            return Err(Error::Config(format!("entry exceeds q-bit limit {lim}")));
        }
        Ok(Self { rows, cols, q, data })
    }

    /// Uniform random entries over the full q-bit range.
    pub fn random(rows: usize, cols: usize, q: u32, rng: &mut Rng) -> Self {
        let lim = (1i32 << (q - 1)) - 1;
        let data = (0..rows * cols)
            .map(|_| rng.range(0, (2 * lim + 1) as usize) as i32 - lim)
            .collect();
        Self { rows, cols, q, data }
    }

    /// Rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Bit width.
    pub fn q(&self) -> u32 {
        self.q
    }

    /// Element access.
    pub fn get(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    /// Decompose into `(plane, B⁺, B⁻)` triples so that
    /// `W = Σ 2^plane (B⁺ − B⁻)`.
    pub fn planes(&self) -> Vec<(u32, BinaryMatrix, BinaryMatrix)> {
        let nplanes = self.q - 1;
        let mut out = Vec::with_capacity(nplanes as usize);
        for b in 0..nplanes {
            let mut plus = BinaryMatrix::zeros(self.rows, self.cols);
            let mut minus = BinaryMatrix::zeros(self.rows, self.cols);
            for r in 0..self.rows {
                for c in 0..self.cols {
                    let w = self.get(r, c);
                    if (w.unsigned_abs() >> b) & 1 == 1 {
                        if w > 0 {
                            plus.set(r, c, true);
                        } else {
                            minus.set(r, c, true);
                        }
                    }
                }
            }
            out.push((b, plus, minus));
        }
        out
    }

    /// Reference dense multiply (baseline and test oracle).
    pub fn standard_mul(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0f32; self.cols];
        for (r, &vr) in v.iter().enumerate() {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for (c, &w) in row.iter().enumerate() {
                out[c] += vr * w as f32;
            }
        }
        out
    }
}

/// Preprocessed q-bit RSR++ plan: one binary plan per signed bit plane.
pub struct QbitRsrPlan {
    planes: Vec<(u32, RsrPlusPlusPlan, RsrPlusPlusPlan)>,
    cols: usize,
    rows: usize,
}

impl QbitRsrPlan {
    /// Preprocess every plane with blocking parameter `k`.
    pub fn preprocess(w: &QbitMatrix, k: usize) -> Result<Self> {
        let planes = w
            .planes()
            .into_iter()
            .map(|(b, p, m)| {
                Ok((
                    b,
                    RsrPlusPlusPlan::new(RsrIndex::preprocess(&p, k))?,
                    RsrPlusPlusPlan::new(RsrIndex::preprocess(&m, k))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { planes, cols: w.cols(), rows: w.rows() })
    }

    /// `out = v · W` via per-plane RSR++.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        if v.len() != self.rows || out.len() != self.cols {
            return Err(Error::ShapeMismatch("qbit execute".into()));
        }
        out.fill(0.0);
        let mut tmp = vec![0.0f32; self.cols];
        for (bit, plus, minus) in self.planes.iter_mut() {
            let scale = (1u32 << *bit) as f32;
            plus.execute(v, &mut tmp)?;
            for (o, t) in out.iter_mut().zip(tmp.iter()) {
                *o += scale * t;
            }
            minus.execute(v, &mut tmp)?;
            for (o, t) in out.iter_mut().zip(tmp.iter()) {
                *o -= scale * t;
            }
        }
        Ok(())
    }

    /// Total index bytes across planes.
    pub fn bytes(&self) -> usize {
        self.planes
            .iter()
            .map(|(_, p, m)| p.index_bytes() + m.index_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planes_reconstruct_matrix() {
        let mut rng = Rng::new(149);
        for q in [2u32, 3, 4, 8] {
            let w = QbitMatrix::random(20, 15, q, &mut rng);
            let planes = w.planes();
            assert_eq!(planes.len(), (q - 1) as usize);
            for r in 0..20 {
                for c in 0..15 {
                    let recon: i32 = planes
                        .iter()
                        .map(|(b, p, m)| {
                            (1i32 << b) * (p.get(r, c) as i32 - m.get(r, c) as i32)
                        })
                        .sum();
                    assert_eq!(recon, w.get(r, c), "q={q} ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn qbit_rsr_matches_standard() {
        let mut rng = Rng::new(151);
        for q in [2u32, 4, 6] {
            let w = QbitMatrix::random(60, 40, q, &mut rng);
            let v = rng.f32_vec(60, -1.0, 1.0);
            let expect = w.standard_mul(&v);
            let mut plan = QbitRsrPlan::preprocess(&w, 4).unwrap();
            let mut out = vec![0.0; 40];
            plan.execute(&v, &mut out).unwrap();
            for (g, e) in out.iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-2 * (1.0 + e.abs()), "q={q}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn q2_is_exactly_ternary() {
        // q=2 gives entries in {-1,0,1} and a single plane pair.
        let mut rng = Rng::new(157);
        let w = QbitMatrix::random(10, 10, 2, &mut rng);
        assert_eq!(w.planes().len(), 1);
        assert!(w.data.iter().all(|&x| (-1..=1).contains(&x)));
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(QbitMatrix::from_dense(1, 1, 2, vec![2]).is_err());
        assert!(QbitMatrix::from_dense(1, 1, 9, vec![0]).is_err());
        assert!(QbitMatrix::from_dense(1, 2, 3, vec![3]).is_err());
        assert!(QbitMatrix::from_dense(1, 1, 3, vec![3]).is_ok());
    }
}
