//! Batched RSR: multiply a *batch* of activation vectors by one
//! preprocessed matrix — the serving-side shape (dynamic batcher output)
//! and the natural extension the paper's §C.1 parallelization implies.
//!
//! Per block, the segmented sums of all batch rows are computed in one
//! pass over the index: for each position, the gathered `v[σ(pos)]`
//! column is accumulated into `U[batch][segment]`. The index is read
//! **once per batch** instead of once per vector — at batch size `b`
//! the per-vector index traffic drops by `b×`, which is exactly why
//! batched serving amortizes RSR so well (EXPERIMENTS.md §Perf).

use super::index::{RsrIndex, TernaryRsrIndex};
use super::rsrpp::block_product_fold;
use crate::error::{Error, Result};

/// Batched RSR++ plan over a binary matrix.
#[derive(Debug, Clone)]
pub struct BatchedRsrPlan {
    index: RsrIndex,
    max_batch: usize,
    // Scratch: `U[b * 2^k + j]` segmented sums per batch row.
    u: Vec<f32>,
    fold: Vec<f32>,
}

impl BatchedRsrPlan {
    /// Build a plan for batches up to `max_batch` rows.
    pub fn new(index: RsrIndex, max_batch: usize) -> Result<Self> {
        index.validate()?;
        if max_batch == 0 {
            return Err(Error::Config("max_batch must be >= 1".into()));
        }
        let max_u = index.blocks.iter().map(|b| 1usize << b.width).max().unwrap_or(0);
        Ok(Self {
            index,
            max_batch,
            u: vec![0.0; max_batch * max_u],
            fold: vec![0.0; max_u],
        })
    }

    /// The underlying index.
    pub fn index(&self) -> &RsrIndex {
        &self.index
    }

    /// `out[b] = vs[b] · B` for every batch row.
    ///
    /// `vs` is row-major `batch × rows`; `out` is row-major
    /// `batch × cols`. `batch ≤ max_batch`.
    pub fn execute(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        let (n, m) = (self.index.rows, self.index.cols);
        if batch == 0 || batch > self.max_batch {
            return Err(Error::ShapeMismatch(format!(
                "batch {batch} outside 1..={}",
                self.max_batch
            )));
        }
        if vs.len() != batch * n {
            return Err(Error::ShapeMismatch(format!(
                "vs len {} != batch*rows {}",
                vs.len(),
                batch * n
            )));
        }
        if out.len() != batch * m {
            return Err(Error::ShapeMismatch(format!(
                "out len {} != batch*cols {}",
                out.len(),
                batch * m
            )));
        }

        for blk in &self.index.blocks {
            let w = blk.width as usize;
            let two_w = 1usize << w;
            let u = &mut self.u[..batch * two_w];
            u.fill(0.0);
            // One pass over the index; gather the whole batch column.
            for j in 0..two_w {
                let lo = blk.seg[j] as usize;
                let hi = blk.seg[j + 1] as usize;
                for &s in &blk.sigma[lo..hi] {
                    let s = s as usize;
                    for b in 0..batch {
                        u[b * two_w + j] += vs[b * n + s];
                    }
                }
            }
            // Fold each batch row's u into its output slice.
            let col = blk.col_start as usize;
            for b in 0..batch {
                let ub = &u[b * two_w..(b + 1) * two_w];
                let ob = &mut out[b * m + col..b * m + col + w];
                block_product_fold(ub, w, ob, &mut self.fold);
            }
        }
        Ok(())
    }
}

/// Batched ternary plan (both Prop 2.1 halves).
#[derive(Debug, Clone)]
pub struct BatchedTernaryRsrPlan {
    plus: BatchedRsrPlan,
    minus: BatchedRsrPlan,
    tmp: Vec<f32>,
}

impl BatchedTernaryRsrPlan {
    /// Build from a preprocessed ternary index.
    pub fn new(index: TernaryRsrIndex, max_batch: usize) -> Result<Self> {
        let cols = index.plus.cols;
        Ok(Self {
            plus: BatchedRsrPlan::new(index.plus, max_batch)?,
            minus: BatchedRsrPlan::new(index.minus, max_batch)?,
            tmp: vec![0.0; max_batch * cols],
        })
    }

    /// `out[b] = vs[b] · A` for every batch row.
    pub fn execute(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        self.plus.execute(vs, batch, out)?;
        let tmp = &mut self.tmp[..out.len()];
        self.minus.execute(vs, batch, tmp)?;
        for (o, t) in out.iter_mut().zip(tmp.iter()) {
            *o -= t;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::binary::BinaryMatrix;
    use super::super::standard::{standard_mul_binary, standard_mul_ternary};
    use super::super::ternary::TernaryMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn batched_matches_per_vector() {
        let mut rng = Rng::new(0xBA7);
        let (n, m, k, batch) = (96, 64, 5, 7);
        let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
        let vs: Vec<f32> = rng.f32_vec(batch * n, -1.0, 1.0);
        let mut plan = BatchedRsrPlan::new(RsrIndex::preprocess(&b, k), batch).unwrap();
        let mut out = vec![0.0; batch * m];
        plan.execute(&vs, batch, &mut out).unwrap();
        for bi in 0..batch {
            let expect = standard_mul_binary(&vs[bi * n..(bi + 1) * n], &b);
            for (g, e) in out[bi * m..(bi + 1) * m].iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()), "row {bi}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn batch_of_one_matches_unbatched_plan() {
        let mut rng = Rng::new(0xBA8);
        let b = BinaryMatrix::random(50, 30, 0.5, &mut rng);
        let v = rng.f32_vec(50, -1.0, 1.0);
        let idx = RsrIndex::preprocess(&b, 4);
        let mut batched = BatchedRsrPlan::new(idx.clone(), 1).unwrap();
        let mut single = super::super::rsrpp::RsrPlusPlusPlan::new(idx).unwrap();
        let mut o1 = vec![0.0; 30];
        let mut o2 = vec![0.0; 30];
        batched.execute(&v, 1, &mut o1).unwrap();
        single.execute(&v, &mut o2).unwrap();
        for (a, b) in o1.iter().zip(o2.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn ternary_batched_matches_standard() {
        let mut rng = Rng::new(0xBA9);
        let (n, m, batch) = (64, 48, 4);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let vs = rng.f32_vec(batch * n, -1.0, 1.0);
        let mut plan =
            BatchedTernaryRsrPlan::new(TernaryRsrIndex::preprocess(&a, 4), batch)
                .unwrap();
        let mut out = vec![0.0; batch * m];
        plan.execute(&vs, batch, &mut out).unwrap();
        for bi in 0..batch {
            let expect = standard_mul_ternary(&vs[bi * n..(bi + 1) * n], &a);
            for (g, e) in out[bi * m..(bi + 1) * m].iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()));
            }
        }
    }

    #[test]
    fn partial_batches_are_allowed() {
        let mut rng = Rng::new(0xBAA);
        let b = BinaryMatrix::random(20, 12, 0.5, &mut rng);
        let mut plan = BatchedRsrPlan::new(RsrIndex::preprocess(&b, 3), 8).unwrap();
        let vs = rng.f32_vec(3 * 20, -1.0, 1.0);
        let mut out = vec![0.0; 3 * 12];
        plan.execute(&vs, 3, &mut out).unwrap();
    }

    #[test]
    fn shape_errors_are_clean() {
        let mut rng = Rng::new(0xBAB);
        let b = BinaryMatrix::random(20, 12, 0.5, &mut rng);
        let mut plan = BatchedRsrPlan::new(RsrIndex::preprocess(&b, 3), 4).unwrap();
        let mut out = vec![0.0; 2 * 12];
        assert!(plan.execute(&[0.0; 40], 0, &mut out).is_err()); // batch 0
        assert!(plan.execute(&[0.0; 40], 5, &mut out).is_err()); // > max
        assert!(plan.execute(&[0.0; 39], 2, &mut out).is_err()); // bad vs
        assert!(plan.execute(&[0.0; 40], 2, &mut [0.0; 23]).is_err()); // bad out
        assert!(BatchedRsrPlan::new(RsrIndex::preprocess(&b, 3), 0).is_err());
    }
}
