//! Batched RSR: multiply a *batch* of activation vectors by one
//! preprocessed matrix — the serving-side shape (dynamic batcher output)
//! and the natural extension the paper's §C.1 parallelization implies.
//!
//! Per block, the segmented sums of all batch rows are computed in one
//! pass over the index, so the index is read **once per batch** instead
//! of once per vector — at batch size `b` the per-vector index traffic
//! drops by `b×`, which is exactly why batched serving amortizes RSR so
//! well (EXPERIMENTS.md §Perf).
//!
//! ## Layout
//!
//! Scratch is **segment-major interleaved**: `U[j·batch + b]` holds
//! segment `j` of batch row `b`. The activation batch is transposed
//! once per call into the same interleaving (`VT[s·batch + b]`), so the
//! innermost loop of the segmented sum is a contiguous `batch`-wide
//! vector add (`U[j·batch ..] += VT[s·batch ..]`) the compiler
//! autovectorizes — in the previous row-major layout it was a
//! `2^k`-strided scatter touching one float per cache line. The RSR++
//! fold then runs on the interleaved buffer directly: folding
//! `x'[m] = x[2m] + x[2m+1]` becomes a pair of contiguous `batch`-wide
//! adds per output value, and each emitted column is written (or, for
//! the ternary minus half, subtracted) straight into the caller's
//! output — the ternary path materializes no `batch × cols` temporary.

use super::flat::{FlatPlan, TernaryFlatPlan};
use super::index::{RsrIndex, TernaryRsrIndex};
use crate::error::{Error, Result};

/// How a batched fold emits its column into the output.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Emit {
    /// `out = value` (first / only Prop 2.1 half).
    Write,
    /// `out -= value` (the minus half of a ternary plan).
    Subtract,
}

/// Interleaved segmented sums for one block: for every segment `j`,
/// `u[j·batch + b] = Σ_{pos ∈ [L[j], L[j+1])} vt[σ(pos)·batch + b]`.
///
/// `vt` is the batch-interleaved activation transpose; the innermost
/// loop is a contiguous `batch`-wide add.
#[inline]
fn segmented_sum_interleaved(
    sigma: &[u32],
    seg: &[u32],
    vt: &[f32],
    batch: usize,
    u: &mut [f32],
) {
    let two_w = seg.len() - 1;
    debug_assert_eq!(u.len(), two_w * batch);
    debug_assert_eq!(vt.len() % batch, 0);
    for j in 0..two_w {
        let lo = seg[j] as usize;
        let hi = seg[j + 1] as usize;
        let uj = &mut u[j * batch..(j + 1) * batch];
        uj.fill(0.0);
        for &s in &sigma[lo..hi] {
            let row = &vt[s as usize * batch..s as usize * batch + batch];
            for (acc, &x) in uj.iter_mut().zip(row.iter()) {
                *acc += x;
            }
        }
    }
}

/// Batched RSR++ fold on the interleaved buffer: every fold level is a
/// contiguous `batch`-wide add, and each emitted column goes straight
/// into `out[b·out_stride + col_start + c]`.
///
/// `x` is consumed in place (`2^width · batch` floats); `odd` is
/// `batch` floats of scratch.
#[inline]
fn block_product_fold_interleaved(
    x: &mut [f32],
    width: usize,
    batch: usize,
    odd: &mut [f32],
    out: &mut [f32],
    out_stride: usize,
    col_start: usize,
    emit: Emit,
) {
    debug_assert!(x.len() >= (1usize << width) * batch);
    debug_assert_eq!(odd.len(), batch);
    let mut len = 1usize << width;
    // Columns are emitted LSB-first: c = width-1 down to 0.
    for c in (0..width).rev() {
        let half = len / 2;
        odd.fill(0.0);
        for m in 0..half {
            // Read both halves of the pair before writing: the write
            // row `m` never overlaps the read rows `2m`/`2m+1` except
            // at m = 0, where the reads of iteration 0 come first.
            for b in 0..batch {
                let a = x[2 * m * batch + b];
                let bb = x[(2 * m + 1) * batch + b];
                odd[b] += bb;
                x[m * batch + b] = a + bb;
            }
        }
        let col = col_start + c;
        match emit {
            Emit::Write => {
                for b in 0..batch {
                    out[b * out_stride + col] = odd[b];
                }
            }
            Emit::Subtract => {
                for b in 0..batch {
                    out[b * out_stride + col] -= odd[b];
                }
            }
        }
        len = half;
    }
}

/// Scratch shared by the binary and ternary batched plans.
#[derive(Debug, Clone)]
struct BatchScratch {
    /// Interleaved segmented sums, `max_batch · max_u`.
    u: Vec<f32>,
    /// Batch-interleaved activation transpose, `max_batch · rows`.
    vt: Vec<f32>,
    /// Per-level odd-lane sums, `max_batch`.
    odd: Vec<f32>,
}

impl BatchScratch {
    fn new(max_batch: usize, rows: usize, max_u: usize) -> Self {
        let mut s = Self { u: Vec::new(), vt: Vec::new(), odd: Vec::new() };
        s.ensure(max_batch, rows, max_u);
        s
    }

    /// Grow the buffers to serve `max_batch` rows of a plan with `rows`
    /// input length and `max_u` segmented sums per block. No-op when
    /// already large enough — called per execute so one executor can
    /// follow a growing slot count (and serve differently-shaped plans)
    /// without reallocation churn.
    fn ensure(&mut self, max_batch: usize, rows: usize, max_u: usize) {
        if self.u.len() < max_batch * max_u {
            self.u.resize(max_batch * max_u, 0.0);
        }
        if self.vt.len() < max_batch * rows {
            self.vt.resize(max_batch * rows, 0.0);
        }
        if self.odd.len() < max_batch {
            self.odd.resize(max_batch, 0.0);
        }
    }

    /// Transpose the row-major `batch × rows` activations into the
    /// interleaved `vt[s·batch + b]` form.
    fn transpose_into(&mut self, vs: &[f32], batch: usize, rows: usize) {
        let vt = &mut self.vt[..batch * rows];
        for b in 0..batch {
            let row = &vs[b * rows..(b + 1) * rows];
            for (s, &x) in row.iter().enumerate() {
                vt[s * batch + b] = x;
            }
        }
    }
}

fn check_batch_shapes(
    rows: usize,
    cols: usize,
    max_batch: usize,
    vs: &[f32],
    batch: usize,
    out: &[f32],
) -> Result<()> {
    if batch == 0 || batch > max_batch {
        return Err(Error::ShapeMismatch(format!(
            "batch {batch} outside 1..={max_batch}"
        )));
    }
    if vs.len() != batch * rows {
        return Err(Error::ShapeMismatch(format!(
            "vs len {} != batch*rows {}",
            vs.len(),
            batch * rows
        )));
    }
    if out.len() != batch * cols {
        return Err(Error::ShapeMismatch(format!(
            "out len {} != batch*cols {}",
            out.len(),
            batch * cols
        )));
    }
    Ok(())
}

/// Run one flat plan's blocks over the interleaved batch, emitting into
/// `out` per [`Emit`].
#[inline]
fn execute_batched_flat(
    plan: &FlatPlan,
    scratch: &mut BatchScratch,
    batch: usize,
    out: &mut [f32],
    emit: Emit,
) {
    let cols = plan.cols();
    let vt = &scratch.vt[..batch * plan.rows()];
    for (i, blk) in plan.blocks().iter().enumerate() {
        let w = blk.width as usize;
        let two_w = 1usize << w;
        let u = &mut scratch.u[..two_w * batch];
        segmented_sum_interleaved(plan.block_sigma(i), plan.block_seg(i), vt, batch, u);
        block_product_fold_interleaved(
            u,
            w,
            batch,
            &mut scratch.odd[..batch],
            out,
            cols,
            blk.col_start as usize,
            emit,
        );
    }
}

/// The batched executor body: transpose + interleaved scratch, holding
/// **no** plan of its own. Callers pass borrowed [`FlatPlan`]s per
/// execute, so the same executor drives plan-owned arenas
/// ([`BatchedRsrPlan`] / [`BatchedTernaryRsrPlan`]) and store-shared
/// ones ([`crate::runtime::ExecutablePlan`]).
#[derive(Debug, Clone)]
pub struct BatchedExec {
    max_batch: usize,
    scratch: BatchScratch,
}

impl BatchedExec {
    /// An executor for plans with `rows` input length needing at most
    /// `max_u` segmented sums per block, serving batches up to
    /// `max_batch`.
    pub fn new(rows: usize, max_u: usize, max_batch: usize) -> Result<Self> {
        if max_batch == 0 {
            return Err(Error::Config("max_batch must be >= 1".into()));
        }
        Ok(Self { max_batch, scratch: BatchScratch::new(max_batch, rows, max_u) })
    }

    /// Largest batch this executor accepts.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Raise the accepted batch ceiling to at least `batch`. Continuous
    /// batching admits sequences into free slots mid-flight, so the
    /// live-slot count an executor sees can grow after construction;
    /// buffers grow lazily on the next execute.
    pub fn ensure_batch(&mut self, batch: usize) {
        if batch > self.max_batch {
            self.max_batch = batch;
        }
    }

    /// `out[b] = vs[b] · B` for every batch row (row-major `batch×rows`
    /// in, `batch×cols` out, `batch ≤ max_batch`).
    pub fn execute(
        &mut self,
        plan: &FlatPlan,
        vs: &[f32],
        batch: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let (n, m) = (plan.rows(), plan.cols());
        check_batch_shapes(n, m, self.max_batch, vs, batch, out)?;
        self.scratch.ensure(self.max_batch, n, plan.max_u());
        self.scratch.transpose_into(vs, batch, n);
        execute_batched_flat(plan, &mut self.scratch, batch, out, Emit::Write);
        Ok(())
    }

    /// `out[b] = vs[b] · A` for every batch row. The minus half is
    /// subtracted directly into `out` block by block — no `batch × cols`
    /// temporary exists anywhere in the ternary batched path.
    pub fn execute_ternary(
        &mut self,
        plus: &FlatPlan,
        minus: &FlatPlan,
        vs: &[f32],
        batch: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let (n, m) = (plus.rows(), plus.cols());
        check_batch_shapes(n, m, self.max_batch, vs, batch, out)?;
        check_batch_shapes(minus.rows(), minus.cols(), self.max_batch, vs, batch, out)?;
        self.scratch.ensure(self.max_batch, n, plus.max_u().max(minus.max_u()));
        self.scratch.transpose_into(vs, batch, n);
        execute_batched_flat(plus, &mut self.scratch, batch, out, Emit::Write);
        execute_batched_flat(minus, &mut self.scratch, batch, out, Emit::Subtract);
        Ok(())
    }
}

/// Batched RSR++ plan over a binary matrix.
#[derive(Debug, Clone)]
pub struct BatchedRsrPlan {
    plan: FlatPlan,
    exec: BatchedExec,
}

impl BatchedRsrPlan {
    /// Build a plan for batches up to `max_batch` rows.
    pub fn new(index: RsrIndex, max_batch: usize) -> Result<Self> {
        let plan = FlatPlan::from_index(&index)?;
        let exec = BatchedExec::new(plan.rows(), plan.max_u(), max_batch)?;
        Ok(Self { plan, exec })
    }

    /// The underlying flat plan.
    pub fn flat(&self) -> &FlatPlan {
        &self.plan
    }

    /// `out[b] = vs[b] · B` for every batch row.
    ///
    /// `vs` is row-major `batch × rows`; `out` is row-major
    /// `batch × cols`. `batch ≤ max_batch`.
    pub fn execute(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        self.exec.execute(&self.plan, vs, batch, out)
    }
}

/// Batched ternary plan (both Prop 2.1 halves). See
/// [`BatchedExec::execute_ternary`] for the emit order.
#[derive(Debug, Clone)]
pub struct BatchedTernaryRsrPlan {
    plan: TernaryFlatPlan,
    exec: BatchedExec,
}

impl BatchedTernaryRsrPlan {
    /// Build from a preprocessed ternary index.
    pub fn new(index: TernaryRsrIndex, max_batch: usize) -> Result<Self> {
        let plan = TernaryFlatPlan::from_index(&index)?;
        let max_u = plan.plus.max_u().max(plan.minus.max_u());
        let exec = BatchedExec::new(plan.plus.rows(), max_u, max_batch)?;
        Ok(Self { plan, exec })
    }

    /// `out[b] = vs[b] · A` for every batch row.
    pub fn execute(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        self.exec.execute_ternary(&self.plan.plus, &self.plan.minus, vs, batch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::binary::BinaryMatrix;
    use super::super::standard::{standard_mul_binary, standard_mul_ternary};
    use super::super::ternary::TernaryMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn batched_matches_per_vector() {
        let mut rng = Rng::new(0xBA7);
        let (n, m, k, batch) = (96, 64, 5, 7);
        let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
        let vs: Vec<f32> = rng.f32_vec(batch * n, -1.0, 1.0);
        let mut plan = BatchedRsrPlan::new(RsrIndex::preprocess(&b, k), batch).unwrap();
        let mut out = vec![0.0; batch * m];
        plan.execute(&vs, batch, &mut out).unwrap();
        for bi in 0..batch {
            let expect = standard_mul_binary(&vs[bi * n..(bi + 1) * n], &b);
            for (g, e) in out[bi * m..(bi + 1) * m].iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()), "row {bi}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn batch_of_one_matches_unbatched_plan() {
        let mut rng = Rng::new(0xBA8);
        let b = BinaryMatrix::random(50, 30, 0.5, &mut rng);
        let v = rng.f32_vec(50, -1.0, 1.0);
        let idx = RsrIndex::preprocess(&b, 4);
        let mut batched = BatchedRsrPlan::new(idx.clone(), 1).unwrap();
        let mut single = super::super::rsrpp::RsrPlusPlusPlan::new(idx).unwrap();
        let mut o1 = vec![0.0; 30];
        let mut o2 = vec![0.0; 30];
        batched.execute(&v, 1, &mut o1).unwrap();
        single.execute(&v, &mut o2).unwrap();
        for (a, b) in o1.iter().zip(o2.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn ternary_batched_matches_standard() {
        let mut rng = Rng::new(0xBA9);
        let (n, m, batch) = (64, 48, 4);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let vs = rng.f32_vec(batch * n, -1.0, 1.0);
        let mut plan =
            BatchedTernaryRsrPlan::new(TernaryRsrIndex::preprocess(&a, 4), batch)
                .unwrap();
        let mut out = vec![0.0; batch * m];
        plan.execute(&vs, batch, &mut out).unwrap();
        for bi in 0..batch {
            let expect = standard_mul_ternary(&vs[bi * n..(bi + 1) * n], &a);
            for (g, e) in out[bi * m..(bi + 1) * m].iter().zip(expect.iter()) {
                assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()));
            }
        }
    }

    #[test]
    fn ternary_batched_overwrites_stale_output() {
        // `out` is written, not accumulated: garbage in the output
        // buffer must not survive (the minus half subtracts in place,
        // so this guards the Write-then-Subtract emit order).
        let mut rng = Rng::new(0xBAC);
        let (n, m, batch) = (40, 24, 3);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let vs = rng.f32_vec(batch * n, -1.0, 1.0);
        let mut plan =
            BatchedTernaryRsrPlan::new(TernaryRsrIndex::preprocess(&a, 3), batch)
                .unwrap();
        let mut clean = vec![0.0; batch * m];
        plan.execute(&vs, batch, &mut clean).unwrap();
        let mut dirty = vec![1e6; batch * m];
        plan.execute(&vs, batch, &mut dirty).unwrap();
        assert_eq!(clean, dirty);
    }

    #[test]
    fn partial_batches_are_allowed() {
        let mut rng = Rng::new(0xBAA);
        let b = BinaryMatrix::random(20, 12, 0.5, &mut rng);
        let mut plan = BatchedRsrPlan::new(RsrIndex::preprocess(&b, 3), 8).unwrap();
        let vs = rng.f32_vec(3 * 20, -1.0, 1.0);
        let mut out = vec![0.0; 3 * 12];
        plan.execute(&vs, 3, &mut out).unwrap();
    }

    #[test]
    fn ensure_batch_grows_a_live_executor() {
        // Continuous batching admits sequences mid-flight: an executor
        // built for 2 rows must serve 5 after ensure_batch, with
        // results identical to a fresh full-size plan.
        let mut rng = Rng::new(0xBAD);
        let (n, m, big) = (48, 36, 5);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let vs = rng.f32_vec(big * n, -1.0, 1.0);
        let idx = TernaryRsrIndex::preprocess(&a, 4);
        let mut grown = BatchedTernaryRsrPlan::new(idx.clone(), 2).unwrap();
        let mut small = vec![0.0; 2 * m];
        grown.execute(&vs[..2 * n], 2, &mut small).unwrap();
        assert!(grown.execute(&vs, big, &mut vec![0.0; big * m]).is_err());
        grown.exec.ensure_batch(big);
        let mut out = vec![0.0; big * m];
        grown.execute(&vs, big, &mut out).unwrap();
        let mut fresh = BatchedTernaryRsrPlan::new(idx, big).unwrap();
        let mut expect = vec![0.0; big * m];
        fresh.execute(&vs, big, &mut expect).unwrap();
        assert_eq!(out, expect);
    }

    #[test]
    fn row_results_are_independent_of_batch_size() {
        // Per row, the interleaved kernel performs the identical f32
        // addition sequence at every batch size — the invariant that
        // makes continuous batching's ragged batches safe: a sequence's
        // output never changes when batchmates join or retire.
        let mut rng = Rng::new(0xBAE);
        let (n, m) = (56, 40);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let vs = rng.f32_vec(4 * n, -1.0, 1.0);
        let idx = TernaryRsrIndex::preprocess(&a, 4);
        let mut plan = BatchedTernaryRsrPlan::new(idx, 4).unwrap();
        let mut full = vec![0.0; 4 * m];
        plan.execute(&vs, 4, &mut full).unwrap();
        for bi in 0..4 {
            let mut solo = vec![0.0; m];
            plan.execute(&vs[bi * n..(bi + 1) * n], 1, &mut solo).unwrap();
            assert_eq!(
                &full[bi * m..(bi + 1) * m],
                &solo[..],
                "row {bi} must be bit-identical alone and in a batch"
            );
        }
    }

    #[test]
    fn shape_errors_are_clean() {
        let mut rng = Rng::new(0xBAB);
        let b = BinaryMatrix::random(20, 12, 0.5, &mut rng);
        let mut plan = BatchedRsrPlan::new(RsrIndex::preprocess(&b, 3), 4).unwrap();
        let mut out = vec![0.0; 2 * 12];
        assert!(plan.execute(&[0.0; 40], 0, &mut out).is_err()); // batch 0
        assert!(plan.execute(&[0.0; 40], 5, &mut out).is_err()); // > max
        assert!(plan.execute(&[0.0; 39], 2, &mut out).is_err()); // bad vs
        assert!(plan.execute(&[0.0; 40], 2, &mut [0.0; 23]).is_err()); // bad out
        assert!(BatchedRsrPlan::new(RsrIndex::preprocess(&b, 3), 0).is_err());
    }
}
