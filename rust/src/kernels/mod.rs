//! The paper's core contribution: preprocessing binary/ternary weight
//! matrices into *block indices* (per-column-block row permutations and
//! full segmentation lists) and the RSR / RSR++ inference algorithms
//! that multiply an activation vector by the preprocessed matrix in
//! `O(n²/log n)` instead of `O(n²)`.
//!
//! Pipeline (paper §3–§4):
//!
//! ```text
//!   TernaryMatrix ──decompose (Prop 2.1)──► (B⁽¹⁾, B⁽²⁾) binary
//!   BinaryMatrix ──┬─ blocking (Def 3.1)      k-column blocks
//!                  ├─ permutation (Def 3.2)   binary row order σᵢ
//!                  └─ segmentation (Def 3.4)  full segmentation Lᵢ
//!                                │
//!                        RsrIndex (σᵢ, Lᵢ per block)
//!                                │
//!   v ∈ Rⁿ ──► segmented sum (Eq 5) ──► u·Bin_[k]  ──► v·B
//!                   O(n)/block         RSR: O(k·2ᵏ)
//!                                      RSR++: O(2ᵏ)   (Alg 3)
//! ```
//!
//! Backends beyond the paper's two algorithms:
//! * [`standard`] — the dense baselines RSR is measured against,
//! * [`parallel`] — block-parallel execution (paper Appendix C.1.I),
//! * [`tensorized`] — the one-hot-matrix formulation used for the GPU
//!   path (paper Appendix C.1.II / E.2–E.3),
//! * [`qbit`] — the q-bit generalization (paper Appendix D.3),
//! * [`tl`] — precomputed table-lookup execution (Bitnet.cpp-style
//!   TL kernels; see PAPERS.md), grouped 2-bit codes + per-group
//!   partial-sum tables.
//!
//! Because the weight matrices are fixed, preprocessing is a one-time
//! cost: indices can be persisted to versioned, checksummed `.rsrz`
//! plan artifacts ([`artifact`]) and shared across processes and
//! threads through [`crate::runtime::PlanStore`]
//! (compile once, serve many).

pub mod artifact;
pub mod batched;
pub mod binary;
pub mod blocking;
pub mod flat;
pub mod fused;
pub mod index;
pub mod optimal_k;
pub mod parallel;
pub mod permutation;
pub mod qbit;
pub mod rsr;
pub mod rsrpp;
pub mod segmentation;
pub mod standard;
pub mod tensorized;
pub mod ternary;
pub mod tl;

pub use artifact::{ArtifactKind, ArtifactMeta, ArtifactPayload, PlanArtifact};
pub use binary::BinaryMatrix;
pub use flat::{FlatBlock, FlatPlan, TernaryFlatPlan};
pub use index::{BinMatrix, BlockIndex, RsrIndex, TernaryRsrIndex};
pub use rsr::{rsr_mul, RsrPlan};
pub use rsrpp::{rsrpp_mul, RsrPlusPlusPlan};
pub use ternary::TernaryMatrix;
pub use tl::{tl_neon_available, tl_simd_available, TlPlan, TL_GROUP};

/// Which algorithm executes a preprocessed multiply.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Naive dense `O(n²)` multiply over i8 weights (paper's "Standard").
    Standard,
    /// Dense multiply over the bit-packed binary pair (stronger baseline).
    StandardPacked,
    /// Algorithm 2 (segmented sums + `u·Bin_[k]` as a dense product).
    Rsr,
    /// Algorithm 2 with Algorithm 3 as the step-2 subroutine.
    RsrPlusPlus,
    /// RSR++ with blocks executed across threads (Appendix C.1.I).
    RsrParallel,
    /// One-hot tensorized form (Appendix E.2); the GPU-path analog.
    Tensorized,
    /// Fused ternary hot path: shared scatter pass over both Prop 2.1
    /// halves + a single fold (§Perf; see [`fused`]).
    RsrFused,
}

impl Backend {
    /// All backends, for sweeps in tests and benches.
    pub const ALL: [Backend; 7] = [
        Backend::Standard,
        Backend::StandardPacked,
        Backend::Rsr,
        Backend::RsrPlusPlus,
        Backend::RsrParallel,
        Backend::Tensorized,
        Backend::RsrFused,
    ];

    /// Short stable name used by the CLI and bench reports.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Standard => "standard",
            Backend::StandardPacked => "standard-packed",
            Backend::Rsr => "rsr",
            Backend::RsrPlusPlus => "rsr++",
            Backend::RsrParallel => "rsr-parallel",
            Backend::Tensorized => "tensorized",
            Backend::RsrFused => "rsr-fused",
        }
    }

    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Option<Backend> {
        Backend::ALL.iter().copied().find(|b| b.name() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_round_trip() {
        for b in Backend::ALL {
            assert_eq!(Backend::from_name(b.name()), Some(b));
        }
        assert_eq!(Backend::from_name("nope"), None);
    }
}
