//! Ternary matrices `A ∈ {-1,0,1}^{n×m}` and the binary decomposition
//! of Proposition 2.1: `A = B⁽¹⁾ − B⁽²⁾` with `B⁽¹⁾ = [A == 1]` and
//! `B⁽²⁾ = [A == -1]`.
//!
//! The decomposition is what carries the paper's binary-matrix results
//! over to 1.58-bit networks: `v·A = v·B⁽¹⁾ − v·B⁽²⁾`, so two RSR
//! indices give the ternary multiply in the same `O(n²/log n)` time —
//! at twice the constant, which the fused backend
//! ([`crate::kernels::fused`]) and the shared plans of
//! [`crate::runtime::PlanStore`] both exploit.

use super::binary::BinaryMatrix;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// A ternary matrix stored as i8 (−1, 0, 1), row-major. A 2-bit packed
/// form is available for storage accounting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TernaryMatrix {
    rows: usize,
    cols: usize,
    data: Vec<i8>,
}

impl TernaryMatrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    /// Build from a row-major i8 buffer of −1/0/1 values.
    pub fn from_dense(rows: usize, cols: usize, data: Vec<i8>) -> Self {
        assert_eq!(data.len(), rows * cols, "dense buffer size mismatch");
        assert!(
            data.iter().all(|&x| (-1..=1).contains(&x)),
            "values must be in {{-1,0,1}}"
        );
        Self { rows, cols, data }
    }

    /// Uniform random ternary matrix: `P(-1) = P(1) = p`, `P(0) = 1−2p`.
    /// `p = 1/3` gives the uniform distribution over {−1,0,1}.
    pub fn random(rows: usize, cols: usize, p: f64, rng: &mut Rng) -> Self {
        assert!(p <= 0.5);
        let data = (0..rows * cols)
            .map(|_| {
                let x = rng.next_f64();
                if x < p {
                    1i8
                } else if x < 2.0 * p {
                    -1i8
                } else {
                    0i8
                }
            })
            .collect();
        Self { rows, cols, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read element `(r, c)`.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        self.data[r * self.cols + c]
    }

    /// Write element `(r, c)`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i8) {
        debug_assert!((-1..=1).contains(&v));
        self.data[r * self.cols + c] = v;
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Raw buffer.
    pub fn data(&self) -> &[i8] {
        &self.data
    }

    /// Proposition 2.1: decompose into `(B⁽¹⁾, B⁽²⁾)` with
    /// `A = B⁽¹⁾ − B⁽²⁾`.
    pub fn decompose(&self) -> (BinaryMatrix, BinaryMatrix) {
        let mut plus = BinaryMatrix::zeros(self.rows, self.cols);
        let mut minus = BinaryMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            for (c, &v) in row.iter().enumerate() {
                match v {
                    1 => plus.set(r, c, true),
                    -1 => minus.set(r, c, true),
                    _ => {}
                }
            }
        }
        (plus, minus)
    }

    /// Bytes of the i8 dense representation.
    pub fn dense_bytes(&self) -> usize {
        self.data.len()
    }

    /// Bytes of a 2-bit packed representation (4 entries/byte) — the
    /// most compact raw form, used as the honest baseline in Fig 5.
    pub fn packed2_bytes(&self) -> usize {
        self.data.len().div_ceil(4)
    }

    /// Pack into 2-bit codes (00=0, 01=+1, 10=−1), row-major.
    pub fn pack2(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.packed2_bytes()];
        for (i, &v) in self.data.iter().enumerate() {
            let code: u8 = match v {
                0 => 0b00,
                1 => 0b01,
                -1 => 0b10,
                _ => unreachable!(),
            };
            out[i / 4] |= code << ((i % 4) * 2);
        }
        out
    }

    /// Inverse of [`pack2`](Self::pack2).
    ///
    /// A short buffer or the reserved code `0b11` is a decode error,
    /// not a panic — `.rtw` weight loading feeds untrusted bytes
    /// through here, and a corrupt input must not abort a serving
    /// process.
    pub fn unpack2(rows: usize, cols: usize, packed: &[u8]) -> Result<Self> {
        let n = rows * cols;
        if packed.len() < n.div_ceil(4) {
            return Err(Error::InvalidModel(format!(
                "packed ternary buffer too small: {} bytes for {rows}x{cols}",
                packed.len()
            )));
        }
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            data.push(match (packed[i / 4] >> ((i % 4) * 2)) & 0b11 {
                0b00 => 0i8,
                0b01 => 1i8,
                0b10 => -1i8,
                _ => {
                    return Err(Error::InvalidModel(format!(
                        "invalid ternary code 0b11 at entry {i}"
                    )))
                }
            });
        }
        Ok(Self { rows, cols, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TernaryMatrix {
        TernaryMatrix::from_dense(
            2,
            3,
            vec![1, 0, -1, /* row 1 */ -1, 1, 0],
        )
    }

    #[test]
    fn decompose_satisfies_prop_2_1() {
        let a = sample();
        let (p, m) = a.decompose();
        for r in 0..2 {
            for c in 0..3 {
                let diff = p.get(r, c) as i8 - m.get(r, c) as i8;
                assert_eq!(diff, a.get(r, c), "({r},{c})");
                // B1 and B2 are never both 1.
                assert!(!(p.get(r, c) && m.get(r, c)));
            }
        }
    }

    #[test]
    fn pack2_roundtrip() {
        let mut rng = Rng::new(17);
        let a = TernaryMatrix::random(13, 29, 1.0 / 3.0, &mut rng);
        let packed = a.pack2();
        assert_eq!(packed.len(), a.packed2_bytes());
        let b = TernaryMatrix::unpack2(13, 29, &packed).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn unpack2_rejects_corrupt_input_without_panicking() {
        let mut rng = Rng::new(19);
        let a = TernaryMatrix::random(8, 8, 1.0 / 3.0, &mut rng);
        let mut packed = a.pack2();
        // The reserved code 0b11 → decode error, not a panic.
        packed[3] |= 0b11;
        let err = TernaryMatrix::unpack2(8, 8, &packed).unwrap_err();
        assert!(err.to_string().contains("invalid ternary code"), "{err}");
        // Truncated buffer → decode error.
        let short = &a.pack2()[..a.packed2_bytes() - 1];
        assert!(TernaryMatrix::unpack2(8, 8, short).is_err());
    }

    #[test]
    fn random_distribution_is_plausible() {
        let mut rng = Rng::new(23);
        let a = TernaryMatrix::random(100, 100, 1.0 / 3.0, &mut rng);
        let pos = a.data().iter().filter(|&&x| x == 1).count();
        let neg = a.data().iter().filter(|&&x| x == -1).count();
        let zero = a.data().iter().filter(|&&x| x == 0).count();
        for count in [pos, neg, zero] {
            assert!((2800..3900).contains(&count), "count {count}");
        }
    }

    #[test]
    #[should_panic(expected = "values must be in")]
    fn from_dense_rejects_out_of_range() {
        TernaryMatrix::from_dense(1, 1, vec![2]);
    }
}
