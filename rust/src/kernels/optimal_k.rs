//! Choosing the blocking parameter `k` (paper §4.2.2 / §4.3.2, App F.1).
//!
//! The analytic cost models are
//!
//! * RSR (Eq 6):   `cost(k) = (n/k)·(n + k·2^k)`
//! * RSR++ (Eq 7): `cost(k) = (n/k)·(n + 2^k)`
//!
//! both unimodal in `k` over the practical range, so the paper's binary
//! search applies; we also expose a plain argmin over the (tiny) range
//! `1..=⌊log₂ n⌋` and an *empirical* timer-driven search used by the
//! App F.1 reproduction.

/// Analytic RSR cost model (Eq 6), in abstract operations.
pub fn rsr_cost(n: usize, k: usize) -> f64 {
    let n = n as f64;
    let kf = k as f64;
    (n / kf) * (n + kf * (1u64 << k) as f64)
}

/// Analytic RSR++ cost model (Eq 7).
pub fn rsrpp_cost(n: usize, k: usize) -> f64 {
    let n = n as f64;
    let kf = k as f64;
    (n / kf) * (n + (1u64 << k) as f64)
}

/// Upper end of the k search range: `⌊log₂ n⌋`, capped at 16 (the
/// segmentation list is `2^k + 1` entries).
pub fn k_max(n: usize) -> usize {
    ((usize::BITS - 1 - n.leading_zeros()) as usize).clamp(1, 16)
}

/// Argmin of a unimodal cost model over `1..=k_max(n)` via ternary-style
/// narrowing (the paper's "binary search on k"); falls back to a scan —
/// the range never exceeds 16 values so both are exact and instant.
fn argmin_cost(n: usize, cost: impl Fn(usize, usize) -> f64) -> usize {
    (1..=k_max(n))
        .min_by(|&a, &b| cost(n, a).partial_cmp(&cost(n, b)).unwrap())
        .unwrap_or(1)
}

/// Analytic `k_opt` for RSR (Eq 6).
pub fn optimal_k_rsr(n: usize) -> usize {
    argmin_cost(n, rsr_cost)
}

/// Analytic `k_opt` for RSR++ (Eq 7).
pub fn optimal_k_rsrpp(n: usize) -> usize {
    argmin_cost(n, rsrpp_cost)
}

/// Candidate window for the empirical autotuner: every `k` within
/// `radius` of the analytic RSR++ optimum, widened to also contain the
/// analytic RSR optimum (the two models disagree by a log-log factor,
/// and the RSR backend's best `k` is usually smaller), clamped to the
/// valid `1..=k_max(n)` range. Sorted ascending, deduplicated.
///
/// The analytic models (Eq 6/7) count abstract operations; on real
/// hardware the winner shifts with cache sizes, gather throughput and
/// the n×m shape, which is exactly why `rsr tune` measures this window
/// instead of trusting the argmin.
pub fn k_candidates(n: usize, radius: usize) -> Vec<usize> {
    let hi_end = k_max(n);
    let center_pp = optimal_k_rsrpp(n);
    let center_r = optimal_k_rsr(n);
    let lo = center_pp.saturating_sub(radius).min(center_r).max(1);
    let hi = (center_pp + radius).max(center_r).min(hi_end);
    (lo..=hi).collect()
}

/// Empirical `k_opt`: time the given runner at every `k` in range and
/// return `(k_opt, times_ms)` — this regenerates App F.1 / Fig 9.
///
/// `run(k)` must execute one full multiply with blocking parameter `k`.
pub fn empirical_k_sweep(
    n: usize,
    reps: usize,
    mut run: impl FnMut(usize),
) -> (usize, Vec<(usize, f64)>) {
    let mut results = Vec::new();
    for k in 1..=k_max(n) {
        // warmup
        run(k);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            run(k);
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        results.push((k, ms));
    }
    let k_opt = results
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|&(k, _)| k)
        .unwrap_or(1);
    (k_opt, results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_max_is_floor_log2() {
        assert_eq!(k_max(2), 1);
        assert_eq!(k_max(1024), 10);
        assert_eq!(k_max(4096), 12);
        assert_eq!(k_max(1 << 16), 16);
        assert_eq!(k_max(1 << 20), 16); // capped
    }

    #[test]
    fn optimal_k_grows_with_n() {
        // Paper Fig 9: larger n → larger k_opt.
        let ks: Vec<usize> = [1 << 8, 1 << 10, 1 << 12, 1 << 14, 1 << 16]
            .iter()
            .map(|&n| optimal_k_rsrpp(n))
            .collect();
        for w in ks.windows(2) {
            assert!(w[0] <= w[1], "k_opt must be non-decreasing: {ks:?}");
        }
        assert!(ks[0] < ks[4]);
    }

    #[test]
    fn rsrpp_opt_k_at_least_rsr_opt_k() {
        // RSR++'s cheaper step 2 tolerates larger k (log n vs
        // log(n/log n)).
        for n in [1 << 10, 1 << 12, 1 << 14] {
            assert!(optimal_k_rsrpp(n) >= optimal_k_rsr(n));
        }
    }

    #[test]
    fn cost_models_match_theory_at_canonical_k() {
        // At k = log(n): RSR++ cost = (n/log n)(n + n) = 2n²/log n.
        let n = 1 << 12;
        let k = 12;
        let c = rsrpp_cost(n, k);
        let expect = 2.0 * (n as f64) * (n as f64) / 12.0;
        assert!((c - expect).abs() < 1e-6 * expect);
    }

    #[test]
    fn analytic_argmin_is_global_min() {
        for n in [64usize, 1 << 10, 1 << 13] {
            let k = optimal_k_rsr(n);
            for other in 1..=k_max(n) {
                assert!(rsr_cost(n, k) <= rsr_cost(n, other));
            }
            let kpp = optimal_k_rsrpp(n);
            for other in 1..=k_max(n) {
                assert!(rsrpp_cost(n, kpp) <= rsrpp_cost(n, other));
            }
        }
    }

    #[test]
    fn k_candidates_window_contains_both_analytic_optima() {
        for n in [64usize, 1 << 10, 1 << 12, 1 << 16] {
            for radius in [0usize, 1, 2, 4] {
                let c = k_candidates(n, radius);
                assert!(!c.is_empty());
                assert!(c.contains(&optimal_k_rsrpp(n)), "n={n} r={radius}: {c:?}");
                assert!(c.contains(&optimal_k_rsr(n)), "n={n} r={radius}: {c:?}");
                assert!(c.windows(2).all(|w| w[0] < w[1]), "sorted+dedup: {c:?}");
                assert!(*c.first().unwrap() >= 1);
                assert!(*c.last().unwrap() <= k_max(n));
            }
        }
        // Tiny n: window degenerates but stays valid.
        assert_eq!(k_candidates(2, 4), vec![1]);
    }

    #[test]
    fn empirical_sweep_returns_all_ks() {
        let n = 256;
        let (k_opt, times) = empirical_k_sweep(n, 1, |_k| {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(times.len(), k_max(n));
        assert!(k_opt >= 1 && k_opt <= k_max(n));
    }
}
