//! The **flat plan** — the contiguous, cache-streamable execution form
//! of an [`RsrIndex`].
//!
//! [`RsrIndex`] is the *preprocessing* output (paper Algorithm 1): one
//! [`BlockIndex`](super::index::BlockIndex) per k-column block, each
//! owning its own `sigma`/`seg` heap allocations. That shape is right
//! for building, validating and serializing, but wrong for executing:
//! a single `v·B` walks `2·⌈m/k⌉` scattered `Vec`s, so the prefetcher
//! restarts at every block boundary and the per-block descriptors are
//! spread across the heap.
//!
//! A [`FlatPlan`] lays the same data out CSR-style in **two arenas**:
//!
//! ```text
//!   sigma_all: [ σ₀ (rows) | σ₁ (rows) | … | σ_{nb−1} (rows) ]
//!   seg_all:   [ L₀ (2^w₀+1) | L₁ (2^w₁+1) | … | L_{nb−1} ]
//!   blocks:    [ (col_start, width, sigma_off, seg_off) … ]   (16 B each)
//! ```
//!
//! Execution streams the two arenas front to back — exactly the access
//! pattern hardware prefetchers reward — and the kernels on top are
//! written for instruction-level parallelism: segmented sums gather
//! with four independent accumulators (or an AVX2 `vgatherdps` path
//! selected once at runtime), and the RSR++ fold is a pairwise loop
//! the compiler can autovectorize
//! ([`block_product_fold`](super::rsrpp::block_product_fold)).
//!
//! A `FlatPlan` validates every structural invariant at construction
//! ([`FlatPlan::from_index`] / [`FlatPlan::from_arena`]) and is
//! immutable afterwards, so the bounds-check-free kernels may trust it.
//! Every executing plan type — [`super::rsr::RsrPlan`],
//! [`super::rsrpp::RsrPlusPlusPlan`], the batched/parallel plans and
//! [`crate::runtime::SharedRsrPlan`] — is a thin wrapper around one.

use super::blocking::column_blocks;
use super::index::{RsrIndex, TernaryRsrIndex};
use super::permutation::is_permutation;
use super::rsrpp::block_product_fold;
use super::segmentation::validate as validate_seg;
use crate::error::{Error, Result};

/// Descriptor of one k-column block inside the arenas: 16 bytes, so a
/// whole plan's geometry fits in a couple of cache lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlatBlock {
    /// First output column this block covers.
    pub col_start: u32,
    /// Block width (`k`, or less for the ragged tail).
    pub width: u32,
    /// Offset of this block's `σ` in `sigma_all` (always `i · rows`).
    pub sigma_off: u32,
    /// Offset of this block's `L` in `seg_all`.
    pub seg_off: u32,
}

/// The contiguous execution form of one binary matrix's RSR index:
/// two arenas plus per-block descriptors. See the module docs for the
/// layout.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatPlan {
    rows: usize,
    cols: usize,
    k: usize,
    sigma_all: Vec<u32>,
    seg_all: Vec<u32>,
    blocks: Vec<FlatBlock>,
    max_u: usize,
}

impl FlatPlan {
    /// Build (and validate) a flat plan from a preprocessed index.
    /// The index's per-block `Vec`s are copied once into the arenas;
    /// the index itself can be dropped afterwards.
    pub fn from_index(index: &RsrIndex) -> Result<Self> {
        index.validate()?;
        let nb = index.blocks.len();
        let sigma_len = nb * index.rows;
        let seg_len: usize =
            index.blocks.iter().map(|b| (1usize << b.width) + 1).sum();
        check_arena_offsets(sigma_len, seg_len)?;
        let mut sigma_all = Vec::with_capacity(sigma_len);
        let mut seg_all = Vec::with_capacity(seg_len);
        let mut blocks = Vec::with_capacity(nb);
        for blk in &index.blocks {
            blocks.push(FlatBlock {
                col_start: blk.col_start,
                width: blk.width,
                sigma_off: sigma_all.len() as u32,
                seg_off: seg_all.len() as u32,
            });
            sigma_all.extend_from_slice(&blk.sigma);
            seg_all.extend_from_slice(&blk.seg);
        }
        let max_u =
            index.blocks.iter().map(|b| 1usize << b.width).max().unwrap_or(0);
        Ok(Self {
            rows: index.rows,
            cols: index.cols,
            k: index.k,
            sigma_all,
            seg_all,
            blocks,
            max_u,
        })
    }

    /// Build (and validate) a flat plan directly from raw arenas — the
    /// `.rsrz` v2 load path: block geometry is derived from
    /// `(cols, k)`, then every block's `σ`/`L` slice is checked exactly
    /// as [`RsrIndex::validate`] would.
    pub fn from_arena(
        rows: usize,
        cols: usize,
        k: usize,
        sigma_all: Vec<u32>,
        seg_all: Vec<u32>,
    ) -> Result<Self> {
        if k == 0 || k > 16 {
            return Err(Error::InvalidIndex(format!("bad blocking parameter k={k}")));
        }
        let geom = column_blocks(cols, k);
        let expect_sigma = geom.len() * rows;
        let expect_seg: usize = geom.iter().map(|cb| (1usize << cb.width) + 1).sum();
        if sigma_all.len() != expect_sigma || seg_all.len() != expect_seg {
            return Err(Error::InvalidIndex(format!(
                "arena sizes {}+{} do not match geometry ({expect_sigma}+{expect_seg})",
                sigma_all.len(),
                seg_all.len()
            )));
        }
        check_arena_offsets(expect_sigma, expect_seg)?;
        let mut blocks = Vec::with_capacity(geom.len());
        let (mut so, mut go) = (0usize, 0usize);
        let mut max_u = 0usize;
        for cb in &geom {
            let two_w = 1usize << cb.width;
            if !is_permutation(&sigma_all[so..so + rows], rows) {
                return Err(Error::InvalidIndex(format!(
                    "sigma at col {} is not a permutation",
                    cb.col_start
                )));
            }
            validate_seg(&seg_all[go..go + two_w + 1], cb.width, rows)
                .map_err(Error::InvalidIndex)?;
            blocks.push(FlatBlock {
                col_start: cb.col_start as u32,
                width: cb.width as u32,
                sigma_off: so as u32,
                seg_off: go as u32,
            });
            so += rows;
            go += two_w + 1;
            max_u = max_u.max(two_w);
        }
        Ok(Self { rows, cols, k, sigma_all, seg_all, blocks, max_u })
    }

    /// Rows of the planned matrix (`n`, the activation length).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns of the planned matrix (`m`, the output length).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Blocking parameter the index was preprocessed with.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Per-block descriptors, in column order.
    #[inline]
    pub fn blocks(&self) -> &[FlatBlock] {
        &self.blocks
    }

    /// The permutation arena (every block's `σ`, concatenated).
    #[inline]
    pub fn sigma_all(&self) -> &[u32] {
        &self.sigma_all
    }

    /// The segmentation arena (every block's `L`, concatenated).
    #[inline]
    pub fn seg_all(&self) -> &[u32] {
        &self.seg_all
    }

    /// Largest `2^width` across blocks — the `u` scratch size every
    /// executor needs.
    #[inline]
    pub fn max_u(&self) -> usize {
        self.max_u
    }

    /// Block `i`'s permutation slice (`rows` entries).
    #[inline]
    pub fn block_sigma(&self, i: usize) -> &[u32] {
        let off = self.blocks[i].sigma_off as usize;
        &self.sigma_all[off..off + self.rows]
    }

    /// Block `i`'s full segmentation slice (`2^width + 1` entries).
    #[inline]
    pub fn block_seg(&self, i: usize) -> &[u32] {
        let blk = &self.blocks[i];
        let off = blk.seg_off as usize;
        &self.seg_all[off..off + (1usize << blk.width) + 1]
    }

    /// Heap bytes the plan occupies (arenas + descriptors) — the Fig 5
    /// "after preprocessing" number at the execution layer.
    pub fn bytes(&self) -> usize {
        (self.sigma_all.len() + self.seg_all.len()) * 4
            + self.blocks.len() * std::mem::size_of::<FlatBlock>()
            + 4 * 4
    }

    /// Reconstruct the boxed-per-block index form (serialization of
    /// `.rsi`, debugging, tests).
    pub fn to_index(&self) -> RsrIndex {
        let blocks = (0..self.blocks.len())
            .map(|i| super::index::BlockIndex {
                col_start: self.blocks[i].col_start,
                width: self.blocks[i].width,
                sigma: self.block_sigma(i).to_vec(),
                seg: self.block_seg(i).to_vec(),
            })
            .collect();
        RsrIndex { rows: self.rows, cols: self.cols, k: self.k, blocks }
    }
}

/// Arena offsets are stored as `u32` in [`FlatBlock`]; with dimensions
/// capped at `2^20` a plan can theoretically exceed that, so refuse to
/// build one we could not address. (`.rsrz` payload caps reject such
/// sizes long before this.)
fn check_arena_offsets(sigma_len: usize, seg_len: usize) -> Result<()> {
    if sigma_len > u32::MAX as usize || seg_len > u32::MAX as usize {
        return Err(Error::InvalidIndex(format!(
            "index too large for flat-plan u32 offsets ({sigma_len} sigma entries)"
        )));
    }
    Ok(())
}

/// Flat plan pair for a ternary matrix (`A = B⁽¹⁾ − B⁽²⁾`, Prop 2.1).
#[derive(Debug, Clone, PartialEq)]
pub struct TernaryFlatPlan {
    /// Plan of `B⁽¹⁾ = [A == +1]`.
    pub plus: FlatPlan,
    /// Plan of `B⁽²⁾ = [A == −1]`.
    pub minus: FlatPlan,
}

impl TernaryFlatPlan {
    /// Build from a preprocessed ternary index pair.
    pub fn from_index(index: &TernaryRsrIndex) -> Result<Self> {
        let plan = Self {
            plus: FlatPlan::from_index(&index.plus)?,
            minus: FlatPlan::from_index(&index.minus)?,
        };
        plan.check_geometry()?;
        Ok(plan)
    }

    /// Both halves must share `(rows, cols, k)` — the batched/parallel
    /// ternary executors walk their blocks in lockstep.
    pub fn check_geometry(&self) -> Result<()> {
        let (p, m) = (&self.plus, &self.minus);
        if p.rows != m.rows || p.cols != m.cols || p.k != m.k {
            return Err(Error::InvalidIndex(
                "ternary halves disagree on geometry".into(),
            ));
        }
        Ok(())
    }

    /// Heap bytes across both halves.
    pub fn bytes(&self) -> usize {
        self.plus.bytes() + self.minus.bytes()
    }
}

// ---------------------------------------------------------------------------
// Gather kernels (segmented sums over the arena)
// ---------------------------------------------------------------------------

/// Gather-sum `Σ v[idx[_]]` with four independent accumulators, so the
/// loads and adds overlap instead of forming one serial `acc +=` chain.
///
/// # Safety
/// Every entry of `idx` must be `< v.len()`. Plan executors get this
/// for free: their `idx` is a sub-slice of a validated permutation of
/// `0..rows` and shapes are checked before the hot loop.
#[inline]
pub unsafe fn gather_sum_scalar(idx: &[u32], v: &[f32]) -> f32 {
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let mut chunks = idx.chunks_exact(4);
    // SAFETY: see the contract above; `c` has exactly 4 entries.
    unsafe {
        for c in &mut chunks {
            acc0 += *v.get_unchecked(*c.get_unchecked(0) as usize);
            acc1 += *v.get_unchecked(*c.get_unchecked(1) as usize);
            acc2 += *v.get_unchecked(*c.get_unchecked(2) as usize);
            acc3 += *v.get_unchecked(*c.get_unchecked(3) as usize);
        }
        for &s in chunks.remainder() {
            acc0 += *v.get_unchecked(s as usize);
        }
    }
    (acc0 + acc1) + (acc2 + acc3)
}

/// AVX2 gather-sum: two in-flight `vgatherdps` streams (16 floats per
/// iteration), horizontal reduction at the end, scalar tail.
///
/// # Safety
/// Caller must ensure AVX2 is available **and** every `idx` entry is
/// `< v.len()` (same contract as [`gather_sum_scalar`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gather_sum_avx2(idx: &[u32], v: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = idx.len();
    let p = idx.as_ptr();
    let base = v.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let ix0 = _mm256_loadu_si256(p.add(i) as *const __m256i);
        let ix1 = _mm256_loadu_si256(p.add(i + 8) as *const __m256i);
        acc0 = _mm256_add_ps(acc0, _mm256_i32gather_ps::<4>(base, ix0));
        acc1 = _mm256_add_ps(acc1, _mm256_i32gather_ps::<4>(base, ix1));
        i += 16;
    }
    if i + 8 <= n {
        let ix = _mm256_loadu_si256(p.add(i) as *const __m256i);
        acc0 = _mm256_add_ps(acc0, _mm256_i32gather_ps::<4>(base, ix));
        i += 8;
    }
    let acc = _mm256_add_ps(acc0, acc1);
    // Horizontal sum of the 8 lanes (SSE-level shuffles).
    let lo = _mm256_castps256_ps128(acc);
    let hi = _mm256_extractf128_ps::<1>(acc);
    let sum4 = _mm_add_ps(lo, hi);
    let sum2 = _mm_add_ps(sum4, _mm_movehl_ps(sum4, sum4));
    let sum1 = _mm_add_ss(sum2, _mm_shuffle_ps::<0b01>(sum2, sum2));
    let mut total = _mm_cvtss_f32(sum1);
    while i < n {
        total += *v.get_unchecked(*p.add(i) as usize);
        i += 1;
    }
    total
}

/// Segments shorter than this stay on the scalar path even when AVX2
/// is available — a `vgatherdps` setup + horizontal reduction does not
/// pay for itself on a handful of elements.
#[cfg(target_arch = "x86_64")]
const AVX2_MIN_GATHER: usize = 16;

/// Whether the AVX2 gather path is usable, detected once per process.
#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static STATE: AtomicU8 = AtomicU8::new(0);
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let ok = is_x86_feature_detected!("avx2");
            STATE.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
            ok
        }
    }
}

/// Whether the dispatched gather path ([`gather_sum`]) can take the
/// AVX2 route on this host — i.e. whether the `rsr++` and
/// `rsr++-scalar` tuning candidates can differ. Also feeds the machine
/// fingerprint of `.rsrt` tuning profiles
/// ([`crate::tune::profile::MachineFingerprint`]).
pub fn simd_gather_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        avx2_available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Gather-sum with runtime SIMD dispatch: AVX2 `vgatherdps` on x86-64
/// CPUs that have it (for segments long enough to amortize the setup),
/// the 4-accumulator scalar kernel everywhere else. Results differ
/// from the scalar path only by f32 re-association.
///
/// # Safety
/// Same contract as [`gather_sum_scalar`]: every `idx` entry `< v.len()`.
#[inline]
pub unsafe fn gather_sum(idx: &[u32], v: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if idx.len() >= AVX2_MIN_GATHER && avx2_available() {
            // SAFETY: AVX2 presence checked; index bounds per contract.
            return unsafe { gather_sum_avx2(idx, v) };
        }
    }
    // SAFETY: forwarded contract.
    unsafe { gather_sum_scalar(idx, v) }
}

/// Segmented sums over one block's arena slices (paper Eq 5 on the
/// flat layout): `u[j] = Σ_{pos ∈ [L[j], L[j+1])} v[σ(pos)]`.
///
/// # Safety
/// `sigma`/`seg` must be the matching [`FlatPlan::block_sigma`] /
/// [`FlatPlan::block_seg`] slices of a **validated** plan and
/// `v.len()` must equal that plan's `rows()` — the gather kernels skip
/// bounds checks under that contract. (Out-of-range `seg` values would
/// already panic on the safe `sigma[lo..hi]` slicing.)
#[inline]
pub unsafe fn segmented_sum_flat(sigma: &[u32], seg: &[u32], v: &[f32], u: &mut [f32]) {
    debug_assert_eq!(u.len() + 1, seg.len());
    debug_assert_eq!(*seg.last().unwrap() as usize, sigma.len());
    for j in 0..u.len() {
        let lo = seg[j] as usize;
        let hi = seg[j + 1] as usize;
        // SAFETY: forwarded contract (sigma entries < rows == v.len()).
        u[j] = unsafe { gather_sum(&sigma[lo..hi], v) };
    }
}

/// [`segmented_sum_flat`] pinned to the scalar kernel — the reference
/// the dispatch-path property tests compare against, and the only path
/// on non-x86 targets.
///
/// # Safety
/// Same contract as [`segmented_sum_flat`].
#[inline]
pub unsafe fn segmented_sum_flat_scalar(sigma: &[u32], seg: &[u32], v: &[f32], u: &mut [f32]) {
    debug_assert_eq!(u.len() + 1, seg.len());
    for j in 0..u.len() {
        let lo = seg[j] as usize;
        let hi = seg[j + 1] as usize;
        // SAFETY: forwarded contract (sigma entries < rows == v.len()).
        u[j] = unsafe { gather_sum_scalar(&sigma[lo..hi], v) };
    }
}

/// The shared RSR++ hot loop over a flat plan: segmented sums + fold
/// per block. Both the owned [`super::rsrpp::RsrPlusPlusPlan`] and the
/// store-shared [`crate::runtime::SharedRsrPlan`] call this, so their
/// outputs are bit-identical by construction.
///
/// `u` and `fold` must each hold at least [`FlatPlan::max_u`] floats;
/// shapes of `v`/`out` are the caller's contract.
#[inline]
pub(crate) fn execute_rsrpp_flat(
    plan: &FlatPlan,
    v: &[f32],
    out: &mut [f32],
    u: &mut [f32],
    fold: &mut [f32],
) {
    // A hard check (not debug-only): it makes the unchecked gathers
    // below sound regardless of the caller, and costs one comparison
    // per execute.
    assert_eq!(v.len(), plan.rows(), "activation length must match plan rows");
    for (i, blk) in plan.blocks.iter().enumerate() {
        let w = blk.width as usize;
        let u = &mut u[..1 << w];
        // SAFETY: the slices come from a validated plan and
        // v.len() == rows was just asserted.
        unsafe { segmented_sum_flat(plan.block_sigma(i), plan.block_seg(i), v, u) };
        let col = blk.col_start as usize;
        block_product_fold(u, w, &mut out[col..col + w], fold);
    }
}

/// [`execute_rsrpp_flat`] pinned to the scalar gather kernel — the
/// `rsr++-scalar` candidate of the autotuner (on machines where the
/// AVX2 gather loses to the 4-accumulator scalar loop, the tuned
/// profile selects this path explicitly).
#[inline]
pub(crate) fn execute_rsrpp_flat_scalar(
    plan: &FlatPlan,
    v: &[f32],
    out: &mut [f32],
    u: &mut [f32],
    fold: &mut [f32],
) {
    assert_eq!(v.len(), plan.rows(), "activation length must match plan rows");
    for (i, blk) in plan.blocks.iter().enumerate() {
        let w = blk.width as usize;
        let u = &mut u[..1 << w];
        // SAFETY: the slices come from a validated plan and
        // v.len() == rows was just asserted.
        unsafe {
            segmented_sum_flat_scalar(plan.block_sigma(i), plan.block_seg(i), v, u)
        };
        let col = blk.col_start as usize;
        block_product_fold(u, w, &mut out[col..col + w], fold);
    }
}

/// The RSR (Algorithm 2) hot loop over a flat plan: segmented sums +
/// **dense** step-2 block product (`O(k·2^k)` instead of the fold's
/// `O(2^k)`). [`super::rsr::RsrPlan`] and the tuned runtime path both
/// call this, so their outputs are bit-identical by construction.
#[inline]
pub(crate) fn execute_rsr_flat(plan: &FlatPlan, v: &[f32], out: &mut [f32], u: &mut [f32]) {
    assert_eq!(v.len(), plan.rows(), "activation length must match plan rows");
    for (i, blk) in plan.blocks.iter().enumerate() {
        let w = blk.width as usize;
        let u = &mut u[..1 << w];
        // SAFETY: the slices come from a validated plan and
        // v.len() == rows was just asserted.
        unsafe { segmented_sum_flat(plan.block_sigma(i), plan.block_seg(i), v, u) };
        let col = blk.col_start as usize;
        super::rsr::block_product_dense(u, w, &mut out[col..col + w]);
    }
}

#[cfg(test)]
mod tests {
    use super::super::binary::BinaryMatrix;
    use super::super::rsr::segmented_sum;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn flat_plan_mirrors_index() {
        let mut rng = Rng::new(2024);
        let b = BinaryMatrix::random(97, 50, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 5);
        let flat = FlatPlan::from_index(&idx).unwrap();
        assert_eq!(flat.rows(), 97);
        assert_eq!(flat.cols(), 50);
        assert_eq!(flat.blocks().len(), idx.blocks.len());
        for (i, blk) in idx.blocks.iter().enumerate() {
            assert_eq!(flat.block_sigma(i), &blk.sigma[..]);
            assert_eq!(flat.block_seg(i), &blk.seg[..]);
            assert_eq!(flat.blocks()[i].col_start, blk.col_start);
            assert_eq!(flat.blocks()[i].width, blk.width);
        }
        assert_eq!(flat.to_index(), idx);
    }

    #[test]
    fn from_arena_round_trips_and_validates() {
        let mut rng = Rng::new(2025);
        let b = BinaryMatrix::random(64, 30, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 4);
        let flat = FlatPlan::from_index(&idx).unwrap();
        let back = FlatPlan::from_arena(
            64,
            30,
            4,
            flat.sigma_all().to_vec(),
            flat.seg_all().to_vec(),
        )
        .unwrap();
        assert_eq!(back, flat);
        // Corrupt a sigma entry into a duplicate → rejected.
        let mut bad = flat.sigma_all().to_vec();
        bad[0] = bad[1];
        assert!(FlatPlan::from_arena(64, 30, 4, bad, flat.seg_all().to_vec()).is_err());
        // Wrong arena length → rejected.
        assert!(FlatPlan::from_arena(
            64,
            30,
            4,
            flat.sigma_all()[1..].to_vec(),
            flat.seg_all().to_vec()
        )
        .is_err());
    }

    #[test]
    fn flat_segmented_sums_match_checked_reference() {
        let mut rng = Rng::new(2026);
        for (n, m, k) in [(100, 30, 4), (97, 61, 7), (33, 5, 3)] {
            let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
            let idx = RsrIndex::preprocess(&b, k);
            let flat = FlatPlan::from_index(&idx).unwrap();
            let v = rng.f32_vec(n, -1.0, 1.0);
            for (i, blk) in idx.blocks.iter().enumerate() {
                let two_w = 1usize << blk.width;
                let mut expect = vec![0.0f32; two_w];
                segmented_sum(blk, &v, &mut expect);
                let mut scalar = vec![0.0f32; two_w];
                // SAFETY: slices of a validated plan; v.len() == rows.
                unsafe {
                    segmented_sum_flat_scalar(
                        flat.block_sigma(i),
                        flat.block_seg(i),
                        &v,
                        &mut scalar,
                    );
                }
                let mut dispatched = vec![0.0f32; two_w];
                // SAFETY: as above.
                unsafe {
                    segmented_sum_flat(
                        flat.block_sigma(i),
                        flat.block_seg(i),
                        &v,
                        &mut dispatched,
                    );
                }
                for j in 0..two_w {
                    let tol = 1e-4 * (1.0 + expect[j].abs());
                    assert!((scalar[j] - expect[j]).abs() <= tol);
                    assert!((dispatched[j] - expect[j]).abs() <= tol);
                }
            }
        }
    }

    #[test]
    fn gather_sum_handles_all_lengths() {
        // Cross the 4-wide scalar unroll and the 8/16-wide AVX2 widths.
        let mut rng = Rng::new(2027);
        let v = rng.f32_vec(256, -1.0, 1.0);
        for len in 0..=67usize {
            let idx: Vec<u32> = (0..len).map(|i| ((i * 37) % 256) as u32).collect();
            let expect: f64 = idx.iter().map(|&s| v[s as usize] as f64).sum();
            // SAFETY: every index is < 256 == v.len() by construction.
            for got in [unsafe { gather_sum_scalar(&idx, &v) }, unsafe { gather_sum(&idx, &v) }] {
                assert!(
                    (got as f64 - expect).abs() <= 1e-4 * (1.0 + expect.abs()),
                    "len {len}: {got} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn ternary_flat_plan_geometry_checked() {
        use super::super::ternary::TernaryMatrix;
        let mut rng = Rng::new(2028);
        let a = TernaryMatrix::random(40, 24, 1.0 / 3.0, &mut rng);
        let idx = TernaryRsrIndex::preprocess(&a, 3);
        let t = TernaryFlatPlan::from_index(&idx).unwrap();
        assert!(t.bytes() > 0);
        t.check_geometry().unwrap();
    }
}
