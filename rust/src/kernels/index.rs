//! The *block index* — the output of preprocessing (paper Algorithm 1)
//! and the only thing the inference algorithms need. Replacing the
//! weight matrix with its index is what yields the `O(n²/log n)` space
//! bound of Theorem 3.6 and the Fig 5 memory numbers; executing
//! against it is what yields the `O(n²/log n)` time bound of
//! Theorem 4.4 (see [`super::rsr`] / [`super::rsrpp`]).
//!
//! Because the weights of a trained binary/ternary network are fixed,
//! an index is built **once** per matrix and reused for every
//! inference — in memory via [`crate::runtime::PlanStore`], or across
//! processes via the `.rsrz` artifacts of [`super::artifact`]. The
//! `.rsi` stream format here is the raw-index building block the
//! checksummed `.rsrz` envelope extends.
//!
//! Also home to [`BinMatrix`], the `2^k × k` enumeration matrix
//! `Bin_[k]` used by Step 2 of RSR.

use std::io::{Read, Write};
use std::path::Path;

use super::binary::BinaryMatrix;
use super::blocking::{column_blocks, ColumnBlock};
use super::permutation::{binary_row_order, is_permutation};
use super::segmentation::{full_segmentation, validate as validate_seg};
use super::ternary::TernaryMatrix;
use crate::error::{Error, Result};

/// `Bin_[k]`: the binary-row-ordered `2^k × k` matrix with one row per
/// k-bit value (paper §3.2). `get(l, j)` is bit `j` of value `l`,
/// MSB-first — i.e. column 0 holds the most significant bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinMatrix {
    /// Bit width `k`.
    pub k: usize,
}

impl BinMatrix {
    /// The enumeration matrix for width `k ≤ 16`.
    pub fn new(k: usize) -> Self {
        assert!(k >= 1 && k <= 16);
        Self { k }
    }

    /// Number of rows, `2^k`.
    #[inline]
    pub fn rows(&self) -> usize {
        1 << self.k
    }

    /// Element `(l, j)`: bit `k−1−j` of `l` (so column 0 is the MSB,
    /// matching `B_i[r,:]₂` concatenation order).
    #[inline]
    pub fn get(&self, l: usize, j: usize) -> bool {
        debug_assert!(l < self.rows() && j < self.k);
        (l >> (self.k - 1 - j)) & 1 == 1
    }

    /// Densify (for tests / the tensorized path).
    pub fn to_dense(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows() * self.k];
        for l in 0..self.rows() {
            for j in 0..self.k {
                out[l * self.k + j] = self.get(l, j) as u8;
            }
        }
        out
    }
}

/// Index of a single k-column block: the permutation `σ` and the full
/// segmentation list `L` (paper Algorithm 1 output).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockIndex {
    /// First column of `B` this block covers.
    pub col_start: u32,
    /// Block width (`k`, or less for the ragged tail).
    pub width: u32,
    /// `sigma[pos] = original_row`; length `n`.
    pub sigma: Vec<u32>,
    /// Full segmentation with sentinel; length `2^width + 1`,
    /// `seg[0] = 0`, `seg[2^width] = n`.
    pub seg: Vec<u32>,
}

impl BlockIndex {
    /// Heap bytes this block index occupies (σ + L as u32).
    pub fn bytes(&self) -> usize {
        (self.sigma.len() + self.seg.len()) * 4
    }
}

/// The full RSR index for one binary matrix: every block's `(σᵢ, Lᵢ)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RsrIndex {
    /// Rows of the indexed matrix (`n`).
    pub rows: usize,
    /// Columns of the indexed matrix (`m`).
    pub cols: usize,
    /// Blocking parameter `k`.
    pub k: usize,
    /// One index per k-column block, in column order.
    pub blocks: Vec<BlockIndex>,
}

impl RsrIndex {
    /// Paper Algorithm 1: block, permute, segment.
    ///
    /// Splits `b` into `⌈m/k⌉` blocks of `k` columns, sorts each
    /// block's rows into binary row order `σᵢ`, and records the full
    /// segmentation list `Lᵢ` of run boundaries. `O(n·m)` time, run
    /// once per (fixed) weight matrix; the index then answers every
    /// `v·B` in `O(n²/log n)` via [`super::rsr::RsrPlan`] or
    /// [`super::rsrpp::RsrPlusPlusPlan`].
    ///
    /// The paper's §3.1 running example (block 1 is Example 3.3):
    ///
    /// ```
    /// use rsr::kernels::{BinaryMatrix, RsrIndex};
    ///
    /// let b = BinaryMatrix::from_rows(&[
    ///     &[0, 1, 1, 1, 0, 1],
    ///     &[0, 0, 0, 1, 1, 1],
    ///     &[0, 1, 1, 1, 1, 0],
    ///     &[1, 1, 0, 0, 1, 0],
    ///     &[0, 0, 1, 1, 0, 1],
    ///     &[0, 0, 0, 0, 1, 0],
    /// ]);
    /// let idx = RsrIndex::preprocess(&b, 2);
    /// assert_eq!(idx.blocks.len(), 3);
    /// // Example 3.3: σ₁ = [1,4,5,0,2,3], L₁ = [0,3,5,5,6].
    /// assert_eq!(idx.blocks[0].sigma, vec![1, 4, 5, 0, 2, 3]);
    /// assert_eq!(idx.blocks[0].seg, vec![0, 3, 5, 5, 6]);
    /// idx.validate().unwrap();
    /// ```
    pub fn preprocess(b: &BinaryMatrix, k: usize) -> Self {
        let geom = column_blocks(b.cols(), k);
        let blocks = geom
            .iter()
            .map(|cb: &ColumnBlock| {
                let ro = binary_row_order(b, cb.col_start, cb.width);
                BlockIndex {
                    col_start: cb.col_start as u32,
                    width: cb.width as u32,
                    sigma: ro.sigma,
                    seg: full_segmentation(&ro.counts),
                }
            })
            .collect();
        Self { rows: b.rows(), cols: b.cols(), k, blocks }
    }

    /// Total index bytes (the Fig 5 "after preprocessing" number).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum::<usize>() + 4 * 4
    }

    /// Validate all structural invariants (used after deserialization
    /// and by property tests).
    pub fn validate(&self) -> Result<()> {
        let mut expect_col = 0u32;
        for blk in &self.blocks {
            if blk.col_start != expect_col {
                return Err(Error::InvalidIndex(format!(
                    "block at col {} expected {}",
                    blk.col_start, expect_col
                )));
            }
            if blk.width == 0 || blk.width as usize > self.k {
                return Err(Error::InvalidIndex(format!("bad width {}", blk.width)));
            }
            if !is_permutation(&blk.sigma, self.rows) {
                return Err(Error::InvalidIndex(format!(
                    "sigma at col {} is not a permutation",
                    blk.col_start
                )));
            }
            validate_seg(&blk.seg, blk.width as usize, self.rows)
                .map_err(Error::InvalidIndex)?;
            expect_col += blk.width;
        }
        if expect_col as usize != self.cols {
            return Err(Error::InvalidIndex(format!(
                "blocks cover {} of {} columns",
                expect_col, self.cols
            )));
        }
        Ok(())
    }

    /// Serialize to the `.rsi` binary format (see module docs).
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        for v in [self.rows as u32, self.cols as u32, self.k as u32, self.blocks.len() as u32] {
            w.write_all(&v.to_le_bytes())?;
        }
        for blk in &self.blocks {
            w.write_all(&blk.col_start.to_le_bytes())?;
            w.write_all(&blk.width.to_le_bytes())?;
            for &s in &blk.sigma {
                w.write_all(&s.to_le_bytes())?;
            }
            for &s in &blk.seg {
                w.write_all(&s.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Deserialize and validate.
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::InvalidIndex("bad magic".into()));
        }
        let rows = read_u32(r)? as usize;
        let cols = read_u32(r)? as usize;
        let k = read_u32(r)? as usize;
        let nblocks = read_u32(r)? as usize;
        if k == 0 || k > 16 || nblocks != cols.div_ceil(k.max(1)) {
            return Err(Error::InvalidIndex("inconsistent header".into()));
        }
        let mut blocks = Vec::with_capacity(nblocks);
        for _ in 0..nblocks {
            let col_start = read_u32(r)?;
            let width = read_u32(r)?;
            if width == 0 || width > 16 {
                return Err(Error::InvalidIndex("bad block width".into()));
            }
            let mut sigma = vec![0u32; rows];
            read_u32s(r, &mut sigma)?;
            let mut seg = vec![0u32; (1usize << width) + 1];
            read_u32s(r, &mut seg)?;
            blocks.push(BlockIndex { col_start, width, sigma, seg });
        }
        let idx = Self { rows, cols, k, blocks };
        idx.validate()?;
        Ok(idx)
    }

    /// Write to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    /// Read from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Self::read_from(&mut f)
    }
}

const MAGIC: &[u8; 8] = b"RSRIDX1\0";

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u32s(r: &mut impl Read, out: &mut [u32]) -> Result<()> {
    // Bulk read as bytes then decode; avoids per-element syscalls.
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (i, chunk) in buf.chunks_exact(4).enumerate() {
        out[i] = u32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(())
}

/// RSR index pair for a ternary matrix: `A = B⁽¹⁾ − B⁽²⁾` (Prop 2.1),
/// both halves preprocessed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TernaryRsrIndex {
    /// Index of `B⁽¹⁾ = [A == +1]`.
    pub plus: RsrIndex,
    /// Index of `B⁽²⁾ = [A == −1]`.
    pub minus: RsrIndex,
}

impl TernaryRsrIndex {
    /// Decompose and preprocess both binary halves.
    pub fn preprocess(a: &TernaryMatrix, k: usize) -> Self {
        let (p, m) = a.decompose();
        Self { plus: RsrIndex::preprocess(&p, k), minus: RsrIndex::preprocess(&m, k) }
    }

    /// Total index bytes.
    pub fn bytes(&self) -> usize {
        self.plus.bytes() + self.minus.bytes()
    }

    /// Validate both halves.
    pub fn validate(&self) -> Result<()> {
        self.plus.validate()?;
        self.minus.validate()
    }
}

/// The paper's running example matrix (§3.1) — shared across kernel
/// unit tests.
#[cfg(test)]
pub(crate) fn paper_matrix() -> BinaryMatrix {
    BinaryMatrix::from_rows(&[
        &[0, 1, 1, 1, 0, 1],
        &[0, 0, 0, 1, 1, 1],
        &[0, 1, 1, 1, 1, 0],
        &[1, 1, 0, 0, 1, 0],
        &[0, 0, 1, 1, 0, 1],
        &[0, 0, 0, 0, 1, 0],
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    use super::paper_matrix;

    #[test]
    fn preprocess_paper_example_block1() {
        let idx = RsrIndex::preprocess(&paper_matrix(), 2);
        assert_eq!(idx.blocks.len(), 3);
        let b1 = &idx.blocks[0];
        // Block 1 is Example 3.3: σ = [1,4,5,0,2,3], L = [0,3,5,5,6].
        assert_eq!(b1.sigma, vec![1, 4, 5, 0, 2, 3]);
        assert_eq!(b1.seg, vec![0, 3, 5, 5, 6]);
        idx.validate().unwrap();
    }

    #[test]
    fn bin_matrix_matches_paper() {
        // Bin_[2] = [[0,0],[0,1],[1,0],[1,1]].
        let bin = BinMatrix::new(2);
        assert_eq!(bin.to_dense(), vec![0, 0, 0, 1, 1, 0, 1, 1]);
        // Bin_[3] row 5 = 101.
        let b3 = BinMatrix::new(3);
        assert!(b3.get(5, 0) && !b3.get(5, 1) && b3.get(5, 2));
    }

    #[test]
    fn serialization_round_trips() {
        let mut rng = Rng::new(41);
        let b = BinaryMatrix::random(97, 50, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 5);
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        let back = RsrIndex::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(idx, back);
    }

    #[test]
    fn deserialization_rejects_corruption() {
        let mut rng = Rng::new(43);
        let b = BinaryMatrix::random(16, 8, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 3);
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        // Corrupt the magic.
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(RsrIndex::read_from(&mut bad.as_slice()).is_err());
        // Corrupt a sigma entry into a duplicate.
        let mut bad = buf.clone();
        let sigma_off = 8 + 16 + 8; // magic + header + block header
        let dup = bad[sigma_off + 4..sigma_off + 8].to_vec();
        bad[sigma_off..sigma_off + 4].copy_from_slice(&dup);
        assert!(RsrIndex::read_from(&mut bad.as_slice()).is_err());
        // Truncated stream.
        let bad = &buf[..buf.len() - 3];
        assert!(RsrIndex::read_from(&mut &bad[..]).is_err());
    }

    #[test]
    fn index_is_smaller_than_dense_for_large_n() {
        // Space: ~ (n/k)(n + 2^k) u32 vs n² f32. At n=4096, k=9 the
        // index must come in well under the dense f32 weights.
        let mut rng = Rng::new(47);
        let n = 1024;
        let b = BinaryMatrix::random(n, n, 0.5, &mut rng);
        let idx = RsrIndex::preprocess(&b, 8);
        let dense_f32 = n * n * 4;
        assert!(
            idx.bytes() < dense_f32,
            "index {} vs dense {}",
            idx.bytes(),
            dense_f32
        );
    }

    #[test]
    fn ternary_index_roundtrip_and_validate() {
        let mut rng = Rng::new(53);
        let a = TernaryMatrix::random(64, 40, 1.0 / 3.0, &mut rng);
        let idx = TernaryRsrIndex::preprocess(&a, 4);
        idx.validate().unwrap();
        assert!(idx.bytes() > 0);
    }
}
