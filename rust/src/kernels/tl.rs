//! **TL (table-lookup) kernels** — the precomputed-lookup execution
//! path over a ternary plan, in the spirit of Bitnet.cpp's TL1/TL2 and
//! T-MAC's LUT kernels (see PAPERS.md).
//!
//! Where RSR/RSR++ amortize work through row permutations and
//! segmented sums, TL amortizes it through **grouping**: `g` weight
//! rows (the reduction dimension) are packed into one byte of 2-bit
//! ternary codes per output column, precomputed at plan-build time
//! from the validated [`FlatPlan`] arenas. At execute time each group
//! builds the full `4^g`-entry table of partial sums over its `g`
//! activation values once (a `O(4^g)` dynamic program), then every
//! output column resolves its `g` multiply-adds with a **single table
//! lookup**:
//!
//! ```text
//!   codes:  [ group 0: m bytes | group 1: m bytes | … ]   (group-major)
//!   byte:   bits 2j..2j+1 = code of row (group·g + j):
//!           00 = 0, 01 = +1, 10 = −1, 11 = invalid (pack2 convention)
//!
//!   per group:  lut[c] = Σ_j sign(c_j) · v[base + j]      (4^g entries)
//!               out[col] += lut[codes[group·m + col]]     (m lookups)
//! ```
//!
//! Per group the cost is `4^g + m` instead of `g·m`, so for wide
//! layers (`m ≫ 4^g/g`) the lookup stream replaces almost all of the
//! arithmetic with a contiguous byte scan — exactly the access pattern
//! that wins on gather-weak edge CPUs.
//!
//! ## Group size `g`
//!
//! `g` trades table-build cost against lookup density: doubling `g`
//! halves the number of groups (and lookups) but squares the table.
//! With `g = 4` (the default, [`TL_GROUP`]) the table is 256 × f32 =
//! 1 KiB — it lives in L1 across the whole group scan — and a code is
//! exactly one byte. `g > 4` would spill codes past a byte and the
//! table past trivial L1 residency, so [`TL_MAX_GROUP`] caps it at 4.
//!
//! ## ISA dispatch
//!
//! [`TlPlan::execute`] is the single runtime-dispatch point:
//!
//! | host                  | column loop                                   |
//! |-----------------------|-----------------------------------------------|
//! | x86-64 with AVX2      | 8-wide `vpmovzxbd` + `vgatherdps` from the LUT|
//! | aarch64 with NEON     | 4-wide lane-gathered `vaddq_f32`              |
//! | anything else         | portable scalar loop                          |
//!
//! All three legs add `lut[code]` into each column in the **same group
//! order**, so their outputs are bit-identical to each other even on
//! arbitrary float activations (the SIMD legs vectorize across
//! *columns*, which never reassociates a column's sum). Against the
//! non-TL backends, equality is exact on integer-valued activations
//! (every partial sum representable) — the property
//! `rust/tests/backend_equivalence.rs` pins for every backend.
//!
//! ## Trust boundary
//!
//! Like [`FlatPlan`], a `TlPlan` validates everything at construction
//! ([`TlPlan::from_parts`]) and is immutable afterwards: code bytes
//! must stay below `4^g`, the reserved digit `11` is rejected
//! (mirroring [`TernaryMatrix::unpack2`]'s Result-ification), and the
//! ragged tail group's padding digits must be zero. Corrupt or
//! truncated payloads are an `Err`, never a panic or an out-of-bounds
//! table read.
//!
//! [`TernaryMatrix::unpack2`]: super::ternary::TernaryMatrix::unpack2

use super::flat::{FlatPlan, TernaryFlatPlan};
use super::rsr::check_shapes;
use crate::error::{Error, Result};

/// Default group size: 4 rows per code byte, 256-entry (1 KiB) tables.
pub const TL_GROUP: usize = 4;

/// Largest supported group size (codes must fit one byte).
pub const TL_MAX_GROUP: usize = 4;

/// Whether the pinned NEON column loop ([`TlPlan::execute_neon`], the
/// `tl-neon` tuning candidate) can run on this host, detected once per
/// process. Also feeds the machine fingerprint of `.rsrt` profiles.
pub fn tl_neon_available() -> bool {
    #[cfg(target_arch = "aarch64")]
    {
        use std::sync::atomic::{AtomicU8, Ordering};
        static STATE: AtomicU8 = AtomicU8::new(0);
        match STATE.load(Ordering::Relaxed) {
            1 => true,
            2 => false,
            _ => {
                let ok = std::arch::is_aarch64_feature_detected!("neon");
                STATE.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
                ok
            }
        }
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        false
    }
}

/// Whether [`TlPlan::execute`]'s dispatch can take a SIMD column loop
/// on this host (AVX2 gather on x86-64, NEON on aarch64) — i.e.
/// whether the `tl` candidate can differ from a scalar-pinned run.
pub fn tl_simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        super::flat::simd_gather_available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        tl_neon_available()
    }
}

/// A precomputed-lookup execution plan for one ternary matrix:
/// group-major packed 2-bit weight codes, validated at construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TlPlan {
    rows: usize,
    cols: usize,
    g: usize,
    /// `groups × cols` code bytes, group-major (one contiguous `cols`
    /// stream per group — the execute-time scan order).
    codes: Vec<u8>,
}

impl TlPlan {
    /// Build a TL plan from both Prop 2.1 halves of a validated flat
    /// plan pair: the ternary weights are reconstructed from the
    /// `σ`/`L` arenas (segment `j` of a block encodes bit pattern `j`,
    /// MSB-first — the [`BinMatrix`](super::index::BinMatrix)
    /// convention), then packed into group codes.
    pub fn from_flat(plan: &TernaryFlatPlan, g: usize) -> Result<Self> {
        plan.check_geometry()?;
        Self::from_halves(&plan.plus, &plan.minus, g)
    }

    /// [`from_flat`](Self::from_flat) over the two halves directly —
    /// the [`SharedTernaryPlan`](crate::runtime::SharedTernaryPlan)
    /// build path, which holds each half behind its own `Arc`.
    pub fn from_halves(plus: &FlatPlan, minus: &FlatPlan, g: usize) -> Result<Self> {
        if plus.rows() != minus.rows() || plus.cols() != minus.cols() {
            return Err(Error::InvalidIndex(
                "ternary halves disagree on geometry".into(),
            ));
        }
        let (rows, cols) = (plus.rows(), plus.cols());
        let mut w = vec![0i8; rows * cols];
        accumulate_half(plus, 1, &mut w);
        accumulate_half(minus, -1, &mut w);
        Self::from_weights(rows, cols, g, &w)
    }

    /// Pack dense row-major ternary weights into a TL plan.
    pub fn from_weights(rows: usize, cols: usize, g: usize, w: &[i8]) -> Result<Self> {
        check_group(g)?;
        if w.len() != rows.checked_mul(cols).unwrap_or(usize::MAX) {
            return Err(Error::InvalidIndex(format!(
                "weight buffer of {} entries for a {rows}x{cols} TL plan",
                w.len()
            )));
        }
        let groups = rows.div_ceil(g);
        let mut codes = vec![0u8; groups * cols];
        for r in 0..rows {
            let (gi, j) = (r / g, r % g);
            let row = &w[r * cols..(r + 1) * cols];
            let chunk = &mut codes[gi * cols..(gi + 1) * cols];
            for (c, &v) in row.iter().enumerate() {
                let code: u8 = match v {
                    0 => 0b00,
                    1 => 0b01,
                    -1 => 0b10,
                    other => {
                        return Err(Error::InvalidIndex(format!(
                            "weight {other} at ({r},{c}) is not ternary"
                        )))
                    }
                };
                chunk[c] |= code << (2 * j);
            }
        }
        Self::from_parts(rows, cols, g, codes)
    }

    /// Assemble (and fully validate) a TL plan from a raw code buffer —
    /// the single trust boundary every constructor funnels through.
    /// Rejects, without panicking or reading out of bounds:
    ///
    /// * truncated or oversized payloads (`codes.len() ≠ groups·cols`),
    /// * the reserved ternary digit `11` in any live position (a
    ///   bit-flipped byte — same discipline as
    ///   [`TernaryMatrix::unpack2`](super::ternary::TernaryMatrix::unpack2)),
    /// * nonzero digits in the ragged tail group's padding positions,
    /// * with `g < 4`, code bytes at or above `4^g` (they would index
    ///   past the lookup table).
    pub fn from_parts(rows: usize, cols: usize, g: usize, codes: Vec<u8>) -> Result<Self> {
        check_group(g)?;
        if rows == 0 || cols == 0 {
            return Err(Error::InvalidIndex(format!(
                "empty TL plan geometry {rows}x{cols}"
            )));
        }
        let groups = rows.div_ceil(g);
        let expect = groups * cols;
        if codes.len() != expect {
            return Err(Error::InvalidIndex(format!(
                "TL code payload of {} bytes, expected {expect} for {rows}x{cols} at g={g}",
                codes.len()
            )));
        }
        // Rows the last (possibly ragged) group actually covers.
        let tail = rows - (groups - 1) * g;
        for (i, &b) in codes.iter().enumerate() {
            let live = if i / cols + 1 == groups { tail } else { g };
            for j in 0..g {
                let digit = (b >> (2 * j)) & 0b11;
                if j < live {
                    if digit == 0b11 {
                        return Err(Error::InvalidIndex(format!(
                            "invalid ternary weight code 0b11 in TL byte {i}"
                        )));
                    }
                } else if digit != 0 {
                    return Err(Error::InvalidIndex(format!(
                        "nonzero padding digit in ragged TL byte {i}"
                    )));
                }
            }
            if g < 4 && (b >> (2 * g)) != 0 {
                return Err(Error::InvalidIndex(format!(
                    "TL byte {i} indexes past the 4^{g}-entry table"
                )));
            }
        }
        Ok(Self { rows, cols, g, codes })
    }

    /// Rows of the planned matrix (`n`, the activation length).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns of the planned matrix (`m`, the output length).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Group size the codes were packed with.
    #[inline]
    pub fn group(&self) -> usize {
        self.g
    }

    /// Number of row groups, `⌈rows/g⌉`.
    #[inline]
    pub fn groups(&self) -> usize {
        self.rows.div_ceil(self.g)
    }

    /// The packed code buffer (group-major, `groups × cols`).
    #[inline]
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// Entries of the per-group lookup table, `4^g`.
    #[inline]
    pub fn lut_len(&self) -> usize {
        1 << (2 * self.g)
    }

    /// A correctly-sized lookup-table scratch for this plan (the
    /// per-executor mutable state; the plan itself stays shared).
    pub fn scratch(&self) -> Vec<f32> {
        vec![0.0; self.lut_len()]
    }

    /// Heap bytes the plan occupies — one byte per `g` weights.
    pub fn bytes(&self) -> usize {
        self.codes.len() + 4 * std::mem::size_of::<usize>()
    }

    /// Fill `lut` with every partial sum of the group starting at
    /// activation `base`: a dynamic program adding one row per step
    /// (`+v` for digit `01`, `−v` for `10`, a copy for the reserved
    /// `11` so the table stays finite — validated codes never index
    /// it). Ragged tail groups fill only their `4^live` prefix; their
    /// padding digits are validated zero, so the stale suffix is never
    /// indexed either.
    fn build_lut(&self, v: &[f32], base: usize, lut: &mut [f32]) {
        debug_assert_eq!(lut.len(), self.lut_len());
        lut[0] = 0.0;
        let live = (self.rows - base).min(self.g);
        let mut filled = 1usize;
        for j in 0..live {
            let x = v[base + j];
            for p in 0..filled {
                let acc = lut[p];
                lut[p + filled] = acc + x;
                lut[p + 2 * filled] = acc - x;
                lut[p + 3 * filled] = acc;
            }
            filled *= 4;
        }
    }

    /// The shared group loop: build each group's table, then let `acc`
    /// stream the group's code bytes into `out`. Every ISA leg runs
    /// this exact loop, differing only in `acc` — which is what makes
    /// the legs bit-identical (per column, one `+= lut[code]` per
    /// group, in group order).
    fn execute_with(
        &self,
        v: &[f32],
        out: &mut [f32],
        lut: &mut Vec<f32>,
        acc: impl Fn(&[u8], &[f32], &mut [f32]),
    ) -> Result<()> {
        check_shapes(self.rows, self.cols, v, out)?;
        if lut.len() != self.lut_len() {
            lut.resize(self.lut_len(), 0.0);
        }
        out.fill(0.0);
        for gi in 0..self.groups() {
            self.build_lut(v, gi * self.g, lut);
            acc(&self.codes[gi * self.cols..(gi + 1) * self.cols], lut, out);
        }
        Ok(())
    }

    /// `out = v · A` — the runtime-dispatched TL multiply (the
    /// `tl` tuning candidate): AVX2 gather on x86-64 hosts that have
    /// it, NEON on aarch64 hosts that have it, the portable scalar
    /// loop everywhere else. All routes are bit-identical.
    pub fn execute(&self, v: &[f32], out: &mut [f32], lut: &mut Vec<f32>) -> Result<()> {
        #[cfg(target_arch = "x86_64")]
        {
            if super::flat::simd_gather_available() {
                // SAFETY: AVX2 presence just checked; codes/lut sizes
                // are construction-validated invariants of `self`.
                return self.execute_with(v, out, lut, |c, l, o| unsafe {
                    accumulate_cols_avx2(c, l, o)
                });
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if tl_neon_available() {
                // SAFETY: NEON presence just checked; sizes validated.
                return self.execute_with(v, out, lut, |c, l, o| unsafe {
                    accumulate_cols_neon(c, l, o)
                });
            }
        }
        self.execute_scalar(v, out, lut)
    }

    /// [`execute`](Self::execute) pinned to the portable scalar column
    /// loop — the reference the dispatch property tests compare
    /// against.
    pub fn execute_scalar(&self, v: &[f32], out: &mut [f32], lut: &mut Vec<f32>) -> Result<()> {
        self.execute_with(v, out, lut, accumulate_cols_scalar)
    }

    /// [`execute`](Self::execute) pinned to the NEON column loop — the
    /// `tl-neon` tuning candidate. A clean error (never a mis-dispatch)
    /// on hosts without aarch64 NEON; [`tl_neon_available`] gates the
    /// candidate so tuned profiles only ever record it where it runs.
    pub fn execute_neon(&self, v: &[f32], out: &mut [f32], lut: &mut Vec<f32>) -> Result<()> {
        #[cfg(target_arch = "aarch64")]
        {
            if tl_neon_available() {
                // SAFETY: NEON presence just checked; sizes validated.
                return self.execute_with(v, out, lut, |c, l, o| unsafe {
                    accumulate_cols_neon(c, l, o)
                });
            }
        }
        let _ = (v, out, lut);
        Err(Error::Config(
            "the tl-neon backend requires aarch64 NEON, which this host lacks".into(),
        ))
    }

    /// `out[b] = vs[b] · A` for a row-major `batch × rows` activation
    /// block: the batched entry point is a per-row loop over the
    /// dispatched single-vector kernel, so per row it performs the
    /// identical f32 operation sequence at every batch size — the
    /// batch-invariance contract continuous batching relies on, for
    /// free.
    pub fn execute_batch(
        &self,
        vs: &[f32],
        batch: usize,
        out: &mut [f32],
        lut: &mut Vec<f32>,
    ) -> Result<()> {
        check_batch_shapes(self.rows, self.cols, vs, batch, out)?;
        for b in 0..batch {
            self.execute(
                &vs[b * self.rows..(b + 1) * self.rows],
                &mut out[b * self.cols..(b + 1) * self.cols],
                lut,
            )?;
        }
        Ok(())
    }

    /// [`execute_batch`](Self::execute_batch) pinned to the NEON leg.
    pub fn execute_batch_neon(
        &self,
        vs: &[f32],
        batch: usize,
        out: &mut [f32],
        lut: &mut Vec<f32>,
    ) -> Result<()> {
        check_batch_shapes(self.rows, self.cols, vs, batch, out)?;
        for b in 0..batch {
            self.execute_neon(
                &vs[b * self.rows..(b + 1) * self.rows],
                &mut out[b * self.cols..(b + 1) * self.cols],
                lut,
            )?;
        }
        Ok(())
    }
}

fn check_group(g: usize) -> Result<()> {
    if g == 0 || g > TL_MAX_GROUP {
        return Err(Error::InvalidIndex(format!(
            "TL group size {g} outside 1..={TL_MAX_GROUP}"
        )));
    }
    Ok(())
}

fn check_batch_shapes(
    rows: usize,
    cols: usize,
    vs: &[f32],
    batch: usize,
    out: &[f32],
) -> Result<()> {
    if batch == 0 || vs.len() != batch * rows || out.len() != batch * cols {
        return Err(Error::ShapeMismatch(format!(
            "TL batch {batch}: vs len {}, out len {} for a {rows}x{cols} plan",
            vs.len(),
            out.len()
        )));
    }
    Ok(())
}

/// Add `sign` into `w` wherever one binary half has a 1, reading the
/// weights back out of the flat arenas: every row in segment `pat` of
/// a block has that block's columns equal to the bits of `pat`,
/// MSB-first (the `Bin_[k]` convention Algorithm 1 sorts by).
fn accumulate_half(flat: &FlatPlan, sign: i8, w: &mut [i8]) {
    let cols = flat.cols();
    for (i, blk) in flat.blocks().iter().enumerate() {
        let width = blk.width as usize;
        let col0 = blk.col_start as usize;
        let sigma = flat.block_sigma(i);
        let seg = flat.block_seg(i);
        for pat in 0..(1usize << width) {
            if pat == 0 {
                continue; // all-zero rows contribute nothing
            }
            let (lo, hi) = (seg[pat] as usize, seg[pat + 1] as usize);
            for &row in &sigma[lo..hi] {
                let base = row as usize * cols + col0;
                for jcol in 0..width {
                    if (pat >> (width - 1 - jcol)) & 1 == 1 {
                        w[base + jcol] += sign;
                    }
                }
            }
        }
    }
}

/// Portable column loop: one table lookup + add per output column.
/// Safe indexing throughout — construction validated every code below
/// `4^g` and `execute_with` sized the table to exactly `4^g`.
fn accumulate_cols_scalar(codes: &[u8], lut: &[f32], out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o += lut[c as usize];
    }
}

/// AVX2 column loop: 8 code bytes widen to dword lanes (`vpmovzxbd`)
/// and gather from the table (`vgatherdps`), 8 columns per iteration.
/// Lanewise adds in column order — bit-identical to the scalar loop.
///
/// # Safety
/// Caller must ensure AVX2 is available and every code byte is below
/// `lut.len()` (guaranteed by [`TlPlan::from_parts`] validation).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_cols_avx2(codes: &[u8], lut: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = out.len();
    let base = lut.as_ptr();
    let cp = codes.as_ptr();
    let op = out.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let bytes = _mm_loadl_epi64(cp.add(i) as *const __m128i);
        let ix = _mm256_cvtepu8_epi32(bytes);
        let vals = _mm256_i32gather_ps::<4>(base, ix);
        let acc = _mm256_add_ps(_mm256_loadu_ps(op.add(i)), vals);
        _mm256_storeu_ps(op.add(i), acc);
        i += 8;
    }
    while i < n {
        *out.get_unchecked_mut(i) += *lut.get_unchecked(*cp.add(i) as usize);
        i += 1;
    }
}

/// NEON column loop: 4 lane-gathered table entries per `vaddq_f32`,
/// column order preserved — bit-identical to the scalar loop.
///
/// # Safety
/// Caller must ensure NEON is available and every code byte is below
/// `lut.len()` (guaranteed by [`TlPlan::from_parts`] validation).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn accumulate_cols_neon(codes: &[u8], lut: &[f32], out: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = out.len();
    let cp = codes.as_ptr();
    let op = out.as_mut_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let vals = [
            *lut.get_unchecked(*cp.add(i) as usize),
            *lut.get_unchecked(*cp.add(i + 1) as usize),
            *lut.get_unchecked(*cp.add(i + 2) as usize),
            *lut.get_unchecked(*cp.add(i + 3) as usize),
        ];
        let acc = vaddq_f32(vld1q_f32(op.add(i)), vld1q_f32(vals.as_ptr()));
        vst1q_f32(op.add(i), acc);
        i += 4;
    }
    while i < n {
        *out.get_unchecked_mut(i) += *lut.get_unchecked(*cp.add(i) as usize);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::index::TernaryRsrIndex;
    use super::super::standard::standard_mul_ternary;
    use super::super::ternary::TernaryMatrix;
    use super::*;
    use crate::util::rng::Rng;

    fn tl_from_matrix(a: &TernaryMatrix, k: usize, g: usize) -> TlPlan {
        let idx = TernaryRsrIndex::preprocess(a, k);
        let flat = TernaryFlatPlan::from_index(&idx).unwrap();
        TlPlan::from_flat(&flat, g).unwrap()
    }

    #[test]
    fn from_flat_reconstructs_the_weights_exactly() {
        // The arena → weights → codes path must equal packing the
        // original matrix directly, for every group size and a ragged
        // row count.
        let mut rng = Rng::new(7001);
        for (n, m, k) in [(37, 23, 3), (64, 48, 5), (50, 31, 4)] {
            let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
            for g in 1..=TL_MAX_GROUP {
                let via_flat = tl_from_matrix(&a, k, g);
                let direct = TlPlan::from_weights(n, m, g, a.data()).unwrap();
                assert_eq!(via_flat, direct, "n={n} m={m} k={k} g={g}");
            }
        }
    }

    #[test]
    fn execute_matches_standard_multiply() {
        let mut rng = Rng::new(7003);
        for (n, m) in [(40, 24), (37, 23), (96, 64)] {
            let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
            let v = rng.f32_vec(n, -1.0, 1.0);
            let expect = standard_mul_ternary(&v, &a);
            for g in 1..=TL_MAX_GROUP {
                let tl = tl_from_matrix(&a, 4, g);
                let mut lut = tl.scratch();
                let mut out = vec![0.0f32; m];
                tl.execute(&v, &mut out, &mut lut).unwrap();
                for (got, exp) in out.iter().zip(expect.iter()) {
                    assert!(
                        (got - exp).abs() <= 1e-4 * (1.0 + exp.abs()),
                        "g={g}: {got} vs {exp}"
                    );
                }
            }
        }
    }

    #[test]
    fn dispatched_and_scalar_legs_are_bit_identical_on_floats() {
        // The SIMD legs vectorize across columns, never inside one
        // column's sum — so dispatch must match the scalar pin to the
        // last bit even on arbitrary float activations.
        let mut rng = Rng::new(7005);
        let a = TernaryMatrix::random(83, 57, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(83, -1.0, 1.0);
        let tl = tl_from_matrix(&a, 4, TL_GROUP);
        let mut lut = tl.scratch();
        let mut scalar = vec![0.0f32; 57];
        tl.execute_scalar(&v, &mut scalar, &mut lut).unwrap();
        let mut dispatched = vec![0.0f32; 57];
        tl.execute(&v, &mut dispatched, &mut lut).unwrap();
        assert_eq!(scalar, dispatched);
    }

    #[test]
    fn scratch_reuse_and_shape_errors() {
        let mut rng = Rng::new(7007);
        let a = TernaryMatrix::random(32, 16, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(32, -1.0, 1.0);
        let tl = tl_from_matrix(&a, 3, TL_GROUP);
        let mut lut = Vec::new(); // wrong size: must be grown, not trusted
        let mut out = vec![0.0f32; 16];
        tl.execute(&v, &mut out, &mut lut).unwrap();
        let first = out.clone();
        tl.execute(&v, &mut out, &mut lut).unwrap();
        assert_eq!(out, first, "scratch reuse must not change results");
        assert!(tl.execute(&v[..31], &mut out, &mut lut).is_err());
        assert!(tl.execute(&v, &mut out[..15], &mut lut).is_err());
        assert!(tl.execute_batch(&v, 0, &mut out, &mut lut).is_err());
        assert!(tl.execute_batch(&v, 2, &mut out, &mut lut).is_err());
    }

    #[test]
    fn execute_batch_rows_match_single_vector_runs() {
        let mut rng = Rng::new(7009);
        let a = TernaryMatrix::random(41, 29, 1.0 / 3.0, &mut rng);
        let tl = tl_from_matrix(&a, 4, TL_GROUP);
        let mut lut = tl.scratch();
        let batch = 3;
        let vs = rng.f32_vec(batch * 41, -1.0, 1.0);
        let mut bout = vec![0.0f32; batch * 29];
        tl.execute_batch(&vs, batch, &mut bout, &mut lut).unwrap();
        for b in 0..batch {
            let mut solo = vec![0.0f32; 29];
            tl.execute(&vs[b * 41..(b + 1) * 41], &mut solo, &mut lut).unwrap();
            assert_eq!(&bout[b * 29..(b + 1) * 29], &solo[..], "row {b}");
        }
    }

    #[test]
    fn neon_pin_errs_cleanly_where_unavailable() {
        let mut rng = Rng::new(7011);
        let a = TernaryMatrix::random(16, 8, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(16, -1.0, 1.0);
        let tl = tl_from_matrix(&a, 3, TL_GROUP);
        let mut lut = tl.scratch();
        let mut out = vec![0.0f32; 8];
        let result = tl.execute_neon(&v, &mut out, &mut lut);
        if tl_neon_available() {
            result.unwrap();
            let mut scalar = vec![0.0f32; 8];
            tl.execute_scalar(&v, &mut scalar, &mut lut).unwrap();
            assert_eq!(out, scalar);
        } else {
            let err = result.unwrap_err();
            assert!(err.to_string().contains("tl-neon"), "{err}");
        }
    }

    #[test]
    fn from_parts_rejects_corruption_without_panicking() {
        let mut rng = Rng::new(7013);
        let a = TernaryMatrix::random(10, 6, 1.0 / 3.0, &mut rng);
        let good = TlPlan::from_weights(10, 6, 4, a.data()).unwrap();
        let codes = good.codes().to_vec();

        // Truncated payload.
        assert!(TlPlan::from_parts(10, 6, 4, codes[..codes.len() - 1].to_vec()).is_err());
        // Oversized payload.
        let mut long = codes.clone();
        long.push(0);
        assert!(TlPlan::from_parts(10, 6, 4, long).is_err());
        // Bit flip that lands on the reserved digit 0b11.
        let mut flipped = codes.clone();
        flipped[0] |= 0b11;
        let err = TlPlan::from_parts(10, 6, 4, flipped).unwrap_err();
        assert!(err.to_string().contains("0b11"), "{err}");
        // Nonzero padding digit in the ragged tail group (10 rows at
        // g=4 → last group has 2 live rows; digits 2..4 must be 0).
        let mut padded = codes.clone();
        let tail_start = (10usize.div_ceil(4) - 1) * 6;
        padded[tail_start] |= 0b01 << 4;
        let err = TlPlan::from_parts(10, 6, 4, padded).unwrap_err();
        assert!(err.to_string().contains("padding"), "{err}");
        // g < 4: a code byte that would index past the 4^g table.
        let small = TlPlan::from_weights(10, 6, 2, a.data()).unwrap();
        let mut oob = small.codes().to_vec();
        oob[0] |= 1 << 4;
        let err = TlPlan::from_parts(10, 6, 2, oob).unwrap_err();
        assert!(err.to_string().contains("table"), "{err}");
        // Bad group sizes.
        assert!(TlPlan::from_parts(10, 6, 0, vec![]).is_err());
        assert!(TlPlan::from_parts(10, 6, 5, vec![0; 12]).is_err());
        // The pristine payload still round-trips.
        assert_eq!(TlPlan::from_parts(10, 6, 4, codes).unwrap(), good);
    }

    #[test]
    fn ragged_tail_group_executes_correctly() {
        // rows not divisible by g: the tail group's table only fills
        // its 4^live prefix and padding digits are zero — outputs must
        // still match the dense reference exactly on integers.
        let mut rng = Rng::new(7015);
        for rows in [5, 6, 7, 9] {
            let a = TernaryMatrix::random(rows, 11, 1.0 / 3.0, &mut rng);
            let v = rng.int_f32_vec(rows, 3);
            let tl = TlPlan::from_weights(rows, 11, 4, a.data()).unwrap();
            let mut lut = tl.scratch();
            let mut out = vec![0.0f32; 11];
            tl.execute(&v, &mut out, &mut lut).unwrap();
            assert_eq!(out, standard_mul_ternary(&v, &a), "rows={rows}");
        }
    }

    #[test]
    fn plan_is_compact() {
        let mut rng = Rng::new(7017);
        let a = TernaryMatrix::random(256, 256, 1.0 / 3.0, &mut rng);
        let tl = TlPlan::from_weights(256, 256, 4, a.data()).unwrap();
        // One byte per 4 weights plus a constant header.
        assert!(tl.bytes() < 256 * 256 / 4 + 64);
        assert_eq!(tl.groups(), 64);
        assert_eq!(tl.lut_len(), 256);
    }
}
