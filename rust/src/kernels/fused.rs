//! Performance-optimized ternary hot path (§Perf deliverable).
//!
//! The profile of the straightforward ternary pipeline
//! (`TernaryRsrPlusPlusPlan`) shows three separable costs per block:
//!
//! 1. two independent gather passes over `v` (one per Prop 2.1 half),
//!    each chasing a `u32` permutation — random reads of `v`,
//! 2. two `u·Bin_[k]` fold products,
//! 3. a final full-width subtraction pass `out = plus − minus`.
//!
//! This module fuses all three:
//!
//! * **scatter instead of gather** — the one-hot key form (paper App
//!   E.2) reads `v` *sequentially* and scatters into the L1-resident
//!   `u` array: `u⁺[k⁺[r]] += v[r]`. No σ permutation is stored at all
//!   (u16 keys halve index traffic vs u32 σ),
//! * **one pass for both halves** — `v[r]` is loaded once and
//!   scattered into both `u⁺` and `u⁻`,
//! * **fold once, not twice** — by linearity
//!   `v·B⁺·Bin − v·B⁻·Bin = (u⁺ − u⁻)·Bin`, so the two fold products
//!   and the output subtraction collapse into a single fold over the
//!   difference vector (`2^k` subtractions instead of `k·n`-ish work).
//!
//! Same math, same index information content, measured ~2–3× over the
//! unfused plan on this host (see EXPERIMENTS.md §Perf).

use super::blocking::column_blocks;
use super::rsrpp::block_product_fold;
use super::ternary::TernaryMatrix;
use crate::error::{Error, Result};

/// Fused ternary RSR++ plan: per block, u16 scatter keys for both
/// Prop 2.1 halves, interleaved in one buffer for locality.
#[derive(Debug, Clone)]
pub struct FusedTernaryPlan {
    rows: usize,
    cols: usize,
    k: usize,
    /// `(col_start, width)` per block.
    blocks: Vec<(u32, u32)>,
    // (k is retained for introspection via `k()`.)
    /// Per block: interleaved `[k⁺[0], k⁻[0], k⁺[1], k⁻[1], …]` —
    /// one stream, sequential access.
    keys: Vec<Vec<u16>>,
    // Scratch (no allocation on the hot path).
    u_plus: Vec<f32>,
    u_minus: Vec<f32>,
    fold: Vec<f32>,
}

impl FusedTernaryPlan {
    /// Preprocess a ternary matrix (Algorithm 1 in key form, both
    /// halves at once).
    pub fn preprocess(a: &TernaryMatrix, k: usize) -> Result<Self> {
        if k == 0 || k > 16 {
            return Err(Error::Config(format!("k={k} out of range 1..=16")));
        }
        let (rows, cols) = (a.rows(), a.cols());
        let geom = column_blocks(cols, k);
        let mut blocks = Vec::with_capacity(geom.len());
        let mut keys = Vec::with_capacity(geom.len());
        for cb in &geom {
            blocks.push((cb.col_start as u32, cb.width as u32));
            let mut ks = Vec::with_capacity(2 * rows);
            for r in 0..rows {
                let mut kp = 0u16;
                let mut km = 0u16;
                for j in 0..cb.width {
                    let w = a.get(r, cb.col_start + j);
                    kp = (kp << 1) | (w == 1) as u16;
                    km = (km << 1) | (w == -1) as u16;
                }
                ks.push(kp);
                ks.push(km);
            }
            keys.push(ks);
        }
        let max_u = 1usize << k.min(16);
        Ok(Self {
            rows,
            cols,
            k,
            blocks,
            keys,
            u_plus: vec![0.0; max_u],
            u_minus: vec![0.0; max_u],
            fold: vec![0.0; max_u],
        })
    }

    /// The blocking parameter this plan was built with.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Index bytes (u16 keys, both halves).
    pub fn bytes(&self) -> usize {
        self.keys.iter().map(|k| k.len() * 2).sum::<usize>() + self.blocks.len() * 8
    }

    /// `out = v · A` — fused scatter + single fold per block.
    pub fn execute(&mut self, v: &[f32], out: &mut [f32]) -> Result<()> {
        if v.len() != self.rows {
            return Err(Error::ShapeMismatch(format!(
                "vector len {} != rows {}",
                v.len(),
                self.rows
            )));
        }
        if out.len() != self.cols {
            return Err(Error::ShapeMismatch(format!(
                "output len {} != cols {}",
                out.len(),
                self.cols
            )));
        }
        for (bi, &(col, w)) in self.blocks.iter().enumerate() {
            let w = w as usize;
            let two_w = 1usize << w;
            let up = &mut self.u_plus[..two_w];
            let um = &mut self.u_minus[..two_w];
            up.fill(0.0);
            um.fill(0.0);
            let keys = &self.keys[bi];
            // One sequential pass over v; both scatters share the load.
            // SAFETY: keys were built from width-w blocks so every key
            // is < 2^w; r < rows == v.len() by construction.
            unsafe {
                for (r, &vr) in v.iter().enumerate() {
                    let kp = *keys.get_unchecked(2 * r) as usize;
                    let km = *keys.get_unchecked(2 * r + 1) as usize;
                    *up.get_unchecked_mut(kp) += vr;
                    *um.get_unchecked_mut(km) += vr;
                }
            }
            // Key 0 collects rows with no ±1 bits in this block — they
            // contribute nothing (Bin row 0 is all zeros), so no fixup
            // is needed. Fold once over the difference.
            for i in 0..two_w {
                up[i] -= um[i];
            }
            let col = col as usize;
            block_product_fold(&up[..two_w], w, &mut out[col..col + w], &mut self.fold);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::standard::standard_mul_ternary;
    use crate::util::rng::Rng;

    #[test]
    fn fused_matches_standard() {
        let mut rng = Rng::new(0xF0);
        for (n, m, k) in [(64, 48, 4), (100, 101, 7), (33, 5, 3), (256, 256, 8)] {
            let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
            let v = rng.f32_vec(n, -1.0, 1.0);
            let mut plan = FusedTernaryPlan::preprocess(&a, k).unwrap();
            let mut out = vec![0.0; m];
            plan.execute(&v, &mut out).unwrap();
            let expect = standard_mul_ternary(&v, &a);
            for (i, (g, e)) in out.iter().zip(expect.iter()).enumerate() {
                assert!(
                    (g - e).abs() < 1e-3 * (1.0 + e.abs()),
                    "n={n} m={m} k={k} elem {i}: {g} vs {e}"
                );
            }
        }
    }

    #[test]
    fn fused_is_exact_on_integer_inputs() {
        let mut rng = Rng::new(0xF1);
        let a = TernaryMatrix::random(128, 96, 1.0 / 3.0, &mut rng);
        let v = rng.int_f32_vec(128, 6);
        let mut plan = FusedTernaryPlan::preprocess(&a, 5).unwrap();
        let mut out = vec![0.0; 96];
        plan.execute(&v, &mut out).unwrap();
        // Scatter + single-fold reorders sums; integer values keep f32
        // exact so the result must still be identical... up to the
        // subtraction refactoring (a−b vs Σ(aᵢ−bᵢ)) which is also
        // exact on integers.
        assert_eq!(out, standard_mul_ternary(&v, &a));
    }

    #[test]
    fn fused_rejects_bad_shapes_and_k() {
        let mut rng = Rng::new(0xF2);
        let a = TernaryMatrix::random(16, 8, 1.0 / 3.0, &mut rng);
        assert!(FusedTernaryPlan::preprocess(&a, 0).is_err());
        assert!(FusedTernaryPlan::preprocess(&a, 17).is_err());
        let mut plan = FusedTernaryPlan::preprocess(&a, 3).unwrap();
        let mut out = vec![0.0; 8];
        assert!(plan.execute(&[0.0; 15], &mut out).is_err());
        assert!(plan.execute(&[0.0; 16], &mut [0.0; 7]).is_err());
    }

    #[test]
    fn fused_index_is_compact() {
        let mut rng = Rng::new(0xF3);
        let n = 512;
        let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
        let plan = FusedTernaryPlan::preprocess(&a, 8).unwrap();
        // 2 u16 keys per row per block = 4 bytes × n × n/k ≈ 4n²/k —
        // half of the two-σ u32 representation.
        let expect = 4 * n * n / 8;
        assert!(plan.bytes() < expect * 2, "{} vs {}", plan.bytes(), expect);
    }
}
