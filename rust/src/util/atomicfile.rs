//! Crash-safe file writes: tmp file + fsync + atomic rename.
//!
//! Every durable artifact this crate writes (`.rsrz` plans, `.rsrt`
//! tuning profiles) goes through [`write_atomic`], which guarantees a
//! reader can only ever observe one of three states, no matter where a
//! kill lands:
//!
//! * the **old** file (rename not reached),
//! * the **complete new** file (rename done — rename within one
//!   directory is atomic on POSIX),
//! * a stray `*.tmp` alongside either (killed mid-write) — which
//!   loaders refuse to open ([`is_tmp`]) and directory scans move
//!   aside ([`quarantine_stray_tmp`]) so it can never be mistaken for
//!   a finished artifact.
//!
//! A loadable-but-corrupt artifact therefore cannot exist: partial
//! bytes only ever live under the `.tmp` name, and the checksum in the
//! artifact formats covers whatever slips past anyway.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::log;
use crate::util::obs::Level;

/// Suffix carried by in-flight writes. Nothing with this suffix is
/// ever a finished artifact.
pub const TMP_SUFFIX: &str = ".tmp";

/// Suffix a stray tmp file is renamed to when quarantined (kept for
/// post-mortem inspection instead of silently deleted).
pub const QUARANTINE_SUFFIX: &str = ".quarantined";

/// The in-flight path for `target`: same directory, `.tmp` appended to
/// the full file name (`plans/wq.rsrz` → `plans/wq.rsrz.tmp`). Same
/// directory is load-bearing: `rename` is only atomic within one
/// filesystem.
pub fn tmp_path(target: &Path) -> PathBuf {
    let mut name = target
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    name.push_str(TMP_SUFFIX);
    target.with_file_name(name)
}

/// True when `path` names an in-flight temporary — loaders must refuse
/// these even if their bytes happen to parse.
pub fn is_tmp(path: &Path) -> bool {
    path.file_name()
        .map(|n| n.to_string_lossy().ends_with(TMP_SUFFIX))
        .unwrap_or(false)
}

/// Write `path` crash-safely: stream through `write` into
/// `path + ".tmp"`, flush, `fsync`, then atomically rename over the
/// target. On any error the tmp file is removed (best-effort) and the
/// target is left exactly as it was — old content intact, or still
/// absent.
pub fn write_atomic(
    path: impl AsRef<Path>,
    write: impl FnOnce(&mut BufWriter<File>) -> Result<()>,
) -> Result<()> {
    let path = path.as_ref();
    let tmp = tmp_path(path);
    let result = (|| -> Result<()> {
        let mut w = BufWriter::new(File::create(&tmp)?);
        write(&mut w)?;
        w.flush()?;
        // Data must be durable BEFORE the rename publishes the name —
        // otherwise a power cut can leave a complete-looking file with
        // unflushed bytes.
        w.get_ref().sync_all()?;
        drop(w);
        std::fs::rename(&tmp, path)?;
        // Make the rename itself durable (best-effort: some
        // filesystems reject directory fsync; the rename is still
        // atomic without it).
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Move a stray tmp file aside as `<name>.quarantined` (overwriting
/// any previous quarantine of the same name) and return the new path.
pub fn quarantine(tmp: &Path) -> Result<PathBuf> {
    let mut name = tmp
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    name.push_str(QUARANTINE_SUFFIX);
    let dest = tmp.with_file_name(name);
    std::fs::rename(tmp, &dest).map_err(|e| {
        Error::Artifact(format!("quarantining {}: {e}", tmp.display()))
    })?;
    Ok(dest)
}

/// Scan `dir` for stray `*.tmp` leftovers of killed writes and
/// quarantine each, logging a warning per file. Returns the
/// `(tmp, quarantined)` pairs moved. Finished artifacts are untouched.
pub fn quarantine_stray_tmp(dir: &Path) -> Result<Vec<(PathBuf, PathBuf)>> {
    let mut moved = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_file() && is_tmp(&path) {
            let dest = quarantine(&path)?;
            log!(
                Level::Warn,
                "quarantined stray tmp file (killed mid-write?) from={} to={}",
                path.display(),
                dest.display()
            );
            moved.push((path, dest));
        }
    }
    Ok(moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn scratch_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("rsr-atomicfile-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn tmp_path_and_is_tmp() {
        let t = tmp_path(Path::new("plans/wq.rsrz"));
        assert_eq!(t, Path::new("plans/wq.rsrz.tmp"));
        assert!(is_tmp(&t));
        assert!(!is_tmp(Path::new("plans/wq.rsrz")));
        assert!(!is_tmp(Path::new("plans/wq.rsrz.tmp.quarantined")));
    }

    #[test]
    fn successful_write_leaves_only_the_target() {
        let dir = scratch_dir("ok");
        let target = dir.join("out.bin");
        write_atomic(&target, |w| {
            w.write_all(b"payload")?;
            Ok(())
        })
        .unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"payload");
        assert!(!tmp_path(&target).exists(), "tmp must be renamed away");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn failed_write_leaves_old_content_untouched() {
        let dir = scratch_dir("fail");
        let target = dir.join("out.bin");
        std::fs::write(&target, b"old").unwrap();
        let err = write_atomic(&target, |w| {
            w.write_all(b"half-written")?;
            Err(Error::Artifact("simulated mid-write failure".into()))
        })
        .unwrap_err();
        assert!(err.to_string().contains("simulated"), "{err}");
        assert_eq!(
            std::fs::read(&target).unwrap(),
            b"old",
            "target must keep its previous content"
        );
        assert!(!tmp_path(&target).exists(), "failed tmp must be cleaned up");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stray_tmp_files_are_quarantined_not_loaded() {
        let dir = scratch_dir("stray");
        // A finished artifact and a truncated in-flight write, as a
        // kill mid-`write_atomic` leaves them.
        std::fs::write(dir.join("done.rsrz"), b"complete").unwrap();
        let mut f = File::create(dir.join("next.rsrz.tmp")).unwrap();
        f.write_all(b"trunca").unwrap();
        drop(f);
        let moved = quarantine_stray_tmp(&dir).unwrap();
        assert_eq!(moved.len(), 1);
        assert!(!dir.join("next.rsrz.tmp").exists());
        assert!(dir.join("next.rsrz.tmp.quarantined").exists());
        assert_eq!(std::fs::read(dir.join("done.rsrz")).unwrap(), b"complete");
        // Idempotent: a second scan finds nothing.
        assert!(quarantine_stray_tmp(&dir).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
