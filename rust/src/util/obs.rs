//! Observability substrate for the serving stack: a tiny leveled
//! logger, per-request trace timelines ([`TraceRing`]), per-layer
//! execution profiling ([`LayerProfile`]), and a Prometheus
//! text-exposition renderer over the metrics snapshot.
//!
//! Everything here follows one overhead policy (see ARCHITECTURE.md
//! §Observability): when the serving flags are at their defaults the
//! hot path sees a single branch on a disabled `Option`/level — no
//! locks, no allocation, no `Instant::now()`. The only lock any
//! enabled facility takes on the request path is one short
//! [`TraceRing`] mutex at request-terminal time.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::json::Json;

// ---------------------------------------------------------------- //
// Leveled logging                                                   //
// ---------------------------------------------------------------- //

/// Log severity, ordered: a configured level admits itself and
/// everything more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or data-losing conditions.
    Error = 0,
    /// Degraded but self-healing conditions (panic recovery, stale
    /// profiles, dropped responses).
    Warn = 1,
    /// Lifecycle milestones (startup knobs, worker respawn).
    Info = 2,
    /// Per-request chatter; off by default.
    Debug = 3,
}

impl Level {
    /// Parse a `--log-level` value.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    /// Fixed-width lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Process-wide threshold; `Info` until `rsr serve --log-level`
/// (or a test) lowers/raises it.
static LOG_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-wide log threshold.
pub fn set_log_level(level: Level) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current process-wide log threshold.
pub fn log_level() -> Level {
    match LOG_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Would a line at `level` be emitted right now? One relaxed atomic
/// load — the entire cost of a disabled `log!` call site.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Milliseconds since the first observability call in this process
/// (monotonic; the logger's timestamp base).
fn now_ms() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Per-call-site rate limiter: at most [`Gate::BURST`] lines per
/// one-second window, with a summary line counting what was dropped.
/// Lock-free — three relaxed atomics — so a log storm in the worker
/// loop cannot serialize workers on a logging mutex.
pub struct Gate {
    window_start_ms: AtomicU64,
    in_window: AtomicU64,
    suppressed: AtomicU64,
}

impl Gate {
    /// Lines admitted per window before suppression kicks in.
    pub const BURST: u64 = 10;
    const WINDOW_MS: u64 = 1000;

    /// A fresh gate (used as a `static` by the `log!` macro).
    pub const fn new() -> Self {
        Self {
            window_start_ms: AtomicU64::new(0),
            in_window: AtomicU64::new(0),
            suppressed: AtomicU64::new(0),
        }
    }
}

/// Emit one log line through `gate` (the `log!` macro's backend —
/// call the macro, not this). Format:
/// `[   12.345s] warn  module::path: message key=value`.
pub fn emit(gate: &Gate, level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    let now = now_ms();
    let start = gate.window_start_ms.load(Ordering::Relaxed);
    if now.saturating_sub(start) >= Gate::WINDOW_MS {
        // One thread wins the window roll; losers just log into it.
        if gate
            .window_start_ms
            .compare_exchange(start, now, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            gate.in_window.store(0, Ordering::Relaxed);
            let dropped = gate.suppressed.swap(0, Ordering::Relaxed);
            if dropped > 0 {
                eprintln!(
                    "[{:>9.3}s] warn  {target}: rate-limited suppressed={dropped}",
                    now as f64 / 1000.0
                );
            }
        }
    }
    if gate.in_window.fetch_add(1, Ordering::Relaxed) >= Gate::BURST {
        gate.suppressed.fetch_add(1, Ordering::Relaxed);
        return;
    }
    eprintln!("[{:>9.3}s] {:5} {target}: {args}", now as f64 / 1000.0, level.name());
}

/// Leveled, rate-limited logging. Usage:
///
/// ```ignore
/// crate::log!(Level::Warn, "worker panic recovered worker={w} step={s}");
/// ```
///
/// Structured context goes in trailing `key=value` tokens so lines
/// stay grep-able. A disabled level costs one relaxed atomic load;
/// each call site gets its own [`Gate`](util::obs::Gate) so one
/// storming site cannot silence another.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)*) => {{
        if $crate::util::obs::enabled($lvl) {
            static GATE: $crate::util::obs::Gate = $crate::util::obs::Gate::new();
            $crate::util::obs::emit(&GATE, $lvl, module_path!(), format_args!($($arg)*));
        }
    }};
}

// ---------------------------------------------------------------- //
// Per-request trace timelines                                       //
// ---------------------------------------------------------------- //

/// One checkpoint in a request's lifetime. Timestamps are µs since
/// the engine's start epoch (monotonic within one engine).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEventKind {
    /// The engine took responsibility for the request.
    Admitted,
    /// A worker seated it into a decode slot (or picked it up
    /// sequentially).
    Seated,
    /// One chunked-prefill step consumed `tokens` prompt tokens.
    PrefillChunk {
        /// Prompt tokens consumed by this step.
        tokens: u32,
    },
    /// Prefill finished and the first output token was sampled.
    FirstToken,
    /// Coalesced decode steps: `steps` lockstep steps between this
    /// event's `t_us` (first step) and `last_t_us` (latest step).
    /// Updated in place — a 10 000-token generation is one event.
    DecodeSteps {
        /// Steps coalesced into this event.
        steps: u32,
        /// Timestamp of the most recent step (µs since engine epoch).
        last_t_us: u64,
    },
    /// Exactly-one terminal outcome (PR 7 invariant):
    /// `completed` / `failed` / `deadline_exceeded` / `cancelled`.
    Terminal {
        /// The outcome label.
        outcome: &'static str,
    },
}

/// A timestamped trace event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// µs since the engine's start epoch.
    pub t_us: u64,
    /// What happened.
    pub kind: TraceEventKind,
}

/// A completed request timeline, admitted → terminal.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// Request id.
    pub id: u64,
    /// Terminal outcome label.
    pub outcome: &'static str,
    /// Admitted → terminal wall time in µs.
    pub total_us: u64,
    /// The ordered events.
    pub events: Vec<TraceEvent>,
}

impl RequestTrace {
    /// Render one trace as JSON (the `trace` wire schema).
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![("t_us", Json::Num(e.t_us as f64))];
                match &e.kind {
                    TraceEventKind::Admitted => fields.push(("event", Json::str("admitted"))),
                    TraceEventKind::Seated => fields.push(("event", Json::str("seated"))),
                    TraceEventKind::PrefillChunk { tokens } => {
                        fields.push(("event", Json::str("prefill_chunk")));
                        fields.push(("tokens", Json::Num(*tokens as f64)));
                    }
                    TraceEventKind::FirstToken => {
                        fields.push(("event", Json::str("first_token")))
                    }
                    TraceEventKind::DecodeSteps { steps, last_t_us } => {
                        fields.push(("event", Json::str("decode_steps")));
                        fields.push(("steps", Json::Num(*steps as f64)));
                        fields.push(("last_t_us", Json::Num(*last_t_us as f64)));
                    }
                    TraceEventKind::Terminal { outcome } => {
                        fields.push(("event", Json::str("terminal")));
                        fields.push(("outcome", Json::str(outcome)));
                    }
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("outcome", Json::str(self.outcome)),
            ("total_us", Json::Num(self.total_us as f64)),
            ("events", Json::Arr(events)),
        ])
    }
}

/// Slot-local timeline accumulator. Lives inside the worker's
/// `SlotState`, so recording an event is a plain `Vec` push with no
/// synchronization; the shared ring is only touched once, at
/// [`finish`](TraceBuilder::finish) time.
#[derive(Debug)]
pub struct TraceBuilder {
    id: u64,
    admitted_us: u64,
    events: Vec<TraceEvent>,
}

impl TraceBuilder {
    /// Start a timeline at its admission timestamp.
    pub fn new(id: u64, admitted_us: u64) -> Self {
        let mut events = Vec::with_capacity(8);
        events.push(TraceEvent { t_us: admitted_us, kind: TraceEventKind::Admitted });
        Self { id, admitted_us, events }
    }

    /// The worker seated the request.
    pub fn seated(&mut self, t_us: u64) {
        self.events.push(TraceEvent { t_us, kind: TraceEventKind::Seated });
    }

    /// One prefill step consumed `tokens` prompt tokens.
    pub fn prefill_chunk(&mut self, t_us: u64, tokens: u32) {
        self.events
            .push(TraceEvent { t_us, kind: TraceEventKind::PrefillChunk { tokens } });
    }

    /// Prefill done; first output token sampled.
    pub fn first_token(&mut self, t_us: u64) {
        self.events.push(TraceEvent { t_us, kind: TraceEventKind::FirstToken });
    }

    /// One decode step — coalesced in place into the trailing
    /// `DecodeSteps` event (no per-step allocation).
    pub fn decode_step(&mut self, t_us: u64) {
        if let Some(TraceEvent {
            kind: TraceEventKind::DecodeSteps { steps, last_t_us }, ..
        }) = self.events.last_mut()
        {
            *steps += 1;
            *last_t_us = t_us;
            return;
        }
        self.events.push(TraceEvent {
            t_us,
            kind: TraceEventKind::DecodeSteps { steps: 1, last_t_us: t_us },
        });
    }

    /// Seal the timeline with its terminal outcome.
    pub fn finish(mut self, t_us: u64, outcome: &'static str) -> RequestTrace {
        self.events.push(TraceEvent { t_us, kind: TraceEventKind::Terminal { outcome } });
        RequestTrace {
            id: self.id,
            outcome,
            total_us: t_us.saturating_sub(self.admitted_us),
            events: self.events,
        }
    }
}

/// Fixed-capacity ring of recent request traces plus a retained
/// slow-log: any trace that is slower than the configured threshold
/// *or* did not complete cleanly is pinned so a burst of fast traffic
/// cannot evict the interesting timeline before anyone scrapes it.
pub struct TraceRing {
    capacity: usize,
    slow_capacity: usize,
    slow_threshold_us: u64,
    inner: Mutex<RingInner>,
}

struct RingInner {
    recent: VecDeque<RequestTrace>,
    slow: VecDeque<RequestTrace>,
}

impl TraceRing {
    /// Default recent-ring capacity.
    pub const DEFAULT_CAPACITY: usize = 256;
    /// Default slow-log capacity.
    pub const DEFAULT_SLOW_CAPACITY: usize = 64;

    /// Ring with the given capacities and slow threshold.
    pub fn new(capacity: usize, slow_capacity: usize, slow_threshold: Duration) -> Self {
        Self {
            capacity: capacity.max(1),
            slow_capacity: slow_capacity.max(1),
            slow_threshold_us: slow_threshold.as_micros() as u64,
            inner: Mutex::new(RingInner {
                recent: VecDeque::new(),
                slow: VecDeque::new(),
            }),
        }
    }

    /// Ring with default capacities for a `--trace-slow-ms` threshold.
    pub fn with_threshold(slow_threshold: Duration) -> Self {
        Self::new(Self::DEFAULT_CAPACITY, Self::DEFAULT_SLOW_CAPACITY, slow_threshold)
    }

    /// Record a finished trace: one short lock per request terminal —
    /// never on the decode hot path.
    pub fn record(&self, trace: RequestTrace) {
        let pin =
            trace.outcome != "completed" || trace.total_us >= self.slow_threshold_us;
        let mut g = self.inner.lock().unwrap();
        if pin {
            if g.slow.len() >= self.slow_capacity {
                g.slow.pop_front();
            }
            g.slow.push_back(trace.clone());
        }
        if g.recent.len() >= self.capacity {
            g.recent.pop_front();
        }
        g.recent.push_back(trace);
    }

    /// Traces currently in the recent ring.
    pub fn recent_len(&self) -> usize {
        self.inner.lock().unwrap().recent.len()
    }

    /// Traces currently pinned in the slow-log.
    pub fn slow_len(&self) -> usize {
        self.inner.lock().unwrap().slow.len()
    }

    /// Dump both rings as JSON (the `trace` wire command payload).
    pub fn snapshot(&self) -> Json {
        let g = self.inner.lock().unwrap();
        Json::obj(vec![
            ("recent", Json::Arr(g.recent.iter().map(|t| t.to_json()).collect())),
            ("slow", Json::Arr(g.slow.iter().map(|t| t.to_json()).collect())),
        ])
    }
}

// ---------------------------------------------------------------- //
// Per-layer execution profiling                                     //
// ---------------------------------------------------------------- //

/// Lock-free per-(layer, backend) timing aggregate. The executor
/// records into two relaxed atomics; readers snapshot whenever.
pub struct LayerProbe {
    count: AtomicU64,
    total_ns: AtomicU64,
}

impl LayerProbe {
    /// A zeroed probe.
    pub fn new() -> Self {
        Self { count: AtomicU64::new(0), total_ns: AtomicU64::new(0) }
    }

    /// Record one timed execution.
    pub fn record(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Executions recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total nanoseconds recorded so far.
    pub fn total_ns(&self) -> u64 {
        self.total_ns.load(Ordering::Relaxed)
    }
}

/// Registry of layer probes, shared by every worker of an engine.
/// `probe()` dedupes by (layer, backend), so a worker rebuilding its
/// model after a panic re-attaches to the same aggregates instead of
/// forking the history. The mutex is taken at model-(re)build and
/// snapshot time only — executions touch just the probe atomics.
pub struct LayerProfile {
    entries: Mutex<Vec<(String, &'static str, std::sync::Arc<LayerProbe>)>>,
}

impl LayerProfile {
    /// An empty registry.
    pub fn new() -> Self {
        Self { entries: Mutex::new(Vec::new()) }
    }

    /// The shared probe for `(layer, backend)`, created on first use.
    pub fn probe(&self, layer: &str, backend: &'static str) -> std::sync::Arc<LayerProbe> {
        let mut g = self.entries.lock().unwrap();
        if let Some((_, _, p)) =
            g.iter().find(|(l, b, _)| l == layer && *b == backend)
        {
            return std::sync::Arc::clone(p);
        }
        let p = std::sync::Arc::new(LayerProbe::new());
        g.push((layer.to_string(), backend, std::sync::Arc::clone(&p)));
        p
    }

    /// Registered (layer, backend) pairs.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render the aggregates, attributing each layer's share of
    /// `decode_busy_ns` (the engine's total forward time — 0 disables
    /// the share column). Sorted by total time, heaviest first.
    pub fn snapshot(&self, decode_busy_ns: u64) -> Json {
        let g = self.entries.lock().unwrap();
        let mut rows: Vec<(String, &'static str, u64, u64)> = g
            .iter()
            .map(|(l, b, p)| (l.clone(), *b, p.count(), p.total_ns()))
            .collect();
        drop(g);
        rows.sort_by(|a, b| b.3.cmp(&a.3).then_with(|| a.0.cmp(&b.0)));
        let arr = rows
            .into_iter()
            .map(|(layer, backend, count, total_ns)| {
                let share = if decode_busy_ns > 0 {
                    total_ns as f64 / decode_busy_ns as f64
                } else {
                    0.0
                };
                Json::obj(vec![
                    ("layer", Json::Str(layer)),
                    ("backend", Json::str(backend)),
                    ("count", Json::Num(count as f64)),
                    ("total_ns", Json::Num(total_ns as f64)),
                    ("share_of_decode_busy", Json::Num(share)),
                ])
            })
            .collect();
        Json::Arr(arr)
    }
}

// ---------------------------------------------------------------- //
// Prometheus text exposition                                        //
// ---------------------------------------------------------------- //

/// Escape a label value per the Prometheus text format: backslash,
/// double-quote and newline.
pub fn escape_label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Everything one replica contributes to a scrape: its metrics
/// snapshot (see `Metrics::snapshot`) plus the engine-level gauges
/// the snapshot cannot know.
pub struct ReplicaScrape {
    /// Replica index (the `replica` label).
    pub replica: usize,
    /// `Metrics::snapshot()` output.
    pub snapshot: Json,
    /// Requests waiting in the bounded queue.
    pub queue_depth: u64,
    /// Admitted requests not yet terminal (queued + seated).
    pub inflight: u64,
    /// Decode slots currently occupied.
    pub live_slots: u64,
    /// Milliseconds since the last worker heartbeat.
    pub heartbeat_ms: u64,
}

/// Render a number the text format accepts: non-finite values (a
/// snapshot mean over zero observations, say) become 0 so a scraper's
/// NaN guard never trips.
fn fmt_num(v: f64) -> String {
    if !v.is_finite() {
        return "0".into();
    }
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn num_at(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

/// Append `# HELP`/`# TYPE` headers once per metric.
fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Append one histogram family (already-cumulative `buckets` from a
/// `LatencyHistogram`) under `name` with `labels` (no trailing comma;
/// may be empty).
fn render_histogram(out: &mut String, name: &str, labels: &str, phase: &Json) {
    let count = num_at(phase, "count");
    let sum = num_at(phase, "sum_us");
    let sep = if labels.is_empty() { "" } else { "," };
    if let Some(buckets) = phase.get("buckets").and_then(|b| b.as_arr()) {
        for b in buckets {
            if let Some(pair) = b.as_arr() {
                if pair.len() == 2 {
                    let le = pair[0].as_f64().unwrap_or(0.0);
                    let cum = pair[1].as_f64().unwrap_or(0.0);
                    out.push_str(&format!(
                        "{name}_bucket{{{labels}{sep}le=\"{}\"}} {}\n",
                        fmt_num(le),
                        fmt_num(cum)
                    ));
                }
            }
        }
    }
    out.push_str(&format!(
        "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}\n",
        fmt_num(count)
    ));
    out.push_str(&format!("{name}_sum{{{labels}}} {}\n", fmt_num(sum)));
    out.push_str(&format!("{name}_count{{{labels}}} {}\n", fmt_num(count)));
}

/// Render the full Prometheus text exposition for a set of replicas
/// (the `metrics?format=prom` payload).
pub fn render_prometheus(uptime_s: f64, replicas: &[ReplicaScrape]) -> String {
    let mut out = String::with_capacity(4096);
    header(&mut out, "rsr_uptime_seconds", "gauge", "Seconds since the server started.");
    out.push_str(&format!("rsr_uptime_seconds {}\n", fmt_num(uptime_s)));

    // (prom name, snapshot key, help) counter triples.
    let counters: [(&str, &str, &str); 13] = [
        ("rsr_requests_admitted_total", "admitted", "Requests the engine took responsibility for."),
        ("rsr_requests_rejected_total", "rejected_total", "Requests shed at admission (queue full)."),
        ("rsr_requests_completed_total", "completed", "Requests that finished cleanly."),
        ("rsr_requests_failed_total", "failed", "Requests that failed terminally."),
        ("rsr_requests_deadline_exceeded_total", "deadline_exceeded_total", "Requests retired past their deadline."),
        ("rsr_requests_cancelled_total", "cancelled_total", "Requests cancelled by the client."),
        ("rsr_requests_kv_budget_exceeded_total", "kv_budget_exceeded_total", "Requests shed or evicted under the KV byte budget."),
        ("rsr_kv_reservations_failed_total", "kv_reservations_failed_total", "KV page reservations refused at admission or seating."),
        ("rsr_kv_evictions_total", "kv_evictions_total", "Slots evicted youngest-first under KV page pressure."),
        ("rsr_worker_panics_total", "panics_total", "Supervised worker panics."),
        ("rsr_tokens_out_total", "tokens_out", "Output tokens generated."),
        ("rsr_decode_steps_total", "decode_steps", "Lockstep decode steps executed."),
        ("rsr_prefill_tokens_total", "prefill_tokens", "Prompt tokens prefilled."),
    ];
    for (name, key, help) in counters {
        header(&mut out, name, "counter", help);
        for r in replicas {
            out.push_str(&format!(
                "{name}{{replica=\"{}\"}} {}\n",
                r.replica,
                fmt_num(num_at(&r.snapshot, key))
            ));
        }
    }

    let snap_gauges: [(&str, &str, &str); 6] = [
        ("rsr_batch_occupancy_mean", "batch_occupancy_mean", "Mean live slots per decode step."),
        ("rsr_tokens_per_sec", "tokens_per_sec", "Decode throughput over busy time."),
        ("rsr_prefill_tokens_per_sec", "prefill_tokens_per_sec", "Prefill throughput over prefill wall time."),
        ("rsr_kv_pages_total", "kv_pages_total", "KV page budget (0 = unbounded)."),
        ("rsr_kv_pages_in_use", "kv_pages_in_use", "KV pages currently granted."),
        ("rsr_kv_pages_peak", "kv_pages_peak", "High-water mark of granted KV pages."),
    ];
    for (name, key, help) in snap_gauges {
        header(&mut out, name, "gauge", help);
        for r in replicas {
            out.push_str(&format!(
                "{name}{{replica=\"{}\"}} {}\n",
                r.replica,
                fmt_num(num_at(&r.snapshot, key))
            ));
        }
    }

    let engine_gauges: [(&str, &str); 4] = [
        ("rsr_queue_depth", "Requests waiting in the bounded queue."),
        ("rsr_inflight", "Admitted requests not yet terminal."),
        ("rsr_live_slots", "Decode slots currently occupied."),
        ("rsr_heartbeat_age_ms", "Milliseconds since the last worker heartbeat."),
    ];
    for (name, help) in engine_gauges {
        header(&mut out, name, "gauge", help);
        for r in replicas {
            let v = match name {
                "rsr_queue_depth" => r.queue_depth as f64,
                "rsr_inflight" => r.inflight as f64,
                "rsr_live_slots" => r.live_slots as f64,
                _ => r.heartbeat_ms as f64,
            };
            out.push_str(&format!(
                "{name}{{replica=\"{}\"}} {}\n",
                r.replica,
                fmt_num(v)
            ));
        }
    }

    // Phase histograms (µs). `total` is labelled by terminal outcome.
    let phases: [(&str, &str, &str); 4] = [
        ("rsr_request_queue_us", "queue", "Queue wait per request."),
        ("rsr_request_prefill_us", "prefill", "Prefill time per request."),
        ("rsr_request_decode_us", "decode", "Decode time per request."),
        ("rsr_ttft_us", "ttft", "Time to first token per request."),
    ];
    for (name, key, help) in phases {
        header(&mut out, name, "histogram", help);
        for r in replicas {
            if let Some(phase) = r.snapshot.get(key) {
                render_histogram(
                    &mut out,
                    name,
                    &format!("replica=\"{}\"", r.replica),
                    phase,
                );
            }
        }
    }
    header(
        &mut out,
        "rsr_request_total_us",
        "histogram",
        "Admitted-to-terminal latency per request, labelled by outcome.",
    );
    for r in replicas {
        if let Some(Json::Obj(by_outcome)) = r.snapshot.get("total_by_outcome") {
            for (outcome, phase) in by_outcome {
                render_histogram(
                    &mut out,
                    "rsr_request_total_us",
                    &format!(
                        "replica=\"{}\",outcome=\"{}\"",
                        r.replica,
                        escape_label_value(outcome)
                    ),
                    phase,
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_parse() {
        assert!(Level::Error < Level::Debug);
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
        // Threshold gating (restore Info for other tests in this
        // process — the level is global).
        set_log_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_log_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn gate_suppresses_after_burst() {
        let gate = Gate::new();
        for _ in 0..Gate::BURST + 5 {
            emit(&gate, Level::Info, "test", format_args!("line"));
        }
        assert_eq!(gate.suppressed.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn trace_builder_coalesces_decode_steps() {
        let mut b = TraceBuilder::new(7, 100);
        b.seated(150);
        b.prefill_chunk(200, 8);
        b.prefill_chunk(260, 4);
        b.first_token(300);
        for i in 0..1000 {
            b.decode_step(300 + i);
        }
        let t = b.finish(1400, "completed");
        assert_eq!(t.total_us, 1300);
        // admitted, seated, 2 chunks, first_token, ONE decode event,
        // terminal.
        assert_eq!(t.events.len(), 7);
        match &t.events[5].kind {
            TraceEventKind::DecodeSteps { steps, last_t_us } => {
                assert_eq!(*steps, 1000);
                assert_eq!(*last_t_us, 1299);
            }
            k => panic!("expected coalesced decode event, got {k:?}"),
        }
        assert_eq!(
            t.events.last().unwrap().kind,
            TraceEventKind::Terminal { outcome: "completed" }
        );
    }

    #[test]
    fn trace_ring_evicts_recent_and_pins_slow_and_failed() {
        let ring = TraceRing::new(4, 2, Duration::from_millis(10));
        let mk = |id: u64, outcome: &'static str, total_us: u64| {
            let b = TraceBuilder::new(id, 0);
            let mut t = b.finish(total_us, outcome);
            t.total_us = total_us;
            t
        };
        for id in 0..8 {
            ring.record(mk(id, "completed", 100)); // fast, clean
        }
        assert_eq!(ring.recent_len(), 4, "recent ring must evict to capacity");
        assert_eq!(ring.slow_len(), 0, "fast clean traces are not pinned");
        ring.record(mk(100, "completed", 50_000)); // slow
        ring.record(mk(101, "failed", 10)); // failed => pinned
        ring.record(mk(102, "deadline_exceeded", 10));
        assert_eq!(ring.slow_len(), 2, "slow-log must evict to its own capacity");
        let snap = ring.snapshot();
        let slow = snap.get("slow").unwrap().as_arr().unwrap();
        let ids: Vec<f64> =
            slow.iter().map(|t| t.get("id").unwrap().as_f64().unwrap()).collect();
        assert_eq!(ids, vec![101.0, 102.0], "oldest pinned trace evicted first");
    }

    #[test]
    fn layer_profile_dedupes_and_snapshots_shares() {
        let p = LayerProfile::new();
        let a = p.probe("layer0.wq", "rsr++");
        let a2 = p.probe("layer0.wq", "rsr++");
        let b = p.probe("layer0.gate", "tl");
        assert_eq!(p.len(), 2, "same (layer, backend) must dedupe");
        a.record(750);
        a2.record(250);
        b.record(1000);
        let snap = p.snapshot(2000);
        let rows = snap.as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        // Heaviest first; shares against decode_busy_ns.
        for row in rows {
            let share = row.get("share_of_decode_busy").unwrap().as_f64().unwrap();
            assert!((share - 0.5).abs() < 1e-9, "share {share}");
        }
        assert_eq!(
            rows[0].get("count").unwrap().as_f64().unwrap()
                + rows[1].get("count").unwrap().as_f64().unwrap(),
            3.0
        );
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value(r#"a"b"#), r#"a\"b"#);
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("a\nb"), "a\\nb");
    }

    #[test]
    fn fmt_num_guards_non_finite() {
        assert_eq!(fmt_num(f64::NAN), "0");
        assert_eq!(fmt_num(f64::INFINITY), "0");
        assert_eq!(fmt_num(3.0), "3");
        assert_eq!(fmt_num(3.5), "3.5");
    }
}
