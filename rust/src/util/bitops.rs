//! Bit-packing helpers shared by the binary/ternary matrix types.

/// Number of u64 words needed to hold `bits` bits.
#[inline]
pub const fn words_for_bits(bits: usize) -> usize {
    bits.div_ceil(64)
}

/// Set bit `i` in a word slice.
#[inline]
pub fn set_bit(words: &mut [u64], i: usize) {
    words[i >> 6] |= 1u64 << (i & 63);
}

/// Read bit `i` from a word slice.
#[inline]
pub fn get_bit(words: &[u64], i: usize) -> bool {
    (words[i >> 6] >> (i & 63)) & 1 == 1
}

/// Population count over a word slice.
#[inline]
pub fn popcount(words: &[u64]) -> u64 {
    words.iter().map(|w| w.count_ones() as u64).sum()
}

/// Extract `width ≤ 16` bits starting at bit `start` from a packed row.
/// Bits are returned with the *first* (lowest `start`) bit as the MSB,
/// matching the paper's "concatenate B[r,1..k]" row-value convention.
#[inline]
pub fn extract_key_msb_first(words: &[u64], start: usize, width: usize) -> u32 {
    let mut key = 0u32;
    for j in 0..width {
        key = (key << 1) | get_bit(words, start + j) as u32;
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_for_bits_rounds_up() {
        assert_eq!(words_for_bits(0), 0);
        assert_eq!(words_for_bits(1), 1);
        assert_eq!(words_for_bits(64), 1);
        assert_eq!(words_for_bits(65), 2);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut w = vec![0u64; 3];
        for i in [0usize, 1, 63, 64, 100, 191] {
            set_bit(&mut w, i);
        }
        for i in 0..192 {
            let expect = matches!(i, 0 | 1 | 63 | 64 | 100 | 191);
            assert_eq!(get_bit(&w, i), expect, "bit {i}");
        }
        assert_eq!(popcount(&w), 6);
    }

    #[test]
    fn key_extraction_is_msb_first() {
        let mut w = vec![0u64; 1];
        // bits 3..6 = 1,0,1 → key 0b101 = 5 (bit 3 is the MSB).
        set_bit(&mut w, 3);
        set_bit(&mut w, 5);
        assert_eq!(extract_key_msb_first(&w, 3, 3), 0b101);
        // crossing a word boundary
        let mut w2 = vec![0u64; 2];
        set_bit(&mut w2, 62);
        set_bit(&mut w2, 65);
        assert_eq!(extract_key_msb_first(&w2, 62, 4), 0b1001);
    }
}
