//! A minimal JSON writer (no serde in the offline registry).
//!
//! Only what the crate needs: objects, arrays, strings, numbers and
//! booleans, with correct string escaping. Used to emit bench results
//! (`target/bench-results/*.json`), the artifact manifest reader's test
//! fixtures, and serving metrics snapshots.
//!
//! A tiny recursive-descent parser is included for reading
//! `artifacts/manifest.json` written by the python AOT step.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for golden tests.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Numeric value.
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    /// Array of numbers.
    pub fn nums<I: IntoIterator<Item = f64>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Accepts the subset this module writes
    /// (which is all the python side emits).
    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As &str if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_compact_objects() {
        let j = Json::obj(vec![
            ("name", Json::str("fig4")),
            ("n", Json::num(4096.0)),
            ("ok", Json::Bool(true)),
            ("xs", Json::nums([1.0, 2.5])),
        ]);
        assert_eq!(
            j.to_string(),
            r#"{"n":4096,"name":"fig4","ok":true,"xs":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::str("a\"b\\c\nd").to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn round_trips_through_parser() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": null, "e": false}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
