//! A scoped thread pool over `std::thread` (the offline registry has no
//! rayon). Used for block-parallel RSR (paper Appendix C.1.I), the
//! tensorized "GPU" execution path, and the serving engine's workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Number of worker threads to use by default: the machine's available
/// parallelism, overridable with `RSR_THREADS`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("RSR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(chunk_index)` for every index in `0..chunks` across `threads`
/// OS threads, work-stealing from a shared atomic counter.
///
/// Scoped: borrows in `f` may reference the caller's stack.
pub fn parallel_for(threads: usize, chunks: usize, f: impl Fn(usize) + Sync) {
    if chunks == 0 {
        return;
    }
    let threads = threads.max(1).min(chunks);
    if threads == 1 {
        for i in 0..chunks {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= chunks {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `items` in parallel, preserving order.
pub fn parallel_map<T: Sync, R: Send>(
    threads: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    {
        let slots: Vec<SlotPtr<R>> =
            out.iter_mut().map(|s| SlotPtr(s as *mut Option<R>)).collect();
        parallel_for(threads, items.len(), |i| {
            let r = f(&items[i]);
            // SAFETY: each index is visited exactly once (the atomic
            // counter hands out distinct indices), so each slot is
            // written by exactly one thread.
            let p = slots[i].0;
            unsafe { *p = Some(r) };
        });
    }
    out.into_iter().map(|s| s.expect("slot filled")).collect()
}

struct SlotPtr<R>(*mut Option<R>);
// SAFETY: distinct indices → distinct slots; no aliasing writes.
unsafe impl<R: Send> Sync for SlotPtr<R> {}
unsafe impl<R: Send> Send for SlotPtr<R> {}

/// A long-lived pool accepting closures — used by the serving engine
/// where workers persist across requests.
pub struct WorkerPool {
    tx: Option<std::sync::mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    /// Spawn `threads` workers pulling from a shared queue.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("pool queue poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Relaxed);
                        }
                        Err(_) => break, // all senders dropped
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), handles, queued }
    }

    /// Enqueue a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(job))
            .expect("pool workers alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_zero_chunks_is_noop() {
        parallel_for(4, 0, |_| panic!("should not run"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..513).collect();
        let out = parallel_map(7, &items, |&x| x * x);
        let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn worker_pool_runs_jobs_and_drains_on_drop() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop waits for workers
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }
}
