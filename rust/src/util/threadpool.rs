//! Thread pools over `std::thread` (the offline registry has no
//! rayon): one-shot scoped helpers ([`parallel_for`] / [`parallel_map`]),
//! the serving engine's job queue ([`WorkerPool`]), the
//! [`PersistentPool`] that block-parallel RSR execution
//! (paper Appendix C.1.I) dispatches to without spawning threads or
//! taking locks per call, and the shareable [`PoolHandle`] (most
//! importantly [`PoolHandle::global`], the process-wide pool) that
//! lets every parallel plan check one pool out per execute instead of
//! owning its own workers.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::Thread;

/// Number of worker threads to use by default: the machine's available
/// parallelism, overridable with `RSR_THREADS`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("RSR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(chunk_index)` for every index in `0..chunks` across `threads`
/// OS threads, work-stealing from a shared atomic counter.
///
/// Scoped: borrows in `f` may reference the caller's stack.
pub fn parallel_for(threads: usize, chunks: usize, f: impl Fn(usize) + Sync) {
    if chunks == 0 {
        return;
    }
    let threads = threads.max(1).min(chunks);
    if threads == 1 {
        for i in 0..chunks {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= chunks {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `items` in parallel, preserving order.
pub fn parallel_map<T: Sync, R: Send>(
    threads: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    {
        let slots: Vec<SlotPtr<R>> =
            out.iter_mut().map(|s| SlotPtr(s as *mut Option<R>)).collect();
        parallel_for(threads, items.len(), |i| {
            let r = f(&items[i]);
            // SAFETY: each index is visited exactly once (the atomic
            // counter hands out distinct indices), so each slot is
            // written by exactly one thread.
            let p = slots[i].0;
            unsafe { *p = Some(r) };
        });
    }
    out.into_iter().map(|s| s.expect("slot filled")).collect()
}

struct SlotPtr<R>(*mut Option<R>);
// SAFETY: distinct indices → distinct slots; no aliasing writes.
unsafe impl<R: Send> Sync for SlotPtr<R> {}
unsafe impl<R: Send> Send for SlotPtr<R> {}

/// A type-erased borrowed task: a thin data pointer plus a monomorphic
/// trampoline. Erasing the closure type this way (instead of
/// `Box<dyn Fn>`) keeps [`PersistentPool::run`] allocation-free.
#[derive(Clone, Copy)]
struct RawTask {
    /// `&F` with the lifetime erased; valid for the duration of the
    /// generation it was published for (the caller blocks in
    /// [`PersistentPool::run`] until every worker acknowledges).
    data: *const (),
    /// Calls `(*data)(worker, chunk)`.
    call: unsafe fn(*const (), usize, usize),
}

unsafe fn call_task<F: Fn(usize, usize) + Sync>(data: *const (), worker: usize, chunk: usize) {
    (*(data as *const F))(worker, chunk)
}

/// Shared state of a [`PersistentPool`], written by the (single)
/// submitting thread and read by workers under the generation
/// protocol documented on [`PersistentPool`].
struct PoolCore {
    /// The current borrowed task. Written by `run` strictly before the
    /// `generation` bump that publishes it; read by workers strictly
    /// after observing that bump.
    task: UnsafeCell<RawTask>,
    /// The submitting thread's handle, for the end-of-generation
    /// unpark. Same write/read discipline as `task`.
    caller: UnsafeCell<Option<Thread>>,
    /// Bumped (Release) once per `run` call to publish a task.
    generation: AtomicUsize,
    /// Work-stealing chunk counter for the current generation.
    next: AtomicUsize,
    /// Chunk count of the current generation.
    chunks: AtomicUsize,
    /// Workers that have finished the current generation. `run`
    /// returns only when this reaches the worker count, which is what
    /// makes the borrowed `task` pointer sound.
    acks: AtomicUsize,
    /// Set by a worker whose task invocation panicked; `run` observes
    /// it after quiescing and re-raises on the calling thread, so a
    /// panicking task surfaces instead of silently losing a block.
    panicked: AtomicBool,
    /// Set (then all workers unparked) to shut the pool down.
    shutdown: AtomicBool,
}

// SAFETY: the UnsafeCell fields are written only between generations
// (before the Release bump of `generation`, which `run` may do only
// after every worker acknowledged the previous generation) and read by
// workers only after an Acquire load of the new generation — so no
// access to them is ever concurrent. The raw task pointer inside is
// only dereferenced during the generation its referent is pinned for
// (the submitting thread blocks until every worker acks), so moving
// the core between threads (Send, required by `Arc` + `spawn`) is
// equally sound.
unsafe impl Sync for PoolCore {}
unsafe impl Send for PoolCore {}

/// A persistent fork-join pool for borrowed, index-addressed work.
///
/// Built once per [`ParallelRsrPlan`](crate::kernels::parallel::ParallelRsrPlan);
/// each [`run`](Self::run) call then costs two atomic stores, one
/// Release increment and `workers` unparks — **no thread spawn, no
/// mutex, no allocation** on the hot path (the old implementation paid
/// a `thread::scope` spawn per worker per call plus a
/// `Mutex<Vec<Option<&mut [f32]>>>` lock per block).
///
/// Protocol per `run` (one *generation*):
/// 1. the caller writes the erased task + its own thread handle, resets
///    the chunk/ack counters, bumps `generation` (Release) and unparks
///    every worker;
/// 2. workers wake on the Acquire-observed bump, claim chunks from the
///    shared counter, and call the task as `f(worker_index, chunk)`;
/// 3. the caller claims chunks too (as worker index `workers`), then
///    parks until all workers have incremented `acks` — every worker
///    acknowledges every generation, even when it claimed no chunks,
///    which is exactly what licenses reusing the task slot next call.
///
/// `run` takes `&mut self`: one submission at a time, enforced by the
/// borrow checker rather than a runtime lock.
pub struct PersistentPool {
    core: Arc<PoolCore>,
    /// Unpark handles of the workers (fixed at construction).
    worker_threads: Vec<Thread>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for PersistentPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PersistentPool")
            .field("workers", &self.handles.len())
            .finish()
    }
}

impl PersistentPool {
    /// A pool delivering `threads` lanes of parallelism: the calling
    /// thread participates in every `run`, so `threads - 1` workers are
    /// spawned (`threads <= 1` spawns none and `run` degenerates to a
    /// serial loop).
    pub fn new(threads: usize) -> Self {
        let nworkers = threads.max(1) - 1;
        let core = Arc::new(PoolCore {
            task: UnsafeCell::new(RawTask { data: std::ptr::null(), call: noop_task }),
            caller: UnsafeCell::new(None),
            generation: AtomicUsize::new(0),
            next: AtomicUsize::new(0),
            chunks: AtomicUsize::new(0),
            acks: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });
        let handles: Vec<_> = (0..nworkers)
            .map(|worker| {
                let core = Arc::clone(&core);
                let total = nworkers;
                std::thread::spawn(move || worker_loop(&core, worker, total))
            })
            .collect();
        let worker_threads = handles.iter().map(|h| h.thread().clone()).collect();
        Self { core, worker_threads, handles }
    }

    /// Total parallelism (spawned workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f(worker_index, chunk)` for every chunk in `0..chunks`,
    /// work-stealing across the pool; blocks until all chunks are done
    /// *and* every worker has quiesced. `worker_index` is stable within
    /// one call and `< self.threads()` — callers use it to address
    /// per-lane scratch. Borrows in `f` may reference the caller's
    /// stack.
    pub fn run<F: Fn(usize, usize) + Sync>(&mut self, chunks: usize, f: F) {
        if chunks == 0 {
            return;
        }
        let nworkers = self.handles.len();
        if nworkers == 0 {
            for i in 0..chunks {
                f(0, i);
            }
            return;
        }
        let core = &*self.core;
        // SAFETY (task/caller slots): all workers acknowledged the
        // previous generation before the previous `run` returned, and
        // none observes the slots again until the Release bump below.
        unsafe {
            *core.task.get() = RawTask {
                data: &f as *const F as *const (),
                call: call_task::<F>,
            };
            *core.caller.get() = Some(std::thread::current());
        }
        core.chunks.store(chunks, Ordering::Relaxed);
        core.next.store(0, Ordering::Relaxed);
        core.acks.store(0, Ordering::Relaxed);
        // Clear any panic report left by a generation whose run()
        // itself unwound off the caller lane (the sticky flag must
        // never blame a later, successful task).
        core.panicked.store(false, Ordering::Relaxed);
        core.generation.fetch_add(1, Ordering::Release);
        // From here until every worker acks, `f` is borrowed by the
        // workers. The guard performs that wait in its destructor, so
        // the borrow ends before `f` is dropped even if `f` panics on
        // the caller's own lane below (unwind safety of the erased
        // pointer).
        let quiesce = QuiesceGuard { core, nworkers };
        for t in &self.worker_threads {
            t.unpark();
        }
        // The caller is the extra lane, index `nworkers`.
        loop {
            let i = core.next.fetch_add(1, Ordering::Relaxed);
            if i >= chunks {
                break;
            }
            f(nworkers, i);
        }
        drop(quiesce);
        // A worker caught a panic from the task: its chunk's work is
        // incomplete, so the result must not be used — re-raise here
        // (the worker thread itself stays alive for future runs).
        if core.panicked.swap(false, Ordering::AcqRel) {
            panic!("PersistentPool task panicked on a worker thread");
        }
    }
}

/// Blocks (in `drop`) until every worker of the current generation has
/// acknowledged. The Acquire load pairs with each worker's Release
/// ack, making all their writes (the computed output blocks) visible
/// to the caller; `park` can return spuriously, hence the loop, and
/// the timeout bounds any lost-unpark window.
struct QuiesceGuard<'a> {
    core: &'a PoolCore,
    nworkers: usize,
}

impl Drop for QuiesceGuard<'_> {
    fn drop(&mut self) {
        while self.core.acks.load(Ordering::Acquire) < self.nworkers {
            std::thread::park_timeout(std::time::Duration::from_micros(100));
        }
    }
}

unsafe fn noop_task(_: *const (), _: usize, _: usize) {}

fn worker_loop(core: &PoolCore, worker: usize, nworkers: usize) {
    let mut seen = 0usize;
    loop {
        // Park until a new generation is published (or shutdown).
        let current = loop {
            if core.shutdown.load(Ordering::Acquire) {
                return;
            }
            let g = core.generation.load(Ordering::Acquire);
            if g != seen {
                break g;
            }
            std::thread::park();
        };
        seen = current;
        // SAFETY: the Acquire load above synchronizes with the
        // caller's Release bump, so the task/caller slots written
        // before it are visible and no longer being written.
        let task = unsafe { *core.task.get() };
        let caller = unsafe { (*core.caller.get()).clone() };
        let chunks = core.chunks.load(Ordering::Relaxed);
        // Catch panics so a panicking task cannot skip the ack below —
        // an unacked worker would deadlock the caller's quiesce wait
        // (and kill this thread for every future generation). The
        // caller re-raises after quiescing.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let i = core.next.fetch_add(1, Ordering::Relaxed);
            if i >= chunks {
                break;
            }
            // SAFETY: `data` outlives the generation (the caller blocks
            // until this worker's ack below).
            unsafe { (task.call)(task.data, worker, i) };
        }));
        if result.is_err() {
            core.panicked.store(true, Ordering::Release);
        }
        // The caller handle was cloned *before* the ack: after the ack
        // the caller may return and start the next generation, so no
        // shared slot may be touched past this point.
        let prev = core.acks.fetch_add(1, Ordering::Release);
        if prev + 1 == nworkers {
            if let Some(t) = caller {
                t.unpark();
            }
        }
    }
}

impl Drop for PersistentPool {
    fn drop(&mut self) {
        self.core.shutdown.store(true, Ordering::Release);
        for t in &self.worker_threads {
            t.unpark();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A clonable, shareable handle to a [`PersistentPool`].
///
/// The ROADMAP problem this solves: every `ParallelRsrPlan` used to
/// *own* a pool, so a transformer built on the parallel backend spawned
/// `default_threads − 1` parked workers **per weight matrix**. A
/// `PoolHandle` instead lets any number of plans share one pool — most
/// commonly [`PoolHandle::global`], the lazily-created process-wide
/// pool — and check it out per `run` call.
///
/// The checkout is a `try_lock`, not a blocking lock: inside one
/// `run`, the hot path is still the pool's lock-free generation
/// protocol, and when another plan holds the pool (the machine's cores
/// are already busy executing it) the caller degrades to running its
/// chunks serially on its own thread instead of queueing — forward
/// progress is never blocked on a peer's multiply.
#[derive(Clone)]
pub struct PoolHandle {
    inner: Arc<std::sync::Mutex<PersistentPool>>,
    /// Cached lane count so sizing per-lane scratch never takes the lock.
    threads: usize,
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolHandle").field("threads", &self.threads).finish()
    }
}

impl PoolHandle {
    /// A dedicated pool of `threads` lanes (benches and tests that pin
    /// an explicit parallelism; everything else should share
    /// [`global`](Self::global)).
    pub fn new(threads: usize) -> Self {
        let pool = PersistentPool::new(threads);
        let threads = pool.threads();
        Self { inner: Arc::new(std::sync::Mutex::new(pool)), threads }
    }

    /// The process-wide pool, sized [`default_threads`] and created on
    /// first use. Every parallel plan built with `threads = 0` shares
    /// this handle, so N weight matrices cost one set of workers, not N.
    pub fn global() -> PoolHandle {
        static GLOBAL: std::sync::OnceLock<PoolHandle> = std::sync::OnceLock::new();
        GLOBAL.get_or_init(|| PoolHandle::new(default_threads())).clone()
    }

    /// Lanes of parallelism a `run` through this handle can use. Worker
    /// indices passed to the task are `< threads()`.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_index, chunk)` for every chunk in `0..chunks` on
    /// the shared pool if it is free, or serially on the calling thread
    /// (as lane 0) if another plan currently holds it. Semantics
    /// otherwise match [`PersistentPool::run`].
    pub fn run<F: Fn(usize, usize) + Sync>(&self, chunks: usize, f: F) {
        use std::sync::TryLockError;
        match self.inner.try_lock() {
            Ok(mut pool) => pool.run(chunks, f),
            // A panic on a previous checkout poisoned the mutex; the
            // pool itself survived (workers catch task panics), so
            // recover it rather than silently going serial forever.
            Err(TryLockError::Poisoned(p)) => p.into_inner().run(chunks, f),
            Err(TryLockError::WouldBlock) => {
                for i in 0..chunks {
                    f(0, i);
                }
            }
        }
    }
}

/// A long-lived pool accepting closures — used by the serving engine
/// where workers persist across requests.
pub struct WorkerPool {
    tx: Option<std::sync::mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    /// Spawn `threads` workers pulling from a shared queue.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("pool queue poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Relaxed);
                        }
                        Err(_) => break, // all senders dropped
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), handles, queued }
    }

    /// Enqueue a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(job))
            .expect("pool workers alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_zero_chunks_is_noop() {
        parallel_for(4, 0, |_| panic!("should not run"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..513).collect();
        let out = parallel_map(7, &items, |&x| x * x);
        let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn persistent_pool_covers_every_chunk_across_generations() {
        let mut pool = PersistentPool::new(4);
        assert_eq!(pool.threads(), 4);
        for round in 0..20usize {
            let hits: Vec<AtomicUsize> =
                (0..round * 7 + 1).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), |worker, i| {
                assert!(worker < 4);
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "round {round}"
            );
        }
    }

    #[test]
    fn persistent_pool_single_thread_is_serial() {
        let mut pool = PersistentPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut seen = vec![false; 17];
        {
            let cell: Vec<AtomicUsize> = (0..17).map(|_| AtomicUsize::new(0)).collect();
            pool.run(17, |worker, i| {
                assert_eq!(worker, 0);
                cell[i].fetch_add(1, Ordering::Relaxed);
            });
            for (s, c) in seen.iter_mut().zip(cell.iter()) {
                *s = c.load(Ordering::Relaxed) == 1;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn persistent_pool_zero_chunks_is_noop() {
        let mut pool = PersistentPool::new(3);
        pool.run(0, |_, _| panic!("should not run"));
    }

    #[test]
    fn persistent_pool_surfaces_task_panics_and_stays_usable() {
        let mut pool = PersistentPool::new(3);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, |_w, i| {
                if i == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "task panic must surface on the caller");
        // The workers survived (they caught the panic and acked), so
        // the pool keeps working.
        let hits: Vec<AtomicUsize> = (0..10).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_handle_is_shared_and_covers_every_chunk() {
        let handle = PoolHandle::new(3);
        assert_eq!(handle.threads(), 3);
        let clone = handle.clone();
        for h in [&handle, &clone] {
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            h.run(hits.len(), |worker, i| {
                assert!(worker < 3);
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn pool_handle_contention_falls_back_to_serial() {
        // Hold the pool from one thread while another runs through the
        // same handle: the second must complete serially, not deadlock.
        let handle = PoolHandle::new(2);
        let inner = handle.clone();
        let hits: Vec<AtomicUsize> = (0..50).map(|_| AtomicUsize::new(0)).collect();
        handle.run(2, |_w, outer_chunk| {
            if outer_chunk == 0 {
                // Re-entering run() while the pool is checked out takes
                // the serial path (worker index 0 for every chunk).
                inner.run(hits.len(), |w, i| {
                    assert_eq!(w, 0);
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn global_pool_is_one_instance() {
        let a = PoolHandle::global();
        let b = PoolHandle::global();
        assert!(Arc::ptr_eq(&a.inner, &b.inner));
        assert!(a.threads() >= 1);
        let hits: Vec<AtomicUsize> = (0..7).map(|_| AtomicUsize::new(0)).collect();
        a.run(hits.len(), |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_pool_runs_jobs_and_drains_on_drop() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop waits for workers
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }
}
