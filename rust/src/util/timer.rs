//! Wall-clock timing helpers shared by benches and metrics.

use std::time::{Duration, Instant};

/// Time a closure, returning `(result, elapsed)`.
pub fn time<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Run `f` `iters` times and return per-iteration durations.
///
/// A `std::hint::black_box` on the closure result defeats dead-code
/// elimination the same way criterion's `black_box` does.
pub fn time_iters<T>(iters: usize, mut f: impl FnMut() -> T) -> Vec<Duration> {
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let r = f();
        std::hint::black_box(&r);
        out.push(t0.elapsed());
    }
    out
}

/// A stopwatch accumulating named phases — used to attribute serving
/// latency to queueing / batching / execution.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(&'static str, Duration)>,
}

impl PhaseTimer {
    /// Empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`.
    pub fn phase<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let (out, d) = time(f);
        self.phases.push((name, d));
        out
    }

    /// Recorded `(name, duration)` pairs in insertion order.
    pub fn phases(&self) -> &[(&'static str, Duration)] {
        &self.phases
    }

    /// Total across phases.
    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_and_returns() {
        let (v, d) = time(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn time_iters_returns_one_duration_per_iter() {
        let ds = time_iters(5, || 1 + 1);
        assert_eq!(ds.len(), 5);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        let a = t.phase("a", || 21 * 2);
        assert_eq!(a, 42);
        t.phase("b", || ());
        assert_eq!(t.phases().len(), 2);
        assert_eq!(t.phases()[0].0, "a");
        assert!(t.total() >= t.phases()[1].1);
    }
}
