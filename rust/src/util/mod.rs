//! Small self-contained utilities the rest of the crate builds on.
//!
//! This environment resolves crates offline, so facilities that would
//! normally come from `rand`, `rayon`, `serde_json` or `criterion` are
//! provided here instead (see DESIGN.md §Substitutions):
//!
//! * [`rng`] — deterministic `SplitMix64` / `Xoshiro256**` PRNGs,
//! * [`stats`] — streaming mean/stddev/percentile summaries,
//! * [`timer`] — wall-clock measurement helpers,
//! * [`json`] — a minimal JSON writer for metrics and bench reports,
//! * [`threadpool`] — a scoped thread pool over `std::thread`,
//! * [`bitops`] — bit-packing helpers shared by the kernels,
//! * [`obs`] — observability: leveled logging, request trace
//!   timelines, per-layer profiling, Prometheus exposition,
//! * [`atomicfile`] — crash-safe writes (tmp + fsync + atomic rename)
//!   for durable artifacts.

pub mod atomicfile;
pub mod bitops;
pub mod json;
pub mod obs;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;
