//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so benchmarks, workload
//! generators and property tests share these small, well-known PRNGs.
//! Everything downstream is seeded explicitly, which keeps experiments
//! and property-test failures reproducible.

/// SplitMix64 — used to seed other generators and for cheap scalar draws.
///
/// Reference: Steele, Lea & Flood, "Fast splittable pseudorandom number
/// generators", OOPSLA 2014.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from an arbitrary seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main workhorse generator.
///
/// Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
/// generators", ACM TOMS 2021.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed via SplitMix64 state expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift reduction;
    /// the slight modulo bias is irrelevant for workload generation.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// A random boolean with probability `p` of being true.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard-normal draw (Box–Muller; one value per call, simple and
    /// good enough for synthetic activations/weights).
    pub fn normal_f32(&mut self) -> f32 {
        // Avoid ln(0).
        let u1 = (self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of uniform floats in `[lo, hi)`.
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32(lo, hi)).collect()
    }

    /// Vector of small integer-valued floats in `[-m, m]` — exact under
    /// f32 summation for the sizes we test, so correctness tests can use
    /// tight tolerances.
    pub fn int_f32_vec(&mut self, n: usize, m: i32) -> Vec<f32> {
        (0..n)
            .map(|_| (self.range(0, (2 * m + 1) as usize) as i32 - m) as f32)
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Derive an independent generator (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_is_deterministic_and_seed_sensitive() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let av: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        let cv: Vec<u64> = (0..50).map(|_| c.next_u64()).collect();
        assert_eq!(av, bv);
        assert_ne!(av, cv);
    }

    #[test]
    fn next_below_respects_bound() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_f32_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..257).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<_>>());
    }

    #[test]
    fn bernoulli_rate_roughly_matches() {
        let mut r = Rng::new(11);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.25)).count();
        assert!((2000..3000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn normal_has_plausible_moments() {
        let mut r = Rng::new(13);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal_f32()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
