//! Summary statistics for benchmark measurements and serving metrics.

/// A batch of scalar samples with the usual summary statistics.
///
/// Used by the bench harness (per-iteration wall times) and by the
/// serving metrics (request latencies).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from an existing sample vector.
    pub fn from_samples(samples: Vec<f64>) -> Self {
        Self { samples, sorted: false }
    }

    /// Record one sample.
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (0 for n < 2).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - mean) * (x - mean)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Minimum (0 for empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
            .pipe_finite()
    }

    /// Maximum (0 for empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max).pipe_finite()
    }

    /// Percentile in `[0, 100]` by nearest-rank on the sorted samples.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let n = self.samples.len();
        let rank = ((p / 100.0) * (n as f64 - 1.0)).round() as usize;
        self.samples[rank.min(n - 1)]
    }

    /// Median (p50).
    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Borrow the raw samples.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

trait PipeFinite {
    fn pipe_finite(self) -> f64;
}

impl PipeFinite for f64 {
    fn pipe_finite(self) -> f64 {
        if self.is_finite() {
            self
        } else {
            0.0
        }
    }
}

/// Online histogram with exponential bucket boundaries, for latency
/// tracking in the serving layer without storing every sample.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in microseconds.
    bounds_us: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Buckets from 1µs to ~17s, ×2 per bucket.
    pub fn new() -> Self {
        let bounds_us: Vec<u64> = (0..25).map(|i| 1u64 << i).collect();
        let counts = vec![0; bounds_us.len() + 1];
        Self { bounds_us, counts, total: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one latency sample.
    pub fn record(&mut self, d: std::time::Duration) {
        let us = d.as_micros() as u64;
        let idx = self
            .bounds_us
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(self.bounds_us.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    /// Maximum recorded latency in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile (upper bound of the bucket that crosses
    /// the requested rank).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    self.max_us
                };
            }
        }
        self.max_us
    }

    /// Total of all recorded samples in microseconds (the Prometheus
    /// `_sum` series).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Cumulative bucket counts as `(upper_bound_us, cumulative)`
    /// pairs, one per finite bucket — the Prometheus exposition shape
    /// (`le` buckets are cumulative by definition; the `+Inf` bucket
    /// is [`count`](Self::count)). Monotone non-decreasing by
    /// construction.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        let mut cum = 0u64;
        self.bounds_us
            .iter()
            .zip(self.counts.iter())
            .map(|(&b, &c)| {
                cum += c;
                (b, cum)
            })
            .collect()
    }

    /// Merge another histogram into this one (same bucket layout).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn summary_mean_stddev() {
        let s = Summary::from_samples(vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of that classic set is ~2.138.
        assert!((s.stddev() - 2.138).abs() < 0.01, "{}", s.stddev());
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::from_samples((1..=100).map(|x| x as f64).collect());
        // Even count: nearest-rank median is either middle element.
        assert!(s.median() == 50.0 || s.median() == 51.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(90.0) - 90.0).abs() <= 1.0);
    }

    #[test]
    fn summary_empty_is_safe() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.median(), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn histogram_counts_and_percentiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        assert!(h.mean_us() > 400.0 && h.mean_us() < 600.0);
        // p50 of 1..=1000 µs falls in the 512-bucket.
        assert_eq!(h.percentile_us(50.0), 512);
        assert!(h.percentile_us(100.0) >= 1000);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 2, 3, 1000, 1_000_000, 40_000_000] {
            h.record(Duration::from_micros(us));
        }
        let b = h.buckets();
        assert_eq!(b.len(), 25);
        let mut prev = 0;
        for &(bound, cum) in &b {
            assert!(bound.is_power_of_two());
            assert!(cum >= prev, "cumulative counts must be monotone");
            prev = cum;
        }
        // The 40 s sample exceeds the ~16.8 s top bound: it lives only
        // in the implicit +Inf bucket (count()).
        assert_eq!(prev, 5);
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum_us(), 1 + 2 + 3 + 1000 + 1_000_000 + 40_000_000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 1000);
    }
}
