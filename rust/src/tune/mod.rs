//! `tune` — the empirical autotuner: compile per-layer `(k, backend)`
//! execution profiles, once, offline.
//!
//! The paper's §4/Fig 9 shows RSR/RSR++ speedups hinging on the
//! blocking parameter `k`, and its own measurements show the *measured*
//! optimum drifting from the analytic one — the cost models count
//! operations, while the winner on real hardware is decided by cache
//! sizes, AVX2 gather throughput, thread count and each layer's n×m
//! shape. The weights are fixed, so this decision — like preprocessing
//! itself — is a compile-once/serve-many artifact:
//!
//! ```text
//!   offline:  rsr tune --weights m.rtw --out m.rsrt     (measure, once)
//!   serve:    rsr serve --model m.rtw --profile m.rsrt  (dispatch per layer)
//! ```
//!
//! Pipeline:
//!
//! * [`candidates`] — the search space: a `k` window around the
//!   analytic optimum ([`crate::kernels::optimal_k::k_candidates`])
//!   × every serve-time backend ([`TunedBackend`]), pruned to what can
//!   pay off on this host;
//! * [`microbench`] — calibrated inner-repeat / median-of-trials
//!   timing, the one measurement path shared with `rsr bench-kernels`;
//! * [`tuner`] — the driver: one Algorithm-1 run per `(layer, k)`,
//!   every backend timed through the same
//!   [`ExecutablePlan`](crate::runtime::ExecutablePlan) serving uses;
//! * [`profile`] — the versioned, checksummed `.rsrt` format with a
//!   machine fingerprint, rejected on foreign hosts the way `.rsrz`
//!   artifacts are rejected on foreign weights.
//!
//! A [`PlanStore`](crate::runtime::PlanStore) given a profile
//! ([`PlanStore::with_profile`](crate::runtime::PlanStore::with_profile))
//! materializes every layer at its tuned `(k, backend)`; without one,
//! nothing changes — the profile is strictly additive.

pub mod candidates;
pub mod microbench;
pub mod profile;
pub mod tuner;

pub use candidates::{candidate_space, Candidate, TunedBackend};
pub use microbench::{bench, human_ns, BenchOpts, BenchResult};
pub use profile::{
    LayerChoice, LayerProfile, MachineFingerprint, TuneProfile, RSRT_MAGIC, RSRT_VERSION,
};
pub use tuner::{tune_matrix, tune_model, CandidateTiming, LayerReport, TuneOpts, TUNE_BATCH};
