//! The tuner's measurement harness: calibrated inner-repeat,
//! median-of-trials timing.
//!
//! Sub-microsecond multiplies (a tiny layer at favorable `k`) cannot be
//! timed one call at a time — clock granularity and `Instant` overhead
//! swamp the signal. So each *trial* runs the operation `inner` times
//! back-to-back, where `inner` is **calibrated** from a first timed
//! call so one trial lands near a fixed duration; the reported figure
//! is the **median** of the per-trial per-op times (robust against the
//! scheduler preempting a trial, where a mean would smear the outlier
//! in). Built on [`crate::util::timer`] and
//! [`crate::util::stats::Summary`]; this is also the measurement path
//! `rsr bench-kernels` reports, so tuning decisions and the recorded
//! perf trajectory never disagree about methodology.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;
use crate::util::timer::time;

/// Target wall time for one calibrated trial. Long enough that clock
/// granularity is noise, short enough that a default budget buys
/// several trials even for large layers.
const TRIAL_TARGET: Duration = Duration::from_micros(200);

/// Ceiling on calibrated inner repeats (nanosecond-scale ops would
/// otherwise calibrate into the millions and blow the budget on one
/// trial).
const MAX_INNER: usize = 1 << 20;

/// How one configuration was measured.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Median per-op time in nanoseconds — the number the tuner ranks.
    pub median_ns: f64,
    /// Mean per-op time in nanoseconds (reported alongside; not ranked).
    pub mean_ns: f64,
    /// Calibrated ops per trial.
    pub inner: usize,
    /// Trials actually run (budget may stop the loop early).
    pub trials: usize,
}

/// Options for one measurement.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Trials to attempt (median is taken across these).
    pub trials: usize,
    /// Soft wall-time budget for this measurement, calibration
    /// included. At least one trial always runs, so a tiny budget
    /// degrades to fewer/shorter trials, never to no data.
    pub budget: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { trials: 5, budget: Duration::from_millis(50) }
    }
}

/// Human-readable nanoseconds (`1.23ms` / `4.5µs` / `678ns`) — the one
/// formatter every surface that prints microbench numbers shares
/// (`rsr tune`, `rsr inspect`, `rsr bench-kernels`).
pub fn human_ns(ns: f64) -> String {
    if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Measure `f` under `opts`: calibrate inner repeats from one timed
/// warmup call, then run up to `opts.trials` trials of `inner`
/// back-to-back calls and report the median per-op nanoseconds.
pub fn bench(opts: BenchOpts, mut f: impl FnMut()) -> BenchResult {
    let started = Instant::now();
    let trials = opts.trials.max(1);
    // Calibration doubles as warmup (first-touch faults, branch
    // predictors, the pool's first generation).
    let (_, first) = time(&mut f);
    let per_trial = (opts.budget / (trials as u32 + 1)).max(TRIAL_TARGET);
    let inner = if first.is_zero() {
        MAX_INNER
    } else {
        ((per_trial.as_secs_f64() / first.as_secs_f64()) as usize).clamp(1, MAX_INNER)
    };

    let mut per_op_ns = Summary::new();
    for _ in 0..trials {
        let t0 = Instant::now();
        for _ in 0..inner {
            f();
        }
        let dt = t0.elapsed();
        per_op_ns.push(dt.as_secs_f64() * 1e9 / inner as f64);
        // Soft budget: never stop before the first trial lands.
        if started.elapsed() >= opts.budget {
            break;
        }
    }
    BenchResult {
        median_ns: per_op_ns.median(),
        mean_ns: per_op_ns.mean(),
        inner,
        trials: per_op_ns.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_a_positive_median_and_runs_at_least_once() {
        let mut hits = 0usize;
        let r = bench(
            BenchOpts { trials: 3, budget: Duration::from_millis(5) },
            || {
                hits += 1;
                std::hint::black_box((0..64).sum::<u64>());
            },
        );
        assert!(hits >= 1);
        assert!(r.trials >= 1);
        assert!(r.inner >= 1);
        assert!(r.median_ns > 0.0, "median {}", r.median_ns);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn slow_ops_get_few_inner_reps() {
        let r = bench(
            BenchOpts { trials: 2, budget: Duration::from_millis(4) },
            || std::thread::sleep(Duration::from_millis(2)),
        );
        assert_eq!(r.inner, 1, "a 2ms op must not be repeated inside a trial");
        // 2ms per op ≈ 2e6 ns, with generous slack for CI jitter.
        assert!(r.median_ns > 1e6);
    }

    #[test]
    fn budget_bounds_the_trial_count() {
        let r = bench(
            BenchOpts { trials: 100, budget: Duration::from_millis(3) },
            || std::thread::sleep(Duration::from_millis(1)),
        );
        assert!(r.trials < 100, "3ms budget cannot afford 100 x 1ms trials");
        assert!(r.trials >= 1);
    }
}
