//! The tuning driver: walk a model's named layer matrices, measure
//! every [`Candidate`] on each, and assemble the `.rsrt`
//! [`TuneProfile`].
//!
//! Honesty rule: every candidate is timed through the **same**
//! [`ExecutablePlan`](crate::runtime::ExecutablePlan) object the
//! profile-driven serve path will run — same shared-`Arc` plan, same
//! scratch discipline, same pool handle — so the measured ranking
//! transfers to serving rather than being a proxy. One stated caveat:
//! tuning runs alone, so the `parallel` candidate is measured on an
//! **uncontended** shared pool. Under many concurrent engine workers
//! the pool checkout contends (losers run serially — see
//! [`PoolHandle::run`](crate::util::threadpool::PoolHandle::run)) and
//! `rsr++` may overtake it; the serving engine warns when a
//! parallel-winning profile is loaded with multiple workers.
//!
//! Cost shape: preprocessing dominates for big layers, so the candidate
//! walk is grouped by `k` — Algorithm 1 runs once per `(layer, k)` and
//! every backend is timed on that one shared index.

use std::sync::Arc;
use std::time::Duration;

use super::candidates::{candidate_space, Candidate};
use super::microbench::{bench, BenchOpts, BenchResult};
use super::profile::{LayerChoice, LayerProfile, MachineFingerprint, TuneProfile};
use crate::error::{Error, Result};
use crate::kernels::index::TernaryRsrIndex;
use crate::kernels::TernaryMatrix;
use crate::model::weights::ModelWeights;
use crate::runtime::{ExecutablePlan, SharedTernaryPlan};
use crate::util::rng::Rng;

/// The synthetic batch size the `batched` candidate is measured at.
/// [`ExecutablePlan`]'s batched state executes at batch 1 — the honest
/// single-vector serve shape — so profiles record 1 until the tuner
/// grows a per-batch sweep. The value is written into the `.rsrt`
/// header ([`TuneProfile::bench_batch`]); serving warns at startup when
/// its configured `max_slots` differs materially, because a batched
/// ranking measured at one occupancy says little about another.
pub const TUNE_BATCH: usize = 1;

/// Options for one tuning run.
#[derive(Debug, Clone, Copy)]
pub struct TuneOpts {
    /// `k`-window radius around the analytic optimum
    /// ([`crate::kernels::optimal_k::k_candidates`]).
    pub radius: usize,
    /// Soft wall-time measurement budget **per layer**, split evenly
    /// across its candidates (preprocessing is on top — it is the
    /// artifact being produced, not a measurement cost).
    pub budget_per_layer: Duration,
    /// Trials per candidate (the ranked figure is their median).
    pub trials: usize,
}

impl Default for TuneOpts {
    fn default() -> Self {
        Self { radius: 2, budget_per_layer: Duration::from_millis(250), trials: 5 }
    }
}

/// One candidate's measurement on one layer.
#[derive(Debug, Clone)]
pub struct CandidateTiming {
    /// What was measured.
    pub candidate: Candidate,
    /// How it measured.
    pub result: BenchResult,
}

/// Full measurement record for one layer — the profile keeps only the
/// `(backend, k, ns)` chain; this carries the rest for reporting.
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// Layer name.
    pub name: String,
    /// Rows (input length).
    pub rows: usize,
    /// Columns (output length).
    pub cols: usize,
    /// Every candidate timed, fastest first.
    pub timings: Vec<CandidateTiming>,
}

impl LayerReport {
    /// The winning timing.
    pub fn winner(&self) -> &CandidateTiming {
        &self.timings[0]
    }
}

/// Tune one ternary matrix: preprocess each candidate `k` once, time
/// every backend on the shared index, and return the timings sorted
/// fastest-first.
pub fn tune_matrix(name: &str, m: &TernaryMatrix, opts: &TuneOpts) -> Result<LayerReport> {
    let space = candidate_space(m.rows(), opts.radius);
    if space.is_empty() {
        return Err(Error::Config(format!(
            "no tuning candidates for {name} ({}x{})",
            m.rows(),
            m.cols()
        )));
    }
    let bench_opts = BenchOpts {
        trials: opts.trials,
        budget: (opts.budget_per_layer / space.len() as u32)
            .max(Duration::from_micros(500)),
    };
    // A fixed activation per layer: candidates race on identical input.
    let mut rng = Rng::new(0x7E57_0000u64 ^ (m.rows() as u64) ^ ((m.cols() as u64) << 20));
    let v = rng.f32_vec(m.rows(), -1.0, 1.0);
    let mut out = vec![0.0f32; m.cols()];

    let mut timings = Vec::with_capacity(space.len());
    let mut shared: Option<(usize, Arc<SharedTernaryPlan>)> = None;
    for cand in space {
        // Algorithm 1 once per k; every backend shares that index.
        if shared.as_ref().map(|(k, _)| *k) != Some(cand.k) {
            let idx = TernaryRsrIndex::preprocess(m, cand.k);
            shared = Some((cand.k, Arc::new(SharedTernaryPlan::new(idx)?)));
        }
        let plan = Arc::clone(&shared.as_ref().expect("just built").1);
        let mut exec = ExecutablePlan::new(plan, cand.backend)?;
        let result = bench(bench_opts, || {
            exec.execute(&v, &mut out).expect("tuner shapes are fixed");
        });
        timings.push(CandidateTiming { candidate: cand, result });
    }
    timings.sort_by(|a, b| {
        a.result
            .median_ns
            .partial_cmp(&b.result.median_ns)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    Ok(LayerReport { name: name.to_string(), rows: m.rows(), cols: m.cols(), timings })
}

/// Tune every named layer matrix of a model. `progress` is called once
/// per finished layer (the CLI prints a row; tests pass `|_| {}`).
///
/// Returns the assembled profile plus the full per-layer reports.
pub fn tune_model(
    weights: &ModelWeights,
    opts: &TuneOpts,
    mut progress: impl FnMut(&LayerReport),
) -> Result<(TuneProfile, Vec<LayerReport>)> {
    let mut layers = Vec::new();
    let mut reports = Vec::new();
    for (name, m, _scale) in weights.named_matrices() {
        let report = tune_matrix(&name, m, opts)?;
        layers.push(LayerProfile {
            name: report.name.clone(),
            rows: report.rows,
            cols: report.cols,
            chain: report
                .timings
                .iter()
                .map(|t| LayerChoice {
                    backend: t.candidate.backend,
                    k: t.candidate.k,
                    ns: t.result.median_ns,
                })
                .collect(),
        });
        progress(&report);
        reports.push(report);
    }
    let profile = TuneProfile::new(MachineFingerprint::current(), layers)?
        .with_bench_batch(TUNE_BATCH as u32)?;
    Ok((profile, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn fast_opts() -> TuneOpts {
        TuneOpts {
            radius: 0,
            budget_per_layer: Duration::from_millis(2),
            trials: 1,
        }
    }

    #[test]
    fn tune_matrix_measures_every_candidate_and_sorts() {
        let mut rng = Rng::new(41);
        let m = TernaryMatrix::random(96, 48, 1.0 / 3.0, &mut rng);
        let report = tune_matrix("t", &m, &fast_opts()).unwrap();
        assert_eq!(report.timings.len(), candidate_space(96, 0).len());
        assert!(report
            .timings
            .windows(2)
            .all(|w| w[0].result.median_ns <= w[1].result.median_ns));
        assert!(report.winner().result.median_ns > 0.0);
    }

    #[test]
    fn tune_model_covers_every_layer_and_verifies_on_host() {
        let weights = ModelWeights::generate(ModelConfig::tiny(), 55).unwrap();
        let mut seen = 0usize;
        let (profile, reports) =
            tune_model(&weights, &fast_opts(), |_| seen += 1).unwrap();
        let expect = weights.matrix_names().len();
        assert_eq!(profile.len(), expect);
        assert_eq!(reports.len(), expect);
        assert_eq!(seen, expect);
        profile.verify_host().unwrap();
        assert_eq!(profile.bench_batch as usize, TUNE_BATCH);
        let l = profile.get("layer0.wq").unwrap();
        assert_eq!((l.rows, l.cols), (64, 64));
        assert!(!l.chain.is_empty());
    }
}
