//! The tuner's search space: which `(k, backend)` configurations are
//! worth timing for one layer matrix.
//!
//! The analytic `k` optimum (paper §4.2.2, [`crate::kernels::optimal_k`])
//! minimizes an abstract operation count; on real hardware the winner
//! shifts with cache sizes, AVX2 gather throughput, thread count and
//! the layer's n×m shape (paper Fig 9 shows the measured curve moving
//! against the model's). So the tuner measures a **window** of `k`
//! values around the analytic optimum, crossed with every execution
//! backend the serve path can dispatch to — including the
//! scalar-pinned gather variant, which on gather-weak cores beats the
//! AVX2 path the runtime dispatch would otherwise pick.

use crate::error::{Error, Result};
use crate::kernels::flat::simd_gather_available;
use crate::kernels::optimal_k::k_candidates;
use crate::kernels::tl::tl_neon_available;
use crate::util::threadpool::PoolHandle;

/// An execution backend the tuner can select for a layer. This is the
/// *serve-time dispatch* space of
/// [`crate::runtime::ExecutablePlan`] — narrower than
/// [`crate::kernels::Backend`] (no dense baselines: they are what RSR
/// replaces, not a deployment option) but finer where it matters (the
/// scalar/SIMD gather split is invisible to `Backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TunedBackend {
    /// Algorithm 2 with the dense step-2 block product (`O(k·2^k)`).
    Rsr,
    /// Algorithm 2 + 3 with runtime-dispatched (SIMD where available)
    /// segmented-sum gathers — the untuned default.
    RsrPlusPlus,
    /// Algorithm 2 + 3 pinned to the 4-accumulator scalar gather.
    RsrPlusPlusScalar,
    /// RSR++ with blocks fanned across the shared worker pool
    /// (Appendix C.1.I).
    Parallel,
    /// RSR++ in the segment-major interleaved batched layout, executed
    /// at batch 1 — a serial single-accumulator kernel shape.
    Batched,
    /// Precomputed table-lookup execution over grouped 2-bit weight
    /// codes ([`crate::kernels::TlPlan`]), runtime-dispatched to the
    /// best column loop the host has (AVX2 gather / NEON / scalar).
    Tl,
    /// The TL plan pinned to its aarch64 NEON column loop — only
    /// offered (and only loadable) on hosts where NEON is detected.
    TlNeon,
}

impl TunedBackend {
    /// Every backend, in stable `.rsrt` code order.
    pub const ALL: [TunedBackend; 7] = [
        TunedBackend::Rsr,
        TunedBackend::RsrPlusPlus,
        TunedBackend::RsrPlusPlusScalar,
        TunedBackend::Parallel,
        TunedBackend::Batched,
        TunedBackend::Tl,
        TunedBackend::TlNeon,
    ];

    /// Short stable name (CLI / `rsr inspect` / tune reports).
    pub fn name(self) -> &'static str {
        match self {
            TunedBackend::Rsr => "rsr",
            TunedBackend::RsrPlusPlus => "rsr++",
            TunedBackend::RsrPlusPlusScalar => "rsr++-scalar",
            TunedBackend::Parallel => "parallel",
            TunedBackend::Batched => "batched",
            TunedBackend::Tl => "tl",
            TunedBackend::TlNeon => "tl-neon",
        }
    }

    /// Whether this backend can execute on the current host. Foreign
    /// ISA pins (today: `tl-neon` off aarch64) are excluded from the
    /// candidate space and rejected with a clean error by
    /// [`crate::runtime::ExecutablePlan::new`]; `.rsrt` host
    /// fingerprinting keeps such profiles from travelling anyway.
    pub fn available(self) -> bool {
        match self {
            TunedBackend::TlNeon => tl_neon_available(),
            _ => true,
        }
    }

    /// Parse a [`name`](Self::name).
    pub fn from_name(s: &str) -> Option<TunedBackend> {
        TunedBackend::ALL.iter().copied().find(|b| b.name() == s)
    }

    /// Stable on-disk code (`.rsrt` payload).
    pub(crate) fn code(self) -> u32 {
        match self {
            TunedBackend::Rsr => 1,
            TunedBackend::RsrPlusPlus => 2,
            TunedBackend::RsrPlusPlusScalar => 3,
            TunedBackend::Parallel => 4,
            TunedBackend::Batched => 5,
            TunedBackend::Tl => 6,
            TunedBackend::TlNeon => 7,
        }
    }

    /// Decode an on-disk code.
    pub(crate) fn from_code(c: u32) -> Result<TunedBackend> {
        TunedBackend::ALL
            .iter()
            .copied()
            .find(|b| b.code() == c)
            .ok_or_else(|| Error::Artifact(format!("unknown tuned backend code {c}")))
    }
}

/// One configuration to measure: a blocking parameter and a backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// Blocking parameter.
    pub k: usize,
    /// Execution backend.
    pub backend: TunedBackend,
}

/// The candidate grid for a matrix with `rows` input length: the
/// `k`-window of [`k_candidates`] × every [`TunedBackend`] that can
/// pay off on this host. Pruned, not padded:
///
/// * `rsr++-scalar` is dropped when the dispatched path cannot take a
///   SIMD route anyway (the two candidates would be byte-for-byte the
///   same code);
/// * `parallel` is dropped when the shared pool has a single lane;
/// * `tl-neon` is dropped off aarch64 ([`TunedBackend::available`]);
/// * the TL backends appear only at the **first** `k` of the window:
///   TL reconstructs the dense weights from the arenas, so its codes —
///   and its runtime — are identical at every `k`. Timing it once
///   avoids both redundant trials and rebuilding the `O(n·m)` code
///   table per window step.
///
/// Grouped by `k` (all backends of one `k` adjacent) so the tuner
/// preprocesses each index once and times every backend on it.
pub fn candidate_space(rows: usize, radius: usize) -> Vec<Candidate> {
    let simd = simd_gather_available();
    let lanes = PoolHandle::global().threads();
    let mut out = Vec::new();
    for (i, k) in k_candidates(rows, radius).into_iter().enumerate() {
        for backend in TunedBackend::ALL {
            match backend {
                TunedBackend::RsrPlusPlusScalar if !simd => continue,
                TunedBackend::Parallel if lanes < 2 => continue,
                TunedBackend::Tl | TunedBackend::TlNeon if i > 0 => continue,
                b if !b.available() => continue,
                _ => out.push(Candidate { k, backend }),
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_and_codes_round_trip() {
        for b in TunedBackend::ALL {
            assert_eq!(TunedBackend::from_name(b.name()), Some(b));
            assert_eq!(TunedBackend::from_code(b.code()).unwrap(), b);
        }
        assert_eq!(TunedBackend::from_name("dense"), None);
        assert!(TunedBackend::from_code(99).is_err());
    }

    #[test]
    fn space_covers_every_k_with_the_default_backend() {
        let space = candidate_space(1024, 2);
        assert!(!space.is_empty());
        for k in k_candidates(1024, 2) {
            assert!(space
                .iter()
                .any(|c| c.k == k && c.backend == TunedBackend::RsrPlusPlus));
            // RSR rides along at every k too.
            assert!(space.iter().any(|c| c.k == k && c.backend == TunedBackend::Rsr));
        }
        // Grouped by k: once a new k starts, the previous never recurs.
        let ks: Vec<usize> = space.iter().map(|c| c.k).collect();
        let mut seen_end = std::collections::HashSet::new();
        for w in ks.windows(2) {
            if w[0] != w[1] {
                assert!(seen_end.insert(w[0]), "k {} re-opened", w[0]);
            }
        }
    }

    #[test]
    fn scalar_candidate_only_where_simd_dispatch_exists() {
        let has_scalar = candidate_space(512, 1)
            .iter()
            .any(|c| c.backend == TunedBackend::RsrPlusPlusScalar);
        assert_eq!(has_scalar, simd_gather_available());
    }

    #[test]
    fn tl_is_timed_once_per_layer_not_once_per_k() {
        let space = candidate_space(1024, 2);
        let tl: Vec<&Candidate> =
            space.iter().filter(|c| c.backend == TunedBackend::Tl).collect();
        assert_eq!(tl.len(), 1, "tl is k-invariant; time it once");
        assert_eq!(tl[0].k, k_candidates(1024, 2)[0]);
        let neon = space
            .iter()
            .filter(|c| c.backend == TunedBackend::TlNeon)
            .count();
        assert_eq!(neon, usize::from(tl_neon_available()));
    }

    #[test]
    fn every_candidate_is_available_on_this_host() {
        for c in candidate_space(256, 2) {
            assert!(c.backend.available(), "{} offered but unavailable", c.backend.name());
        }
        // And availability only ever excludes the foreign-ISA pin.
        for b in TunedBackend::ALL {
            if b != TunedBackend::TlNeon {
                assert!(b.available(), "{}", b.name());
            }
        }
    }
}
