//! The **`.rsrt` tuning profile** — the durable output of `rsr tune`.
//!
//! Tuning, like preprocessing, is a compile-once/serve-many artifact:
//! the weights never change, and for one machine the measured winner
//! per layer does not either. A `.rsrt` file records, per named layer,
//! the measured preference chain of `(backend, k)` configurations —
//! `chain[0]` is the winner a profile-driven
//! [`PlanStore`](crate::runtime::PlanStore) materializes, the rest is
//! the fallback order `rsr inspect` shows and future policy can demote
//! to.
//!
//! Measured numbers are only meaningful on the machine that produced
//! them, so the header carries a **machine fingerprint** (CPU feature
//! flags + thread count) and loading a profile on a host whose
//! fingerprint differs is an error, mirroring how `.rsrz` artifacts
//! bind to the exact weights they were compiled from.
//!
//! ## On-disk layout (version 2, all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "RSRT"
//! 4       4     format version (u32) — currently 2 (v1 still readable)
//! 8       4     machine feature flags (u32; bit 0 x86-64, bit 1
//!               aarch64, bit 2 AVX2-gather)
//! 12      4     machine thread count (u32)
//! 16      4     bench batch size (u32, v2 only) — the synthetic batch
//!               the `batched` candidate was measured at; serving warns
//!               when its configured `max_slots` differs materially
//! 20      4     layer count (u32)
//! 24      8     body length (u64)
//! 32      8     FNV-1a 64 checksum (u64) over the body bytes followed
//!               by every other header field — a flipped bit in the
//!               thread count is as fatal as one in a measured time
//! 40      …     body: per layer —
//!                 name length (u32), UTF-8 name,
//!                 rows (u32), cols (u32),
//!                 chain length (u32), then per chain entry:
//!                   backend code (u32), k (u32), median ns (f64 bits)
//! ```
//!
//! Version 1 files (no bench-batch field; layer count at offset 16)
//! still load, with the bench batch defaulting to 1 — the value every
//! v1 profile was in fact measured at. Re-saving writes v2.
//!
//! Decoding re-validates everything after the checksum passes: name and
//! chain caps, `k` range, backend codes, finite non-negative times —
//! the same trust-on-load discipline as
//! [`crate::kernels::artifact`].

use std::io::{Read, Write};
use std::path::Path;

use super::candidates::TunedBackend;
use crate::error::{Error, Result};
use crate::kernels::artifact::{fnv1a64, fnv1a64_continue, read_arr, read_u32};
use crate::kernels::flat::simd_gather_available;
use crate::kernels::tl::tl_neon_available;
use crate::util::threadpool::default_threads;

/// The `.rsrt` magic bytes.
pub const RSRT_MAGIC: &[u8; 4] = b"RSRT";

/// The format version this build writes (it also reads version 1).
pub const RSRT_VERSION: u32 = 2;

/// Caps mirroring the `.rsrz` reader: bound what a corrupt header can
/// ask the allocator for.
const MAX_LAYERS: usize = 1 << 20;
const MAX_NAME: usize = 4096;
const MAX_CHAIN: usize = 256;
const MAX_BODY: usize = 1 << 28;
const MAX_DIM: usize = 1 << 20;
const MAX_BATCH: usize = 1 << 16;

/// Machine feature bits stored in the fingerprint.
const FEAT_X86_64: u32 = 1 << 0;
const FEAT_AARCH64: u32 = 1 << 1;
const FEAT_AVX2_GATHER: u32 = 1 << 2;
const FEAT_NEON: u32 = 1 << 3;

/// What `rsr tune` measured *on*: the CPU features that change which
/// kernels exist (the AVX2 gather path) plus the thread count that
/// changes what `parallel` is worth. Two hosts with equal fingerprints
/// agree on the candidate space and roughly on its ranking; anything
/// else must re-tune.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachineFingerprint {
    /// Feature bit set (see the `FEAT_*` constants).
    pub features: u32,
    /// Lanes of parallelism available ([`default_threads`]).
    pub threads: u32,
}

impl MachineFingerprint {
    /// Fingerprint of the current host.
    pub fn current() -> Self {
        let mut features = 0u32;
        if cfg!(target_arch = "x86_64") {
            features |= FEAT_X86_64;
        }
        if cfg!(target_arch = "aarch64") {
            features |= FEAT_AARCH64;
        }
        if simd_gather_available() {
            features |= FEAT_AVX2_GATHER;
        }
        if tl_neon_available() {
            features |= FEAT_NEON;
        }
        Self { features, threads: default_threads() as u32 }
    }

    /// Human-readable form, e.g. `x86_64+avx2/8t`.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.features & FEAT_X86_64 != 0 {
            parts.push("x86_64");
        }
        if self.features & FEAT_AARCH64 != 0 {
            parts.push("aarch64");
        }
        if self.features & FEAT_AVX2_GATHER != 0 {
            parts.push("avx2");
        }
        if self.features & FEAT_NEON != 0 {
            parts.push("neon");
        }
        if parts.is_empty() {
            parts.push("generic");
        }
        format!("{}/{}t", parts.join("+"), self.threads)
    }
}

/// One measured configuration in a layer's preference chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerChoice {
    /// Execution backend.
    pub backend: TunedBackend,
    /// Blocking parameter the index must be built with.
    pub k: usize,
    /// Measured median nanoseconds per multiply.
    pub ns: f64,
}

/// The tuning result for one named layer matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfile {
    /// Layer name (the [`PlanStore`](crate::runtime::PlanStore) key,
    /// e.g. `layer0.wq`).
    pub name: String,
    /// Rows of the tuned matrix (input length) — sanity-checked against
    /// the served model.
    pub rows: usize,
    /// Columns (output length).
    pub cols: usize,
    /// Measured configurations, fastest first; never empty.
    pub chain: Vec<LayerChoice>,
}

impl LayerProfile {
    /// The winning configuration (`chain[0]`).
    pub fn winner(&self) -> &LayerChoice {
        &self.chain[0]
    }
}

/// A full tuning profile: the machine it was measured on plus one
/// [`LayerProfile`] per tuned layer.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneProfile {
    /// The measuring host.
    pub fingerprint: MachineFingerprint,
    /// The synthetic batch size the `batched` candidate was measured at
    /// ([`crate::tune::tuner::TUNE_BATCH`]). Serving compares it to the
    /// engine's configured `max_slots` and warns on a material gap —
    /// a batched ranking measured at batch 1 says little about batch 16.
    pub bench_batch: u32,
    /// Per-layer results, in tuning order.
    pub layers: Vec<LayerProfile>,
}

impl TuneProfile {
    /// Assemble a profile. Every layer must carry a non-empty chain and
    /// in-range geometry (the same invariants loading enforces). The
    /// bench batch defaults to 1 ([`with_bench_batch`](Self::with_bench_batch)).
    pub fn new(
        fingerprint: MachineFingerprint,
        layers: Vec<LayerProfile>,
    ) -> Result<Self> {
        let p = Self { fingerprint, bench_batch: 1, layers };
        p.validate()?;
        Ok(p)
    }

    /// Record the batch size the `batched` candidate was measured at.
    pub fn with_bench_batch(mut self, bench_batch: u32) -> Result<Self> {
        self.bench_batch = bench_batch;
        self.validate()?;
        Ok(self)
    }

    fn validate(&self) -> Result<()> {
        if self.bench_batch == 0 || self.bench_batch as usize > MAX_BATCH {
            return Err(Error::Artifact(format!(
                "tuning profile bench batch {} out of range 1..={MAX_BATCH}",
                self.bench_batch
            )));
        }
        if self.layers.len() > MAX_LAYERS {
            return Err(Error::Artifact(format!(
                "tuning profile has {} layers (cap {MAX_LAYERS})",
                self.layers.len()
            )));
        }
        for l in &self.layers {
            if l.name.is_empty() || l.name.len() > MAX_NAME {
                return Err(Error::Artifact(format!(
                    "tuning profile layer name length {} out of range",
                    l.name.len()
                )));
            }
            if l.rows == 0 || l.cols == 0 || l.rows > MAX_DIM || l.cols > MAX_DIM {
                return Err(Error::Artifact(format!(
                    "layer {}: implausible dimensions {}x{}",
                    l.name, l.rows, l.cols
                )));
            }
            if l.chain.is_empty() || l.chain.len() > MAX_CHAIN {
                return Err(Error::Artifact(format!(
                    "layer {}: chain length {} out of range 1..={MAX_CHAIN}",
                    l.name,
                    l.chain.len()
                )));
            }
            for c in &l.chain {
                if c.k == 0 || c.k > 16 {
                    return Err(Error::Artifact(format!(
                        "layer {}: blocking parameter k={} out of range",
                        l.name, c.k
                    )));
                }
                if !c.ns.is_finite() || c.ns < 0.0 {
                    return Err(Error::Artifact(format!(
                        "layer {}: measured time {} is not a finite non-negative ns",
                        l.name, c.ns
                    )));
                }
            }
        }
        Ok(())
    }

    /// Number of tuned layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// True when no layers were tuned.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Look up one layer by name.
    pub fn get(&self, name: &str) -> Option<&LayerProfile> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Reject this profile unless it was measured on a machine with the
    /// current host's fingerprint. The error is deliberately distinct
    /// from any format error: the file is *valid*, just not *for this
    /// machine*.
    pub fn verify_host(&self) -> Result<()> {
        let host = MachineFingerprint::current();
        if self.fingerprint != host {
            return Err(Error::Config(format!(
                "tuning profile was measured on a different machine \
                 (profile {}, host {}) — re-run `rsr tune` on this host",
                self.fingerprint.describe(),
                host.describe()
            )));
        }
        Ok(())
    }

    /// Serialize to a `.rsrt` stream.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        self.validate()?;
        let mut body = Vec::new();
        for l in &self.layers {
            body.extend_from_slice(&(l.name.len() as u32).to_le_bytes());
            body.extend_from_slice(l.name.as_bytes());
            body.extend_from_slice(&(l.rows as u32).to_le_bytes());
            body.extend_from_slice(&(l.cols as u32).to_le_bytes());
            body.extend_from_slice(&(l.chain.len() as u32).to_le_bytes());
            for c in &l.chain {
                body.extend_from_slice(&c.backend.code().to_le_bytes());
                body.extend_from_slice(&(c.k as u32).to_le_bytes());
                body.extend_from_slice(&c.ns.to_bits().to_le_bytes());
            }
        }
        let checksum = profile_checksum(
            RSRT_VERSION,
            &self.fingerprint,
            self.bench_batch,
            self.layers.len(),
            &body,
        );
        w.write_all(RSRT_MAGIC)?;
        for v in [
            RSRT_VERSION,
            self.fingerprint.features,
            self.fingerprint.threads,
            self.bench_batch,
            self.layers.len() as u32,
        ] {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&(body.len() as u64).to_le_bytes())?;
        w.write_all(&checksum.to_le_bytes())?;
        w.write_all(&body)?;
        Ok(())
    }

    /// Deserialize from a `.rsrt` stream: header checks → checksum →
    /// decode → full structural validation.
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != RSRT_MAGIC {
            return Err(Error::Artifact(
                "bad magic (not a .rsrt tuning profile)".into(),
            ));
        }
        let version = read_u32(r)?;
        if version == 0 || version > RSRT_VERSION {
            return Err(Error::Artifact(format!(
                "unsupported .rsrt version {version} (this build reads versions \
                 1..={RSRT_VERSION})"
            )));
        }
        let features = read_u32(r)?;
        let threads = read_u32(r)?;
        // v1 predates the bench-batch header field; every v1 profile
        // was measured at batch 1.
        let bench_batch = if version >= 2 { read_u32(r)? } else { 1 };
        let layer_count = read_u32(r)? as usize;
        let body_len = u64::from_le_bytes(read_arr(r)?) as usize;
        let checksum = u64::from_le_bytes(read_arr(r)?);
        if layer_count > MAX_LAYERS {
            return Err(Error::Artifact(format!(
                "implausible layer count {layer_count}"
            )));
        }
        if body_len > MAX_BODY {
            return Err(Error::Artifact(format!(
                "implausible body length {body_len}"
            )));
        }
        let mut body = Vec::new();
        body.try_reserve_exact(body_len).map_err(|_| {
            Error::Artifact(format!("cannot allocate {body_len} body bytes"))
        })?;
        body.resize(body_len, 0);
        r.read_exact(&mut body)?;
        let fingerprint = MachineFingerprint { features, threads };
        if profile_checksum(version, &fingerprint, bench_batch, layer_count, &body)
            != checksum
        {
            return Err(Error::Artifact(
                "checksum mismatch (corrupt tuning profile header or body)".into(),
            ));
        }

        let mut off = 0usize;
        let mut layers = Vec::with_capacity(layer_count.min(1024));
        for _ in 0..layer_count {
            let name_len = read_body_u32(&body, &mut off)? as usize;
            if name_len > MAX_NAME {
                return Err(Error::Artifact(format!("layer name too long ({name_len})")));
            }
            let name_bytes = read_body_bytes(&body, &mut off, name_len)?;
            let name = String::from_utf8(name_bytes.to_vec())
                .map_err(|e| Error::Artifact(e.to_string()))?;
            let rows = read_body_u32(&body, &mut off)? as usize;
            let cols = read_body_u32(&body, &mut off)? as usize;
            let chain_len = read_body_u32(&body, &mut off)? as usize;
            if chain_len > MAX_CHAIN {
                return Err(Error::Artifact(format!(
                    "layer {name}: chain length {chain_len} out of range"
                )));
            }
            let mut chain = Vec::with_capacity(chain_len);
            for _ in 0..chain_len {
                let backend = TunedBackend::from_code(read_body_u32(&body, &mut off)?)?;
                let k = read_body_u32(&body, &mut off)? as usize;
                let bits = read_body_bytes(&body, &mut off, 8)?;
                let ns = f64::from_bits(u64::from_le_bytes(bits.try_into().unwrap()));
                chain.push(LayerChoice { backend, k, ns });
            }
            layers.push(LayerProfile { name, rows, cols, chain });
        }
        if off != body.len() {
            return Err(Error::Artifact(format!(
                "tuning profile body has {} trailing bytes",
                body.len() - off
            )));
        }
        Self::new(fingerprint, layers)?.with_bench_batch(bench_batch)
    }

    /// Write to a file crash-safely (tmp + fsync + atomic rename): a
    /// kill mid-`rsr tune` leaves the old profile, the complete new
    /// one, or a stray `*.tmp` that loaders refuse — never a
    /// loadable-but-corrupt file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        crate::util::atomicfile::write_atomic(path, |w| self.write_to(w))
    }

    /// Read + validate from a file (host fingerprint is **not** checked
    /// here — `rsr inspect` must read foreign profiles; serve-time
    /// loaders call [`verify_host`](Self::verify_host)). In-flight
    /// `*.tmp` names are refused outright.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if crate::util::atomicfile::is_tmp(path) {
            return Err(Error::Artifact(format!(
                "{} is an in-flight temporary from an interrupted write, \
                 not a finished tuning profile",
                path.display()
            )));
        }
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Self::read_from(&mut f)
    }
}

/// FNV-1a over the body, continued over every other header field —
/// computed from *parsed* values on read, exactly like the `.rsrz`
/// checksum, so surviving header corruption still fails the comparison.
/// The bench-batch field joins the hash from version 2 on (hashing it
/// into v1 checksums would break every existing profile).
fn profile_checksum(
    version: u32,
    fp: &MachineFingerprint,
    bench_batch: u32,
    layer_count: usize,
    body: &[u8],
) -> u64 {
    let mut h = fnv1a64(body);
    for v in [version, fp.features, fp.threads] {
        h = fnv1a64_continue(h, &v.to_le_bytes());
    }
    if version >= 2 {
        h = fnv1a64_continue(h, &bench_batch.to_le_bytes());
    }
    h = fnv1a64_continue(h, &(layer_count as u32).to_le_bytes());
    fnv1a64_continue(h, &(body.len() as u64).to_le_bytes())
}

fn read_body_bytes<'a>(body: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *off + n > body.len() {
        return Err(Error::Artifact("tuning profile body truncated".into()));
    }
    let s = &body[*off..*off + n];
    *off += n;
    Ok(s)
}

fn read_body_u32(body: &[u8], off: &mut usize) -> Result<u32> {
    let b = read_body_bytes(body, off, 4)?;
    Ok(u32::from_le_bytes(b.try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_profile() -> TuneProfile {
        TuneProfile::new(
            MachineFingerprint::current(),
            vec![
                LayerProfile {
                    name: "layer0.wq".into(),
                    rows: 64,
                    cols: 64,
                    chain: vec![
                        LayerChoice { backend: TunedBackend::RsrPlusPlus, k: 5, ns: 810.0 },
                        LayerChoice { backend: TunedBackend::Rsr, k: 4, ns: 1024.5 },
                    ],
                },
                LayerProfile {
                    name: "lm_head".into(),
                    rows: 64,
                    cols: 270,
                    chain: vec![LayerChoice {
                        backend: TunedBackend::Parallel,
                        k: 6,
                        ns: 2048.25,
                    }],
                },
            ],
        )
        .unwrap()
    }

    #[test]
    fn round_trip_preserves_everything() {
        let p = sample_profile();
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let back = TuneProfile::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.get("lm_head").unwrap().winner().k, 6);
        assert!(back.get("nope").is_none());
        assert_eq!(back.len(), 2);
    }

    #[test]
    fn v2_records_bench_batch_and_v1_defaults_to_one() {
        let p = sample_profile().with_bench_batch(8).unwrap();
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let back = TuneProfile::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.bench_batch, 8);
        assert_eq!(back, p);

        // Hand-serialize the same layers as version 1 (no bench-batch
        // header field, v1 checksum): it must still load, at batch 1 —
        // the value every v1 profile was in fact measured at.
        let mut body = Vec::new();
        for l in &p.layers {
            body.extend_from_slice(&(l.name.len() as u32).to_le_bytes());
            body.extend_from_slice(l.name.as_bytes());
            body.extend_from_slice(&(l.rows as u32).to_le_bytes());
            body.extend_from_slice(&(l.cols as u32).to_le_bytes());
            body.extend_from_slice(&(l.chain.len() as u32).to_le_bytes());
            for c in &l.chain {
                body.extend_from_slice(&c.backend.code().to_le_bytes());
                body.extend_from_slice(&(c.k as u32).to_le_bytes());
                body.extend_from_slice(&c.ns.to_bits().to_le_bytes());
            }
        }
        let header = [
            1u32,
            p.fingerprint.features,
            p.fingerprint.threads,
            p.layers.len() as u32,
        ];
        let mut h = fnv1a64(&body);
        for v in header {
            h = fnv1a64_continue(h, &v.to_le_bytes());
        }
        let checksum = fnv1a64_continue(h, &(body.len() as u64).to_le_bytes());
        let mut v1 = Vec::new();
        v1.extend_from_slice(RSRT_MAGIC);
        for v in header {
            v1.extend_from_slice(&v.to_le_bytes());
        }
        v1.extend_from_slice(&(body.len() as u64).to_le_bytes());
        v1.extend_from_slice(&checksum.to_le_bytes());
        v1.extend_from_slice(&body);
        let old = TuneProfile::read_from(&mut v1.as_slice()).unwrap();
        assert_eq!(old.bench_batch, 1);
        assert_eq!(old.layers, p.layers);

        // A zero bench batch is rejected at construction.
        assert!(sample_profile().with_bench_batch(0).is_err());
    }

    #[test]
    fn version_and_magic_are_checked() {
        let p = sample_profile();
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(TuneProfile::read_from(&mut bad.as_slice()).is_err());
        let mut bad = buf;
        bad[4] = 42;
        let err = TuneProfile::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(err.to_string().contains("version 42"), "{err}");
    }

    #[test]
    fn corruption_and_truncation_are_rejected() {
        let p = sample_profile();
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        // Body bit flip → checksum.
        let mut bad = buf.clone();
        let last = bad.len() - 3;
        bad[last] ^= 0x40;
        let err = TuneProfile::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Header bit flip (thread count, offset 12) → checksum.
        let mut bad = buf.clone();
        bad[12] ^= 0x01;
        let err = TuneProfile::read_from(&mut bad.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Truncation.
        for cut in [buf.len() - 1, buf.len() / 2, 10] {
            assert!(TuneProfile::read_from(&mut &buf[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn host_verification_distinguishes_machines() {
        let mut p = sample_profile();
        p.verify_host().unwrap();
        p.fingerprint.threads += 1;
        let err = p.verify_host().unwrap_err();
        assert!(err.to_string().contains("different machine"), "{err}");
        // A foreign profile still round-trips (inspect must read it)…
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let back = TuneProfile::read_from(&mut buf.as_slice()).unwrap();
        // …but keeps failing host verification after the trip.
        assert!(back.verify_host().is_err());
    }

    #[test]
    fn invalid_profiles_cannot_be_constructed() {
        let fp = MachineFingerprint::current();
        let bad_chain = LayerProfile {
            name: "x".into(),
            rows: 4,
            cols: 4,
            chain: vec![],
        };
        assert!(TuneProfile::new(fp, vec![bad_chain]).is_err());
        let bad_k = LayerProfile {
            name: "x".into(),
            rows: 4,
            cols: 4,
            chain: vec![LayerChoice { backend: TunedBackend::Rsr, k: 17, ns: 1.0 }],
        };
        assert!(TuneProfile::new(fp, vec![bad_k]).is_err());
        let bad_ns = LayerProfile {
            name: "x".into(),
            rows: 4,
            cols: 4,
            chain: vec![LayerChoice {
                backend: TunedBackend::Rsr,
                k: 3,
                ns: f64::NAN,
            }],
        };
        assert!(TuneProfile::new(fp, vec![bad_ns]).is_err());
    }

    #[test]
    fn fingerprint_describe_is_stable_shape() {
        let d = MachineFingerprint::current().describe();
        assert!(d.contains("/"), "{d}");
        assert!(d.ends_with('t'), "{d}");
    }
}
