//! Model architecture configuration and the paper's evaluation shapes.

use crate::error::{Error, Result};

/// Architecture of a decoder-only ternary transformer.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Human-readable name (appears in bench reports).
    pub name: String,
    /// Vocabulary size (byte-level tokenizer → small).
    pub vocab_size: usize,
    /// Hidden width `d_model`.
    pub d_model: usize,
    /// Number of decoder blocks.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// KV heads (GQA; `n_kv_heads == n_heads` → MHA).
    pub n_kv_heads: usize,
    /// Feed-forward inner width.
    pub d_ff: usize,
    /// Maximum sequence length (KV cache capacity).
    pub max_seq_len: usize,
    /// RoPE base frequency.
    pub rope_theta: f32,
}

impl ModelConfig {
    /// Per-head dimension.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameters (approximate, for reporting).
    pub fn param_count(&self) -> usize {
        let attn = self.d_model * self.d_model * 2
            + 2 * self.d_model * (self.n_kv_heads * self.head_dim());
        let mlp = 3 * self.d_model * self.d_ff;
        let emb = self.vocab_size * self.d_model;
        self.n_layers * (attn + mlp) + 2 * emb
    }

    /// Validate divisibility constraints.
    pub fn validate(&self) -> Result<()> {
        if self.d_model % self.n_heads != 0 {
            return Err(Error::Config("d_model % n_heads != 0".into()));
        }
        if self.n_heads % self.n_kv_heads != 0 {
            return Err(Error::Config("n_heads % n_kv_heads != 0".into()));
        }
        if self.head_dim() % 2 != 0 {
            return Err(Error::Config("head_dim must be even for RoPE".into()));
        }
        if self.vocab_size == 0 || self.n_layers == 0 || self.max_seq_len == 0 {
            return Err(Error::Config("zero-sized model dimension".into()));
        }
        Ok(())
    }

    /// Tiny config for unit tests (runs in milliseconds).
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            vocab_size: 270,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 128,
            max_seq_len: 128,
            rope_theta: 10_000.0,
        }
    }

    /// ~125M-parameter-shape model for the end-to-end example
    /// (`examples/llm_inference.rs`).
    pub fn small_125m() -> Self {
        Self {
            name: "small-125m".into(),
            vocab_size: 270,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            n_kv_heads: 12,
            d_ff: 3072,
            max_seq_len: 512,
            rope_theta: 10_000.0,
        }
    }

    /// Llama3-8B-1.58bit proxy: the paper states its matrix sizes range
    /// `2^12..2^13` (d=4096, ffn=14336→trimmed to 8192 = 2^13 band).
    /// Depth is trimmed to 4 blocks — Fig 6 measures *per-layer* matmul
    /// speedup, which is depth-independent (see DESIGN.md).
    pub fn llama3_8b_proxy() -> Self {
        Self {
            name: "Llama3-8B-1.58bit(proxy)".into(),
            vocab_size: 270,
            d_model: 4096,
            n_layers: 4,
            n_heads: 32,
            n_kv_heads: 8,
            d_ff: 8192,
            max_seq_len: 256,
            rope_theta: 500_000.0,
        }
    }

    /// Falcon3-3B-1.58bit proxy: paper band `2^11..2^12` (d=2048...3072).
    pub fn falcon3_3b_proxy() -> Self {
        Self {
            name: "Falcon3-3B-1.58bit(proxy)".into(),
            vocab_size: 270,
            d_model: 2048,
            n_layers: 4,
            n_heads: 16,
            n_kv_heads: 4,
            d_ff: 4096,
            max_seq_len: 256,
            rope_theta: 1_000_042.0,
        }
    }

    /// Falcon3-10B-1.58bit proxy: paper band `2^11..2^12`, wider FFN.
    pub fn falcon3_10b_proxy() -> Self {
        Self {
            name: "Falcon3-10B-1.58bit(proxy)".into(),
            vocab_size: 270,
            d_model: 2048,
            n_layers: 6,
            n_heads: 16,
            n_kv_heads: 4,
            d_ff: 8192,
            max_seq_len: 256,
            rope_theta: 1_000_042.0,
        }
    }

    /// Look up a named preset.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "tiny" => Some(Self::tiny()),
            "small-125m" => Some(Self::small_125m()),
            "llama3-8b" => Some(Self::llama3_8b_proxy()),
            "falcon3-3b" => Some(Self::falcon3_3b_proxy()),
            "falcon3-10b" => Some(Self::falcon3_10b_proxy()),
            _ => None,
        }
    }

    /// All preset names.
    pub const PRESETS: [&'static str; 5] =
        ["tiny", "small-125m", "llama3-8b", "falcon3-3b", "falcon3-10b"];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in ModelConfig::PRESETS {
            let c = ModelConfig::preset(name).unwrap();
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(ModelConfig::preset("nope").is_none());
    }

    #[test]
    fn small_is_roughly_125m() {
        let c = ModelConfig::small_125m();
        let p = c.param_count();
        assert!(
            (90_000_000..200_000_000).contains(&p),
            "param count {p}"
        );
    }

    #[test]
    fn validation_catches_bad_heads() {
        let mut c = ModelConfig::tiny();
        c.n_heads = 3;
        assert!(c.validate().is_err());
        let mut c = ModelConfig::tiny();
        c.n_kv_heads = 3;
        assert!(c.validate().is_err());
    }
}
