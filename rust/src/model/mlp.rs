//! SwiGLU feed-forward block (Llama/Falcon style): three `BitLinear`
//! projections — the largest matrices in the model and where RSR's
//! speedup shows most (paper Fig 6 dims `2^11..2^13` are FFN widths).

use super::bitlinear::BitLinear;
use super::tensor::{ensure_len, silu};
use crate::error::Result;

/// `down( silu(gate(x)) ⊙ up(x) )`.
pub struct Mlp {
    gate: BitLinear,
    up: BitLinear,
    down: BitLinear,
    // Scratch.
    g: Vec<f32>,
    u: Vec<f32>,
    // Stacked batch scratch (grown on the first batched step).
    gb: Vec<f32>,
    ub: Vec<f32>,
}

impl Mlp {
    /// Assemble from the three projections.
    pub fn new(gate: BitLinear, up: BitLinear, down: BitLinear) -> Self {
        let d_ff = gate.out_dim();
        debug_assert_eq!(up.out_dim(), d_ff);
        debug_assert_eq!(down.in_dim(), d_ff);
        Self {
            gate,
            up,
            down,
            g: vec![0.0; d_ff],
            u: vec![0.0; d_ff],
            gb: Vec::new(),
            ub: Vec::new(),
        }
    }

    /// Bytes held by prepared weights.
    pub fn weight_bytes(&self) -> usize {
        self.gate.weight_bytes() + self.up.weight_bytes() + self.down.weight_bytes()
    }

    /// Attach `--profile-layers` probes to the three projections,
    /// named `layer{i}.gate` / `.up` / `.down` (the plan-store names,
    /// so the profile rows line up with `rsr tune` output).
    pub(crate) fn attach_probes(
        &mut self,
        profile: &crate::util::obs::LayerProfile,
        layer: usize,
    ) {
        self.gate.attach_probe(profile, &format!("layer{layer}.gate"));
        self.up.attach_probe(profile, &format!("layer{layer}.up"));
        self.down.attach_probe(profile, &format!("layer{layer}.down"));
    }

    /// Forward one token.
    pub fn forward(&mut self, x: &[f32], out: &mut [f32]) -> Result<()> {
        self.gate.forward(x, &mut self.g)?;
        self.up.forward(x, &mut self.u)?;
        for (g, &u) in self.g.iter_mut().zip(self.u.iter()) {
            *g = silu(*g) * u;
        }
        self.down.forward(&self.g, out)
    }

    /// Forward a stacked batch (row-major `batch × d`). The three
    /// projections — the model's largest matrices, where batching the
    /// index reads pays most — run batched; the SwiGLU gating is
    /// elementwise and identical to [`forward`](Self::forward).
    pub fn forward_batch(&mut self, xs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        let d_ff = self.gate.out_dim();
        ensure_len(&mut self.gb, batch * d_ff);
        ensure_len(&mut self.ub, batch * d_ff);
        self.gate.forward_batch(xs, batch, &mut self.gb[..batch * d_ff])?;
        self.up.forward_batch(xs, batch, &mut self.ub[..batch * d_ff])?;
        for (g, &u) in self.gb[..batch * d_ff]
            .iter_mut()
            .zip(self.ub[..batch * d_ff].iter())
        {
            *g = silu(*g) * u;
        }
        self.down.forward_batch(&self.gb[..batch * d_ff], batch, out)
    }

    /// Chunked-prefill forward. The MLP holds no per-position state, so
    /// a chunk step is exactly a batched step over the stacked rows
    /// (`rows = Σ counts` of the step): this is a documented alias of
    /// [`forward_batch`](Self::forward_batch), kept so the chunk
    /// pipeline reads uniformly across `Attention`/`Block`/`Mlp`.
    pub fn forward_chunk(&mut self, xs: &[f32], rows: usize, out: &mut [f32]) -> Result<()> {
        self.forward_batch(xs, rows, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Backend, TernaryMatrix};
    use crate::util::rng::Rng;

    fn make_mlp(d: usize, d_ff: usize, backend: Backend, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let mk = |rows: usize, cols: usize, rng: &mut Rng| {
            BitLinear::new(
                TernaryMatrix::random(rows, cols, 1.0 / 3.0, rng),
                1.0,
                backend,
                0,
            )
            .unwrap()
        };
        let gate = mk(d, d_ff, &mut rng);
        let up = mk(d, d_ff, &mut rng);
        let down = mk(d_ff, d, &mut rng);
        Mlp::new(gate, up, down)
    }

    #[test]
    fn output_is_finite_and_shaped() {
        let mut mlp = make_mlp(32, 64, Backend::RsrPlusPlus, 211);
        let mut rng = Rng::new(223);
        let x = rng.f32_vec(32, -1.0, 1.0);
        let mut out = vec![0.0; 32];
        mlp.forward(&x, &mut out).unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
        assert!(out.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn backends_agree_through_mlp() {
        let mut a = make_mlp(48, 96, Backend::Standard, 227);
        let mut b = make_mlp(48, 96, Backend::Rsr, 227);
        let mut c = make_mlp(48, 96, Backend::Tensorized, 227);
        let mut rng = Rng::new(229);
        let x = rng.f32_vec(48, -1.0, 1.0);
        let (mut oa, mut ob, mut oc) = (vec![0.0; 48], vec![0.0; 48], vec![0.0; 48]);
        a.forward(&x, &mut oa).unwrap();
        b.forward(&x, &mut ob).unwrap();
        c.forward(&x, &mut oc).unwrap();
        for i in 0..48 {
            assert!((oa[i] - ob[i]).abs() < 1e-2 * (1.0 + oa[i].abs()));
            assert!((oa[i] - oc[i]).abs() < 1e-2 * (1.0 + oa[i].abs()));
        }
    }

    #[test]
    fn zero_input_gives_zero_output() {
        let mut mlp = make_mlp(16, 32, Backend::Standard, 233);
        let mut out = vec![1.0; 16];
        mlp.forward(&[0.0; 16], &mut out).unwrap();
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
