//! Token sampling strategies for decoding.

use super::tensor::{argmax, softmax};
use crate::util::rng::Rng;

/// Decoding strategy.
#[derive(Debug, Clone, Copy)]
pub enum Sampler {
    /// Deterministic argmax — used everywhere outputs must be
    /// comparable across backends (the paper's §5.3 equality check).
    Greedy,
    /// Top-k sampling with temperature.
    TopK {
        /// Candidates kept.
        k: usize,
        /// Softmax temperature (>0).
        temperature: f32,
    },
}

impl Sampler {
    /// Pick the next token from logits.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match *self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::TopK { k, temperature } => {
                let k = k.max(1).min(logits.len());
                // Indices of the top-k logits.
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
                });
                idx.truncate(k);
                let mut probs: Vec<f32> =
                    idx.iter().map(|&i| logits[i] / temperature.max(1e-6)).collect();
                softmax(&mut probs);
                let r = rng.next_f32();
                let mut acc = 0.0;
                for (p, &i) in probs.iter().zip(idx.iter()) {
                    acc += p;
                    if r <= acc {
                        return i as u32;
                    }
                }
                *idx.last().unwrap() as u32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let s = Sampler::Greedy;
        let mut rng = Rng::new(1);
        assert_eq!(s.sample(&[0.1, 2.0, 0.5], &mut rng), 1);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let s = Sampler::TopK { k: 2, temperature: 1.0 };
        let mut rng = Rng::new(2);
        let logits = [5.0f32, 4.0, -10.0, -10.0];
        for _ in 0..100 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let s = Sampler::TopK { k: 4, temperature: 0.01 };
        let mut rng = Rng::new(3);
        let logits = [1.0f32, 3.0, 2.0, 0.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn k_larger_than_vocab_is_clamped() {
        let s = Sampler::TopK { k: 100, temperature: 1.0 };
        let mut rng = Rng::new(4);
        let t = s.sample(&[0.0, 1.0], &mut rng);
        assert!(t < 2);
    }
}
