//! A 1.58-bit (ternary-weight) decoder-only transformer — the substrate
//! the paper's §5.3/§5.4 LLM experiments run on.
//!
//! The paper evaluates on Llama3-8B / Falcon3-3B / Falcon3-10B 1.58-bit
//! checkpoints from Hugging Face; those are not available here, so we
//! build architecturally equivalent models (BitNet-style: every linear
//! layer is a [`bitlinear::BitLinear`] with ternary weights and a
//! per-tensor scale) with *matching layer dimensions* and synthetic
//! weights. Per DESIGN.md §Substitutions this preserves what Fig 6 and
//! Table 1 measure — per-layer matmul cost and Standard-vs-RSR output
//! equality — since timing depends on shapes, not trained values.
//!
//! Every `BitLinear` dispatches to a pluggable multiply backend
//! ([`crate::kernels::Backend`]), so the whole model can run on
//! Standard, RSR, RSR++, parallel-RSR or tensorized kernels and the
//! outputs can be compared token-for-token.

pub mod attention;
pub mod bitlinear;
pub mod block;
pub mod config;
pub mod kv_cache;
pub mod mlp;
pub mod quantize;
pub mod rmsnorm;
pub mod rope;
pub mod sampler;
pub mod tensor;
pub mod tokenizer;
pub mod transformer;
pub mod weights;

pub use bitlinear::BitLinear;
pub use config::ModelConfig;
pub use transformer::Transformer;
