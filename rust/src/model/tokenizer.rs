//! Byte-level tokenizer: 256 byte tokens + a handful of specials.
//! Keeps the vocabulary tiny so the LM head stays cheap while the
//! decoder blocks carry the paper-relevant matrix shapes.

/// Special token ids start after the 256 byte values.
pub const BOS: u32 = 256;
/// End-of-sequence.
pub const EOS: u32 = 257;
/// Padding.
pub const PAD: u32 = 258;
/// First id available to models (vocab must be ≥ this).
pub const VOCAB_MIN: usize = 259;

/// Byte-level tokenizer.
#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    /// New tokenizer (stateless).
    pub fn new() -> Self {
        Self
    }

    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    /// Encode with BOS prefix.
    pub fn encode_with_bos(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(self.encode(text));
        out
    }

    /// Decode token ids back to text (specials are dropped; invalid
    /// UTF-8 is replaced).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| t < 256)
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_round_trip() {
        let t = Tokenizer::new();
        let s = "What is the capital of France?";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn utf8_round_trip() {
        let t = Tokenizer::new();
        let s = "héllo — 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bos_prepended_and_dropped_on_decode() {
        let t = Tokenizer::new();
        let toks = t.encode_with_bos("ab");
        assert_eq!(toks[0], BOS);
        assert_eq!(t.decode(&toks), "ab");
    }

    #[test]
    fn specials_do_not_collide_with_bytes() {
        assert!(BOS as usize >= 256 && EOS as usize >= 256 && PAD as usize >= 256);
        assert!(VOCAB_MIN > PAD as usize);
    }
}
