//! Byte-level tokenizer: 256 byte tokens + a handful of specials.
//! Keeps the vocabulary tiny so the LM head stays cheap while the
//! decoder blocks carry the paper-relevant matrix shapes.

/// Special token ids start after the 256 byte values.
pub const BOS: u32 = 256;
/// End-of-sequence.
pub const EOS: u32 = 257;
/// Padding.
pub const PAD: u32 = 258;
/// First id available to models (vocab must be ≥ this).
pub const VOCAB_MIN: usize = 259;

/// Byte-level tokenizer.
#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    /// New tokenizer (stateless).
    pub fn new() -> Self {
        Self
    }

    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    /// Encode with BOS prefix.
    pub fn encode_with_bos(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(self.encode(text));
        out
    }

    /// Decode token ids back to text (specials are dropped; invalid
    /// UTF-8 is replaced).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| t < 256)
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Incremental decoder for token streaming.
///
/// Feeding every token through [`push`](StreamDecoder::push) and then
/// calling [`finish`](StreamDecoder::finish) yields text whose
/// concatenation is **byte-identical** to [`Tokenizer::decode`] over
/// the same token sequence — the invariant the streaming wire protocol
/// pins. It replicates `String::from_utf8_lossy`'s maximal-subpart
/// substitution incrementally: an invalid sequence becomes one U+FFFD
/// as soon as it is known invalid, while an *incomplete* multi-byte
/// suffix is held back until more bytes arrive (or `finish` flushes it
/// as the single U+FFFD the lossy decoder would emit at end of input).
#[derive(Debug, Default)]
pub struct StreamDecoder {
    pending: Vec<u8>,
}

impl StreamDecoder {
    /// Fresh decoder with no buffered bytes.
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one token; returns the text it completes (possibly empty —
    /// specials decode to nothing, and a mid-character byte stays
    /// buffered).
    pub fn push(&mut self, token: u32) -> String {
        if token >= 256 {
            return String::new();
        }
        self.pending.push(token as u8);
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.pending) {
                Ok(s) => {
                    out.push_str(s);
                    self.pending.clear();
                    break;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(std::str::from_utf8(&self.pending[..valid]).unwrap());
                    match e.error_len() {
                        // Incomplete-but-plausible suffix: wait for
                        // the rest of the character.
                        None => {
                            self.pending.drain(..valid);
                            break;
                        }
                        // Known-invalid sequence of `n` bytes: one
                        // replacement char, exactly like the lossy
                        // decoder's maximal-subpart rule.
                        Some(n) => {
                            out.push('\u{FFFD}');
                            self.pending.drain(..valid + n);
                        }
                    }
                }
            }
        }
        out
    }

    /// Flush: any incomplete suffix still buffered becomes the single
    /// U+FFFD that `from_utf8_lossy` emits for an unterminated
    /// sequence at end of input.
    pub fn finish(&mut self) -> String {
        if self.pending.is_empty() {
            String::new()
        } else {
            self.pending.clear();
            "\u{FFFD}".to_string()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_round_trip() {
        let t = Tokenizer::new();
        let s = "What is the capital of France?";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn utf8_round_trip() {
        let t = Tokenizer::new();
        let s = "héllo — 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bos_prepended_and_dropped_on_decode() {
        let t = Tokenizer::new();
        let toks = t.encode_with_bos("ab");
        assert_eq!(toks[0], BOS);
        assert_eq!(t.decode(&toks), "ab");
    }

    #[test]
    fn specials_do_not_collide_with_bytes() {
        assert!(BOS as usize >= 256 && EOS as usize >= 256 && PAD as usize >= 256);
        assert!(VOCAB_MIN > PAD as usize);
    }

    /// Concatenated incremental output must equal the batch decode,
    /// byte for byte, for every prefix boundary of every sequence.
    fn assert_stream_matches_batch(tokens: &[u32]) {
        let t = Tokenizer::new();
        let mut dec = StreamDecoder::new();
        let mut streamed = String::new();
        for &tok in tokens {
            streamed.push_str(&dec.push(tok));
        }
        streamed.push_str(&dec.finish());
        assert_eq!(streamed, t.decode(tokens), "tokens: {tokens:?}");
    }

    #[test]
    fn stream_decoder_matches_batch_decode() {
        let t = Tokenizer::new();
        assert_stream_matches_batch(&t.encode("plain ascii"));
        assert_stream_matches_batch(&t.encode("héllo — 世界"));
        // Specials interleaved: dropped by both paths.
        let mut toks = t.encode_with_bos("caf");
        toks.extend(t.encode("é"));
        toks.push(EOS);
        assert_stream_matches_batch(&toks);
    }

    #[test]
    fn stream_decoder_multibyte_chars_arrive_only_when_complete() {
        let t = Tokenizer::new();
        let mut dec = StreamDecoder::new();
        let bytes = "é".as_bytes(); // two bytes
        assert_eq!(dec.push(bytes[0] as u32), "", "first byte must buffer");
        assert_eq!(dec.push(bytes[1] as u32), "é");
        assert_eq!(dec.finish(), "");
        let _ = t;
    }

    #[test]
    fn stream_decoder_lossy_semantics_on_invalid_and_truncated_utf8() {
        // Lone continuation byte: invalid as soon as it is seen.
        assert_stream_matches_batch(&[0x80]);
        // Invalid start byte then valid ascii.
        assert_stream_matches_batch(&[0xFF, b'a' as u32]);
        // Overlong/invalid sequence mid-text.
        assert_stream_matches_batch(&[b'a' as u32, 0xE2, 0x28, 0xA1, b'b' as u32]);
        // Truncated 3-byte sequence at end of input → one U+FFFD.
        assert_stream_matches_batch(&[b'x' as u32, 0xE2, 0x82]);
        // Truncated 2-byte sequence alone.
        assert_stream_matches_batch(&[0xC3]);
        // Valid text ending exactly on a boundary.
        assert_stream_matches_batch(&[0xE2, 0x82, 0xAC]); // €
    }
}
