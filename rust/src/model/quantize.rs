//! BitNet-b1.58 absmean quantization: import an fp32 weight matrix as
//! `(TernaryMatrix, scale)` — the bridge that lets this system consume
//! *real* trained checkpoints, not only synthetic weights (Ma et al.
//! 2024, the 1.58-bit recipe the paper's models use):
//!
//! ```text
//! γ = mean(|W|)            (absmean)
//! W̃ = clip(round(W / γ), −1, 1) ∈ {−1,0,1}
//! y ≈ (x · W̃) · γ
//! ```

use crate::error::{Error, Result};
use crate::kernels::TernaryMatrix;

/// Result of quantizing one weight matrix.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    /// The ternary weights.
    pub weights: TernaryMatrix,
    /// The per-tensor absmean scale γ.
    pub scale: f32,
}

/// Absmean-quantize a dense row-major `rows × cols` f32 matrix.
pub fn absmean_quantize(w: &[f32], rows: usize, cols: usize) -> Result<QuantizedLinear> {
    if w.len() != rows * cols {
        return Err(Error::ShapeMismatch(format!(
            "buffer {} != {rows}x{cols}",
            w.len()
        )));
    }
    if w.is_empty() {
        return Err(Error::Config("empty matrix".into()));
    }
    let gamma = w.iter().map(|x| x.abs()).sum::<f32>() / w.len() as f32;
    // Degenerate all-zero matrix: keep scale 1, all zeros.
    if gamma == 0.0 {
        return Ok(QuantizedLinear {
            weights: TernaryMatrix::zeros(rows, cols),
            scale: 1.0,
        });
    }
    let data: Vec<i8> = w
        .iter()
        .map(|&x| {
            let q = (x / gamma).round();
            q.clamp(-1.0, 1.0) as i8
        })
        .collect();
    Ok(QuantizedLinear { weights: TernaryMatrix::from_dense(rows, cols, data), scale: gamma })
}

/// Mean-squared quantization error of `(W̃·γ)` vs `W` — used to sanity
/// check imports and in tests.
pub fn quantization_mse(w: &[f32], q: &QuantizedLinear) -> f32 {
    let (rows, cols) = (q.weights.rows(), q.weights.cols());
    debug_assert_eq!(w.len(), rows * cols);
    let mut acc = 0.0f64;
    for r in 0..rows {
        for c in 0..cols {
            let approx = q.weights.get(r, c) as f32 * q.scale;
            let d = (w[r * cols + c] - approx) as f64;
            acc += d * d;
        }
    }
    (acc / w.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantizes_exact_ternary_losslessly() {
        // A matrix that is already γ·{−1,0,1} must round-trip exactly.
        let gamma = 0.37f32;
        let vals = [-1.0f32, 0.0, 1.0, 1.0, 0.0, -1.0];
        let w: Vec<f32> = vals.iter().map(|v| v * gamma).collect();
        let q = absmean_quantize(&w, 2, 3).unwrap();
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(q.weights.get(i / 3, i % 3) as f32, v);
        }
        // γ is the absmean of the nonzero magnitude pattern: 4/6·gamma.
        assert!((q.scale - gamma * 4.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn gaussian_weights_quantize_with_bounded_error() {
        let mut rng = Rng::new(0x0A);
        let (rows, cols) = (64, 64);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal_f32() * 0.02).collect();
        let q = absmean_quantize(&w, rows, cols).unwrap();
        // All values in range.
        assert!(q.scale > 0.0);
        let mse = quantization_mse(&w, &q);
        let var = w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        // Ternary absmean keeps MSE well below the signal variance.
        assert!(mse < var, "mse {mse} vs var {var}");
    }

    #[test]
    fn zero_matrix_is_degenerate_but_valid() {
        let q = absmean_quantize(&[0.0; 12], 3, 4).unwrap();
        assert_eq!(q.scale, 1.0);
        assert!(q.weights.data().iter().all(|&x| x == 0));
    }

    #[test]
    fn quantized_layer_runs_through_bitlinear() {
        use crate::kernels::Backend;
        use crate::model::bitlinear::BitLinear;
        let mut rng = Rng::new(0x0B);
        let (n, m) = (48, 32);
        let w: Vec<f32> = (0..n * m).map(|_| rng.normal_f32() * 0.05).collect();
        let x = rng.f32_vec(n, -1.0, 1.0);
        let q = absmean_quantize(&w, n, m).unwrap();

        // Dense reference of the quantized layer.
        let dense: Vec<f32> = (0..m)
            .map(|c| {
                (0..n)
                    .map(|r| x[r] * q.weights.get(r, c) as f32 * q.scale)
                    .sum()
            })
            .collect();

        let mut layer =
            BitLinear::new(q.weights.clone(), q.scale, Backend::RsrFused, 0).unwrap();
        let mut out = vec![0.0; m];
        layer.forward(&x, &mut out).unwrap();
        for (g, e) in out.iter().zip(dense.iter()) {
            assert!((g - e).abs() < 1e-3 * (1.0 + e.abs()), "{g} vs {e}");
        }
    }

    #[test]
    fn rejects_shape_mismatch() {
        assert!(absmean_quantize(&[0.0; 5], 2, 3).is_err());
        assert!(absmean_quantize(&[], 0, 0).is_err());
    }
}
