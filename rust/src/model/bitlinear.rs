//! `BitLinear` — the ternary linear layer (BitNet b1.58 style), the
//! exact spot where the paper's §5.3 experiment swaps matmul
//! implementations ("for each fully connected layer
//! (`torch.nn.BitLinear`), we integrated and executed the inference
//! step of RSR").
//!
//! `y = (x · W) · β` with `W ∈ {-1,0,1}^{in×out}` and a per-tensor
//! scale `β` (the absmean scale a real BitNet checkpoint carries).
//! The multiply dispatches to a prepared backend plan; all backends are
//! bit-exact against each other up to f32 re-association.

use std::sync::Arc;
use std::time::Instant;

use crate::error::Result;
use crate::kernels::index::TernaryRsrIndex;
use crate::kernels::parallel::ParallelTernaryRsrPlan;
use crate::kernels::rsr::TernaryRsrPlan;
use crate::kernels::rsrpp::TernaryRsrPlusPlusPlan;
use crate::kernels::standard::{packed_mul_ternary, standard_mul_ternary_i8};
use crate::kernels::tensorized::TernaryTensorizedIndex;
use crate::kernels::{Backend, BinaryMatrix, TernaryMatrix};
use crate::runtime::executable::ExecutablePlan;
use crate::runtime::plan_store::{PlanEntry, PlanScratch, SharedTernaryPlan};
use crate::tune::candidates::TunedBackend;
use crate::util::obs::{LayerProbe, LayerProfile};

/// Prepared execution state for one backend.
enum Prepared {
    /// Raw ternary weights (paper's Standard baseline).
    Standard(TernaryMatrix),
    /// Bit-packed Prop 2.1 halves (stronger baseline).
    Packed(BinaryMatrix, BinaryMatrix),
    /// RSR plan (Algorithm 2).
    Rsr(TernaryRsrPlan),
    /// RSR++ plan (Algorithm 2 + 3).
    RsrPlusPlus(TernaryRsrPlusPlusPlan),
    /// Block-parallel RSR++ (Appendix C.1.I).
    Parallel(ParallelTernaryRsrPlan),
    /// One-hot tensorized form (Appendix E.2).
    Tensorized(TernaryTensorizedIndex),
    /// Fused scatter + single-fold hot path (§Perf).
    Fused(crate::kernels::fused::FusedTernaryPlan),
    /// A store-shared RSR++ plan: the index lives behind an `Arc`
    /// (built once per process by a
    /// [`PlanStore`](crate::runtime::PlanStore)), only the scratch is
    /// owned by this layer instance. `batched` is the batched-decode
    /// executor, built on the first [`BitLinear::forward_batch`] call —
    /// sequential deployments never allocate it.
    Shared {
        plan: Arc<SharedTernaryPlan>,
        scratch: PlanScratch,
        batched: Option<crate::kernels::batched::BatchedExec>,
    },
    /// A store-shared plan executing a **tuned** backend choice (an
    /// `rsr tune` profile winner) through
    /// [`ExecutablePlan`](crate::runtime::ExecutablePlan).
    Tuned(ExecutablePlan),
}

/// A ternary linear layer with a pluggable multiply backend.
pub struct BitLinear {
    in_dim: usize,
    out_dim: usize,
    scale: f32,
    backend: Backend,
    prepared: Prepared,
    /// Optional `--profile-layers` timing probe for the prepared
    /// variants that do not execute through an [`ExecutablePlan`]
    /// (tuned layers probe at that boundary instead). `None` — the
    /// default — is a single branch per forward.
    probe: Option<Arc<LayerProbe>>,
}

impl BitLinear {
    /// Prepare a layer from ternary weights.
    ///
    /// `k = 0` selects the analytic optimum
    /// [`crate::kernels::optimal_k::optimal_k_rsrpp`] for the row count.
    pub fn new(w: TernaryMatrix, scale: f32, backend: Backend, k: usize) -> Result<Self> {
        let (in_dim, out_dim) = (w.rows(), w.cols());
        let k = if k == 0 {
            crate::kernels::optimal_k::optimal_k_rsrpp(in_dim)
        } else {
            k
        };
        let prepared = match backend {
            Backend::Standard => Prepared::Standard(w),
            Backend::StandardPacked => {
                let (p, m) = w.decompose();
                Prepared::Packed(p, m)
            }
            Backend::Rsr => {
                Prepared::Rsr(TernaryRsrPlan::new(TernaryRsrIndex::preprocess(&w, k))?)
            }
            Backend::RsrPlusPlus => Prepared::RsrPlusPlus(TernaryRsrPlusPlusPlan::new(
                TernaryRsrIndex::preprocess(&w, k),
            )?),
            Backend::RsrParallel => Prepared::Parallel(ParallelTernaryRsrPlan::new(
                TernaryRsrIndex::preprocess(&w, k),
                0,
            )?),
            Backend::Tensorized => {
                Prepared::Tensorized(TernaryTensorizedIndex::preprocess(&w, k))
            }
            Backend::RsrFused => Prepared::Fused(
                crate::kernels::fused::FusedTernaryPlan::preprocess(&w, k)?,
            ),
        };
        Ok(Self { in_dim, out_dim, scale, backend, prepared, probe: None })
    }

    /// Prepare a layer around a plan compiled elsewhere (a
    /// [`PlanStore`](crate::runtime::PlanStore) entry). The expensive
    /// index is shared; this instance owns only its per-thread scratch.
    /// Executes via RSR++ — bit-identical to `Backend::RsrPlusPlus`.
    pub fn from_shared(plan: Arc<SharedTernaryPlan>, scale: f32) -> Self {
        let (in_dim, out_dim) = (plan.rows(), plan.cols());
        let scratch = plan.scratch();
        Self {
            in_dim,
            out_dim,
            scale,
            backend: Backend::RsrPlusPlus,
            prepared: Prepared::Shared { plan, scratch, batched: None },
            probe: None,
        }
    }

    /// Prepare a layer from a [`PlanStore`](crate::runtime::PlanStore)
    /// entry, honoring the entry's tuned `(k, backend)` choice when the
    /// store was built with an `rsr tune` profile. Untuned entries take
    /// the exact [`from_shared`](Self::from_shared) path — a store
    /// without a profile behaves identically to before tuning existed.
    pub fn from_plan_entry(entry: &PlanEntry, scale: f32) -> Result<Self> {
        let plan = entry.ternary()?;
        match &entry.tuned {
            None => Ok(Self::from_shared(plan, scale)),
            Some(choice) => {
                let exec = ExecutablePlan::new(plan, choice.backend)?;
                Ok(Self {
                    in_dim: exec.rows(),
                    out_dim: exec.cols(),
                    scale,
                    backend: coarse_backend(choice.backend),
                    prepared: Prepared::Tuned(exec),
                    probe: None,
                })
            }
        }
    }

    /// Input width.
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Output width.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// The backend this layer dispatches to. For tuned layers this is
    /// the coarse algorithm *family* (see
    /// [`tuned_backend`](Self::tuned_backend) for the exact choice).
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The exact tuned backend, when this layer executes a profile
    /// choice.
    pub fn tuned_backend(&self) -> Option<TunedBackend> {
        match &self.prepared {
            Prepared::Tuned(exec) => Some(exec.backend()),
            _ => None,
        }
    }

    /// Attach a `--profile-layers` timing probe keyed by `(layer,
    /// backend)`. Tuned layers probe at the
    /// [`ExecutablePlan::execute`] boundary — timing exactly what the
    /// tuner measured, so its decisions can be audited against live
    /// traffic — while the shared/owned paths time the whole forward
    /// dispatch. The profile dedupes, so a worker re-attaching after a
    /// panic rebuild keeps accumulating into the same aggregates.
    pub fn attach_probe(&mut self, profile: &LayerProfile, layer: &str) {
        match &mut self.prepared {
            Prepared::Tuned(exec) => {
                let backend = exec.backend().name();
                exec.set_probe(profile.probe(layer, backend));
            }
            Prepared::Shared { .. } => {
                self.probe = Some(profile.probe(layer, "rsr++-shared"));
            }
            _ => {
                self.probe = Some(profile.probe(layer, self.backend.name()));
            }
        }
    }

    /// Bytes held by the prepared weight representation — what Fig 5's
    /// memory comparison measures at the model level.
    pub fn weight_bytes(&self) -> usize {
        match &self.prepared {
            Prepared::Standard(w) => w.dense_bytes(),
            Prepared::Packed(p, m) => p.packed_bytes() + m.packed_bytes(),
            Prepared::Rsr(plan) => plan.bytes(),
            Prepared::RsrPlusPlus(plan) => {
                plan.index_bytes()
            }
            Prepared::Parallel(plan) => plan.index_bytes(),
            Prepared::Tensorized(t) => t.plus.bytes() + t.minus.bytes(),
            Prepared::Fused(plan) => plan.bytes(),
            // The index is shared process-wide; report it in full here
            // (Fig 5 semantics) — per-instance cost is just the scratch.
            Prepared::Shared { plan, .. } => plan.index_bytes(),
            Prepared::Tuned(exec) => exec.index_bytes(),
        }
    }

    /// `out = (x · W) · β`. `x.len() == in_dim`, `out.len() == out_dim`.
    pub fn forward(&mut self, x: &[f32], out: &mut [f32]) -> Result<()> {
        if let Some(probe) = self.probe.clone() {
            let t0 = Instant::now();
            let res = self.forward_inner(x, out);
            probe.record(t0.elapsed().as_nanos() as u64);
            return res;
        }
        self.forward_inner(x, out)
    }

    fn forward_inner(&mut self, x: &[f32], out: &mut [f32]) -> Result<()> {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(out.len(), self.out_dim);
        match &mut self.prepared {
            Prepared::Standard(w) => {
                let y = standard_mul_ternary_i8(x, w);
                out.copy_from_slice(&y);
            }
            Prepared::Packed(p, m) => {
                let y = packed_mul_ternary(x, p, m);
                out.copy_from_slice(&y);
            }
            Prepared::Rsr(plan) => plan.execute(x, out)?,
            Prepared::RsrPlusPlus(plan) => plan.execute(x, out)?,
            Prepared::Parallel(plan) => plan.execute(x, out)?,
            Prepared::Tensorized(t) => t.execute(x, out)?,
            Prepared::Fused(plan) => plan.execute(x, out)?,
            Prepared::Shared { plan, scratch, .. } => plan.execute(scratch, x, out)?,
            Prepared::Tuned(exec) => exec.execute(x, out)?,
        }
        if self.scale != 1.0 {
            for o in out.iter_mut() {
                *o *= self.scale;
            }
        }
        Ok(())
    }

    /// Batched forward: `out[b] = (vs[b] · W) · β` for a row-major
    /// `batch × in_dim` activation block (`out` is `batch × out_dim`) —
    /// the continuous-batching hot path.
    ///
    /// Store-shared and tuned layers dispatch to the batched flat-plan
    /// kernel, which reads the shared index once per **batch** instead
    /// of once per row; per row the kernel performs the identical f32
    /// addition sequence at every batch size, so a sequence's output
    /// never depends on its batchmates (ragged batches and mid-flight
    /// joins are exact). Owned backends, which have no batched kernel,
    /// execute row by row through [`forward`](Self::forward) —
    /// bit-identical to the sequential path, just without the index
    /// amortization.
    pub fn forward_batch(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        if let Some(probe) = self.probe.clone() {
            let t0 = Instant::now();
            let res = self.forward_batch_inner(vs, batch, out);
            probe.record(t0.elapsed().as_nanos() as u64);
            return res;
        }
        self.forward_batch_inner(vs, batch, out)
    }

    fn forward_batch_inner(&mut self, vs: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        if batch == 0
            || vs.len() != batch * self.in_dim
            || out.len() != batch * self.out_dim
        {
            return Err(crate::error::Error::ShapeMismatch(format!(
                "forward_batch: batch {batch}, vs len {}, out len {} for a {}x{} layer",
                vs.len(),
                out.len(),
                self.in_dim,
                self.out_dim
            )));
        }
        if !matches!(self.prepared, Prepared::Shared { .. } | Prepared::Tuned(_)) {
            for b in 0..batch {
                // `forward_inner` applies β per row (the un-probed
                // body: the batch call was already timed as a whole).
                self.forward_inner(
                    &vs[b * self.in_dim..(b + 1) * self.in_dim],
                    &mut out[b * self.out_dim..(b + 1) * self.out_dim],
                )?;
            }
            return Ok(());
        }
        match &mut self.prepared {
            Prepared::Shared { plan, batched, .. } => {
                if batched.is_none() {
                    *batched = Some(plan.batch_exec(batch)?);
                }
                let exec = batched.as_mut().expect("created above");
                plan.execute_batch(exec, vs, batch, out)?;
            }
            Prepared::Tuned(exec) => exec.execute_batch(vs, batch, out)?,
            _ => unreachable!("owned backends took the per-row path above"),
        }
        if self.scale != 1.0 {
            for o in out.iter_mut() {
                *o *= self.scale;
            }
        }
        Ok(())
    }
}

/// Map a tuned backend to the coarse [`Backend`] family it belongs to
/// (the scalar-gather, batched and table-lookup variants are serve-time
/// refinements the `Backend` enum cannot distinguish — they all replace
/// the same RSR++ slot in the coarse taxonomy).
fn coarse_backend(tuned: TunedBackend) -> Backend {
    match tuned {
        TunedBackend::Rsr => Backend::Rsr,
        TunedBackend::RsrPlusPlus
        | TunedBackend::RsrPlusPlusScalar
        | TunedBackend::Batched
        | TunedBackend::Tl
        | TunedBackend::TlNeon => Backend::RsrPlusPlus,
        TunedBackend::Parallel => Backend::RsrParallel,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn all_backends_agree() {
        let mut rng = Rng::new(163);
        let w = TernaryMatrix::random(96, 64, 1.0 / 3.0, &mut rng);
        let x = rng.f32_vec(96, -1.0, 1.0);
        let mut reference = vec![0.0; 64];
        BitLinear::new(w.clone(), 0.5, Backend::Standard, 0)
            .unwrap()
            .forward(&x, &mut reference)
            .unwrap();
        for backend in Backend::ALL {
            let mut layer = BitLinear::new(w.clone(), 0.5, backend, 4).unwrap();
            let mut out = vec![0.0; 64];
            layer.forward(&x, &mut out).unwrap();
            for (g, e) in out.iter().zip(reference.iter()) {
                assert!(
                    (g - e).abs() < 1e-3 * (1.0 + e.abs()),
                    "{}: {g} vs {e}",
                    backend.name()
                );
            }
        }
    }

    #[test]
    fn shared_plan_layer_matches_owned_rsrpp_layer() {
        let mut rng = Rng::new(179);
        let w = TernaryMatrix::random(96, 64, 1.0 / 3.0, &mut rng);
        let x = rng.f32_vec(96, -1.0, 1.0);
        let mut owned = BitLinear::new(w.clone(), 0.5, Backend::RsrPlusPlus, 4).unwrap();
        let mut expect = vec![0.0; 64];
        owned.forward(&x, &mut expect).unwrap();

        let plan =
            Arc::new(SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&w, 4)).unwrap());
        let mut shared = BitLinear::from_shared(Arc::clone(&plan), 0.5);
        assert_eq!(shared.in_dim(), 96);
        assert_eq!(shared.out_dim(), 64);
        assert_eq!(shared.backend(), Backend::RsrPlusPlus);
        let mut got = vec![0.0; 64];
        shared.forward(&x, &mut got).unwrap();
        assert_eq!(got, expect, "shared layer must be bit-identical to owned layer");

        // A second instance over the SAME Arc'd plan works independently.
        let mut shared2 = BitLinear::from_shared(plan, 0.5);
        let mut got2 = vec![0.0; 64];
        shared2.forward(&x, &mut got2).unwrap();
        assert_eq!(got2, expect);
    }

    #[test]
    fn plan_entry_layers_execute_tuned_and_untuned() {
        use crate::runtime::PlanStore;
        use crate::tune::profile::LayerChoice;

        let mut rng = Rng::new(191);
        let w = TernaryMatrix::random(64, 48, 1.0 / 3.0, &mut rng);
        let x = rng.int_f32_vec(64, 2);
        let store = PlanStore::new();
        let entry = store
            .insert_ternary("l", crate::kernels::TernaryRsrIndex::preprocess(&w, 4), 4, 1.0)
            .unwrap();

        // Untuned entry → the from_shared path, bit-identical to it.
        let mut untuned = BitLinear::from_plan_entry(&entry, 1.0).unwrap();
        assert_eq!(untuned.tuned_backend(), None);
        let mut expect = vec![0.0; 48];
        untuned.forward(&x, &mut expect).unwrap();

        // A tuned entry dispatches its choice; on integer inputs every
        // backend is exactly equal.
        for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
            let tuned_entry = PlanEntry {
                tuned: Some(LayerChoice { backend, k: 4, ns: 1.0 }),
                ..(*entry).clone()
            };
            let mut layer = BitLinear::from_plan_entry(&tuned_entry, 1.0).unwrap();
            assert_eq!(layer.tuned_backend(), Some(backend));
            assert_eq!(layer.in_dim(), 64);
            let mut got = vec![0.0; 48];
            layer.forward(&x, &mut got).unwrap();
            assert_eq!(got, expect, "{}", backend.name());
        }
    }

    #[test]
    fn forward_batch_agrees_with_forward_on_every_path() {
        let mut rng = Rng::new(193);
        let w = TernaryMatrix::random(80, 56, 1.0 / 3.0, &mut rng);
        let batch = 3;
        // Integer activations: every backend must agree exactly.
        let vs = rng.int_f32_vec(batch * 80, 2);
        let plan =
            Arc::new(SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&w, 4)).unwrap());

        let mut layers: Vec<(&str, BitLinear)> = vec![
            ("owned-std", BitLinear::new(w.clone(), 0.5, Backend::Standard, 4).unwrap()),
            ("owned-rsr++", BitLinear::new(w.clone(), 0.5, Backend::RsrPlusPlus, 4).unwrap()),
            ("shared", BitLinear::from_shared(Arc::clone(&plan), 0.5)),
        ];
        for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
            let entry = PlanEntry {
                name: "l".into(),
                k: 4,
                scale: 0.5,
                weights_fp: 0,
                tuned: Some(crate::tune::profile::LayerChoice { backend, k: 4, ns: 1.0 }),
                plan: crate::runtime::plan_store::PlanKind::Ternary(Arc::clone(&plan)),
            };
            layers.push(("tuned", BitLinear::from_plan_entry(&entry, 0.5).unwrap()));
        }
        for (name, layer) in &mut layers {
            let mut batched = vec![0.0; batch * 56];
            layer.forward_batch(&vs, batch, &mut batched).unwrap();
            for b in 0..batch {
                let mut row = vec![0.0; 56];
                layer.forward(&vs[b * 80..(b + 1) * 80], &mut row).unwrap();
                assert_eq!(&batched[b * 56..(b + 1) * 56], &row[..], "{name} row {b}");
            }
        }
    }

    #[test]
    fn forward_batch_rejects_bad_shapes() {
        let mut rng = Rng::new(197);
        let w = TernaryMatrix::random(32, 16, 1.0 / 3.0, &mut rng);
        let mut layer = BitLinear::new(w, 1.0, Backend::RsrPlusPlus, 3).unwrap();
        let mut out = vec![0.0; 2 * 16];
        assert!(layer.forward_batch(&[0.0; 64], 0, &mut out).is_err());
        assert!(layer.forward_batch(&[0.0; 63], 2, &mut out).is_err());
        assert!(layer.forward_batch(&[0.0; 64], 2, &mut [0.0; 31]).is_err());
    }

    #[test]
    fn k_zero_picks_optimal() {
        let mut rng = Rng::new(167);
        let w = TernaryMatrix::random(128, 32, 1.0 / 3.0, &mut rng);
        let layer = BitLinear::new(w, 1.0, Backend::RsrPlusPlus, 0).unwrap();
        assert_eq!(layer.in_dim(), 128);
        assert_eq!(layer.out_dim(), 32);
    }

    #[test]
    fn index_backends_use_less_memory_than_f32_dense_at_scale() {
        // Fig 5 compares the index against the float storage NumPy
        // keeps (4 bytes/weight): index ≈ 8n²/k bytes vs 4n² bytes,
        // i.e. a 2/k ratio — clearly smaller for k ≥ 3.
        let mut rng = Rng::new(173);
        let n = 1024;
        let w = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
        let dense_f32 = n * n * 4;
        let rsr =
            BitLinear::new(w, 1.0, Backend::RsrPlusPlus, 0).unwrap().weight_bytes();
        assert!(rsr < dense_f32, "rsr {rsr} vs dense f32 {dense_f32}");
    }
}
