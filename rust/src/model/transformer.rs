//! The assembled decoder-only transformer: embedding → blocks → final
//! norm → LM head, decoding one token per forward pass (the paper's
//! §5.3 setting), with a lockstep batched step for continuous decoding
//! and a chunked step ([`Transformer::forward_chunk`]) that prefills
//! several prompt tokens per pass by stacking them along the batch
//! dimension of the same kernels.

use super::attention::Attention;
use super::bitlinear::BitLinear;
use super::block::Block;
use super::config::ModelConfig;
use super::mlp::Mlp;
use super::rmsnorm::RmsNorm;
use super::rope::Rope;
use super::sampler::Sampler;
use super::weights::ModelWeights;
use crate::error::{Error, Result};
use crate::kernels::Backend;
use crate::runtime::kv_pool::KvPool;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Cap on decode-slot indices: each slot owns a full per-layer KV
/// cache, so an arbitrary index must fail cleanly instead of
/// allocating without bound (or wrapping `max + 1` in release builds).
pub const MAX_SLOTS: usize = 1 << 16;

/// A ready-to-run model instance: prepared weights on one backend.
///
/// Decoding has three entry points: [`forward_token`](Self::forward_token)
/// (one sequence, slot 0 — the paper's §5.3 single-vector setting),
/// [`forward_batch`](Self::forward_batch) (continuous batched decode:
/// `B` sequences stepped in lockstep against per-slot KV caches, every
/// `BitLinear` reading its shared plan index once per step instead of
/// once per sequence), and [`forward_chunk`](Self::forward_chunk)
/// (chunked prefill: a slot may feed several consecutive prompt tokens
/// in one pass, stacked along the same batch dimension — one shared
/// index read covers the whole chunk). `forward_batch` **is** the
/// chunk path with every count equal to one, so there is a single
/// lockstep implementation and the two can never diverge.
pub struct Transformer {
    config: ModelConfig,
    backend: Backend,
    embedding: Vec<f32>,
    blocks: Vec<Block>,
    final_norm: RmsNorm,
    lm_head: BitLinear,
    rope: Rope,
    // Scratch.
    hidden: Vec<f32>,
    logits: Vec<f32>,
    // Stacked batch scratch (grown on the first batched step).
    hidden_b: Vec<f32>,
    normed_b: Vec<f32>,
    batch_logits: Vec<f32>,
    /// All-ones chunk lengths, reused so `forward_batch` delegates to
    /// the chunk path without a per-step allocation.
    ones: Vec<usize>,
}

impl Transformer {
    /// Prepare a model from raw weights on the given backend.
    /// `k = 0` selects the analytic optimal blocking parameter.
    pub fn from_weights(weights: &ModelWeights, backend: Backend, k: usize) -> Result<Self> {
        let pool = Arc::new(KvPool::unbounded(KvPool::DEFAULT_PAGE_TOKENS));
        Self::from_weights_pooled(weights, backend, k, pool)
    }

    /// [`from_weights`](Self::from_weights) drawing every layer's KV
    /// pages from a shared [`KvPool`] — the serving engine passes one
    /// pool to all workers so `--kv-budget` caps the whole process.
    pub fn from_weights_pooled(
        weights: &ModelWeights,
        backend: Backend,
        k: usize,
        kv_pool: Arc<KvPool>,
    ) -> Result<Self> {
        let cfg = weights.config.clone();
        cfg.validate()?;
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for lw in &weights.layers {
            let attn = Attention::with_pool(
                &cfg,
                BitLinear::new(lw.wq.clone(), lw.scales[0], backend, k)?,
                BitLinear::new(lw.wk.clone(), lw.scales[1], backend, k)?,
                BitLinear::new(lw.wv.clone(), lw.scales[2], backend, k)?,
                BitLinear::new(lw.wo.clone(), lw.scales[3], backend, k)?,
                Arc::clone(&kv_pool),
            );
            let mlp = Mlp::new(
                BitLinear::new(lw.gate.clone(), lw.scales[4], backend, k)?,
                BitLinear::new(lw.up.clone(), lw.scales[5], backend, k)?,
                BitLinear::new(lw.down.clone(), lw.scales[6], backend, k)?,
            );
            blocks.push(Block::new(
                RmsNorm::new(lw.attn_norm.clone(), 1e-6),
                attn,
                RmsNorm::new(lw.mlp_norm.clone(), 1e-6),
                mlp,
            ));
        }
        let lm_head =
            BitLinear::new(weights.lm_head.clone(), weights.lm_head_scale, backend, k)?;
        Ok(Self {
            embedding: weights.embedding.clone(),
            final_norm: RmsNorm::new(weights.final_norm.clone(), 1e-6),
            lm_head,
            rope,
            hidden: vec![0.0; cfg.d_model],
            logits: vec![0.0; cfg.vocab_size],
            hidden_b: Vec::new(),
            normed_b: Vec::new(),
            batch_logits: Vec::new(),
            ones: Vec::new(),
            blocks,
            backend,
            config: cfg,
        })
    }

    /// Prepare a model whose `BitLinear` layers execute **shared**
    /// plans from a [`PlanStore`](crate::runtime::PlanStore) instead of
    /// preprocessing their own. The store resolves each layer name
    /// (`layer{i}.wq` … `lm_head`, see
    /// [`ModelWeights::named_matrices`]) once per process; this
    /// instance holds only per-thread scratch, so N workers cost one
    /// index, not N. Executes via RSR++ — outputs are bit-identical to
    /// [`from_weights`](Self::from_weights) with
    /// `Backend::RsrPlusPlus` — unless the store carries an `rsr tune`
    /// profile ([`PlanStore::with_profile`]), in which case each layer
    /// runs its measured `(k, backend)` winner.
    ///
    /// [`PlanStore::with_profile`]: crate::runtime::PlanStore::with_profile
    ///
    /// `weights` still provides everything that is not a ternary
    /// matmul: config, embeddings, norms. Each plan is validated
    /// against the weights — shape *and* weights fingerprint (when the
    /// artifact carries one) — so a mismatched or stale artifact
    /// directory fails here, not at request time.
    pub fn from_plan_store(
        weights: &ModelWeights,
        store: &crate::runtime::PlanStore,
    ) -> Result<Self> {
        let pool = Arc::new(KvPool::unbounded(KvPool::DEFAULT_PAGE_TOKENS));
        Self::from_plan_store_pooled(weights, store, pool)
    }

    /// [`from_plan_store`](Self::from_plan_store) drawing every
    /// layer's KV pages from a shared [`KvPool`] (see
    /// [`from_weights_pooled`](Self::from_weights_pooled)).
    pub fn from_plan_store_pooled(
        weights: &ModelWeights,
        store: &crate::runtime::PlanStore,
        kv_pool: Arc<KvPool>,
    ) -> Result<Self> {
        let cfg = weights.config.clone();
        cfg.validate()?;
        // Fingerprints only carry information for disk-backed stores
        // (a Model-backed store hashed these same matrices itself), and
        // a store the engine already verified as a whole
        // (`PlanStore::verify_fingerprints`) needn't be re-hashed per
        // worker.
        let verify_fp = store.is_artifact_backed() && !store.fingerprints_verified();
        let get = |name: &str,
                   m: &crate::kernels::TernaryMatrix,
                   scale: f32|
         -> Result<BitLinear> {
            let entry = store.get(name)?;
            if entry.shape() != (m.rows(), m.cols()) {
                return Err(Error::InvalidModel(format!(
                    "plan {name} has shape {:?}, model expects ({}, {})",
                    entry.shape(),
                    m.rows(),
                    m.cols()
                )));
            }
            // The fingerprint binds the ternary entries; the scale is
            // checked separately — a recalibrated checkpoint can change
            // β while the {−1,0,1} pattern stays identical.
            if entry.scale != scale {
                return Err(Error::InvalidModel(format!(
                    "plan {name} was packed with scale {} but the model carries {scale} \
                     (stale artifacts — re-run `rsr pack`)",
                    entry.scale
                )));
            }
            // Same shapes do not imply same weights: a plans directory
            // packed from another checkpoint of this architecture must
            // not silently serve its logits.
            if verify_fp
                && entry.weights_fp != 0
                && entry.weights_fp != crate::kernels::artifact::ternary_fingerprint(m)
            {
                return Err(Error::InvalidModel(format!(
                    "plan {name} was packed from different weights \
                     (fingerprint mismatch — re-run `rsr pack`)"
                )));
            }
            // The model's own scale is authoritative at execution time.
            // A store with a tuning profile hands back entries carrying
            // their measured (k, backend) winner; from_plan_entry
            // dispatches it (untuned entries keep the shared-RSR++
            // path bit-for-bit).
            BitLinear::from_plan_entry(&entry, scale)
        };
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for (i, lw) in weights.layers.iter().enumerate() {
            let attn = Attention::with_pool(
                &cfg,
                get(&format!("layer{i}.wq"), &lw.wq, lw.scales[0])?,
                get(&format!("layer{i}.wk"), &lw.wk, lw.scales[1])?,
                get(&format!("layer{i}.wv"), &lw.wv, lw.scales[2])?,
                get(&format!("layer{i}.wo"), &lw.wo, lw.scales[3])?,
                Arc::clone(&kv_pool),
            );
            let mlp = Mlp::new(
                get(&format!("layer{i}.gate"), &lw.gate, lw.scales[4])?,
                get(&format!("layer{i}.up"), &lw.up, lw.scales[5])?,
                get(&format!("layer{i}.down"), &lw.down, lw.scales[6])?,
            );
            blocks.push(Block::new(
                RmsNorm::new(lw.attn_norm.clone(), 1e-6),
                attn,
                RmsNorm::new(lw.mlp_norm.clone(), 1e-6),
                mlp,
            ));
        }
        let lm_head = get("lm_head", &weights.lm_head, weights.lm_head_scale)?;
        Ok(Self {
            embedding: weights.embedding.clone(),
            final_norm: RmsNorm::new(weights.final_norm.clone(), 1e-6),
            lm_head,
            rope,
            hidden: vec![0.0; cfg.d_model],
            logits: vec![0.0; cfg.vocab_size],
            hidden_b: Vec::new(),
            normed_b: Vec::new(),
            batch_logits: Vec::new(),
            ones: Vec::new(),
            blocks,
            backend: Backend::RsrPlusPlus,
            config: cfg,
        })
    }

    /// Attach `--profile-layers` timing probes to every `BitLinear` in
    /// the model — all block projections plus the LM head — keyed by
    /// the plan-store layer names (`layer{i}.wq` … `lm_head`), so the
    /// profile rows can be audited against `rsr tune` output. The
    /// registry dedupes per (layer, backend): a worker rebuilding its
    /// model after a supervised panic re-attaches to the same
    /// aggregates.
    pub fn attach_layer_probes(&mut self, profile: &crate::util::obs::LayerProfile) {
        for (i, block) in self.blocks.iter_mut().enumerate() {
            block.attach_probes(profile, i);
        }
        self.lm_head.attach_probe(profile, "lm_head");
    }

    /// Architecture.
    pub fn config(&self) -> &ModelConfig {
        &self.config
    }

    /// The multiply backend every `BitLinear` dispatches to.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Current decoded length (KV cache fill, slot 0).
    pub fn seq_len(&self) -> usize {
        self.blocks.first().map_or(0, |b| b.seq_len())
    }

    /// KV slots currently allocated (≥ 1; slot 0 is the
    /// single-sequence path every existing API uses).
    pub fn slots(&self) -> usize {
        self.blocks.first().map_or(1, |b| b.slots())
    }

    /// Grow every layer to at least `n` KV slots. Existing slots keep
    /// their cached state; new slots start empty. Cost is KV-cache
    /// memory only — weights and plan indices stay shared.
    pub fn ensure_slots(&mut self, n: usize) {
        for b in &mut self.blocks {
            b.ensure_slots(n);
        }
    }

    /// Decoded length of one slot.
    pub fn seq_len_slot(&self, slot: usize) -> usize {
        self.blocks.first().map_or(0, |b| b.seq_len_slot(slot))
    }

    /// Clear one slot's KV caches for a new sequence (slot reuse in the
    /// continuous-batching engine; other slots are untouched).
    pub fn reset_slot(&mut self, slot: usize) {
        for b in &mut self.blocks {
            b.reset_slot(slot);
        }
    }

    /// Total prepared-weight bytes (Fig 5 at the model level).
    pub fn weight_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.weight_bytes()).sum::<usize>()
            + self.lm_head.weight_bytes()
            + self.embedding.len() * 4
    }

    /// Logits produced by the most recent [`forward_token`]
    /// (zeros before the first call). Lets callers sample without
    /// re-borrowing the model mutably.
    ///
    /// [`forward_token`]: Self::forward_token
    pub fn last_logits(&self) -> &[f32] {
        &self.logits
    }

    /// Reset all KV caches (every slot) for a new sequence.
    pub fn reset(&mut self) {
        for b in &mut self.blocks {
            b.reset();
        }
    }

    /// One decode step: feed `token` at position `seq_len()`, return
    /// logits over the vocabulary.
    pub fn forward_token(&mut self, token: u32) -> Result<&[f32]> {
        let pos = self.seq_len();
        if pos >= self.config.max_seq_len {
            return Err(Error::Serving("sequence exceeds max_seq_len".into()));
        }
        let d = self.config.d_model;
        let t = token as usize;
        if t >= self.config.vocab_size {
            return Err(Error::Config(format!("token {token} out of vocab")));
        }
        self.hidden.copy_from_slice(&self.embedding[t * d..(t + 1) * d]);
        for block in &mut self.blocks {
            block.forward(&mut self.hidden, pos, &self.rope)?;
        }
        let mut normed = vec![0.0; d];
        self.final_norm.forward(&self.hidden, &mut normed);
        self.lm_head.forward(&normed, &mut self.logits)?;
        Ok(&self.logits)
    }

    /// One **lockstep decode step** over a ragged batch of live slots:
    /// feed `tokens[i]` to slot `slots[i]` at that slot's own position,
    /// and return the stacked logits (row-major `tokens.len() ×
    /// vocab_size`, row `i` belonging to slot `slots[i]`).
    ///
    /// This is the continuous-batching hot path: every `BitLinear`
    /// executes the batched flat-plan kernel, reading its shared index
    /// once per step instead of once per sequence. Per row that kernel
    /// performs the identical f32 addition sequence at every batch
    /// size, so a slot's logits are **independent of its batchmates** —
    /// sequences joining or retiring mid-flight never perturb the
    /// others, which is what makes ragged batches and mid-flight joins
    /// safe to serve.
    ///
    /// Slots must be distinct within one step (each appends one KV
    /// position). Everything is validated before any cache is touched,
    /// so a failed call leaves no partial state behind. Slots beyond
    /// the allocated count are grown on demand
    /// ([`ensure_slots`](Self::ensure_slots)).
    pub fn forward_batch(&mut self, tokens: &[u32], slots: &[usize]) -> Result<&[f32]> {
        if tokens.len() != slots.len() {
            return Err(Error::Config(format!(
                "forward_batch: {} tokens for {} slots",
                tokens.len(),
                slots.len()
            )));
        }
        let mut ones = std::mem::take(&mut self.ones);
        ones.clear();
        ones.resize(slots.len(), 1);
        let rows = self.forward_chunk_impl(tokens, slots, &ones);
        self.ones = ones;
        let rows = rows?;
        Ok(&self.batch_logits[..rows * self.config.vocab_size])
    }

    /// One **chunked lockstep step**: slot `slots[i]` feeds `counts[i]`
    /// consecutive tokens this pass — its next `counts[i]` prompt
    /// tokens while prefilling, exactly one token while decoding. The
    /// concatenated `tokens` (length `Σ counts`, slot-major, in
    /// sequence order) are stacked along the **batch dimension** of the
    /// batched flat kernels, so one shared-index read per layer covers
    /// the whole chunk — the paper's reuse argument applied to the
    /// sequence axis, which is what makes prefill a matrix–matrix
    /// workload instead of `prompt_len` decode-rate steps.
    ///
    /// Returns the stacked logits (row-major `Σ counts × vocab_size`;
    /// the rows of slot `i` start at `counts[..i]` summed). Per row the
    /// kernels perform the identical f32 addition sequence at every
    /// batch size and the attention window of the row at chunk offset
    /// `j` is truncated to its own position, so chunked prefill is
    /// **bit-identical** to feeding the same tokens one step at a time
    /// — the correctness spine `rust/tests/prefill.rs` pins.
    ///
    /// Slots must be distinct within one step; every count must be at
    /// least 1 and fit the slot's remaining context. Everything is
    /// validated before any cache is touched, so a failed call leaves
    /// no partial state behind.
    pub fn forward_chunk(
        &mut self,
        tokens: &[u32],
        slots: &[usize],
        counts: &[usize],
    ) -> Result<&[f32]> {
        let rows = self.forward_chunk_impl(tokens, slots, counts)?;
        Ok(&self.batch_logits[..rows * self.config.vocab_size])
    }

    /// The single lockstep implementation behind
    /// [`forward_batch`](Self::forward_batch) and
    /// [`forward_chunk`](Self::forward_chunk); returns the stacked row
    /// count (logits live in `self.batch_logits`).
    fn forward_chunk_impl(
        &mut self,
        tokens: &[u32],
        slots: &[usize],
        counts: &[usize],
    ) -> Result<usize> {
        let b = slots.len();
        if b == 0 || counts.len() != b {
            return Err(Error::Config(format!(
                "forward_chunk: {b} slots with {} chunk lengths",
                counts.len()
            )));
        }
        if counts.iter().any(|&c| c == 0) {
            return Err(Error::Config(
                "forward_chunk: every slot in a step must feed at least one token".into(),
            ));
        }
        let rows: usize = counts.iter().sum();
        if tokens.len() != rows {
            return Err(Error::Config(format!(
                "forward_chunk: {} tokens for {rows} stacked rows",
                tokens.len()
            )));
        }
        for (i, &s) in slots.iter().enumerate() {
            // Bound before growing: slots allocate a full KV cache
            // each, so a wild index must be a clean error, not an
            // overflow panic or an OOM abort.
            if s >= MAX_SLOTS {
                return Err(Error::Config(format!(
                    "forward_chunk: slot {s} exceeds the slot cap {MAX_SLOTS}"
                )));
            }
            if slots[..i].contains(&s) {
                return Err(Error::Config(format!(
                    "forward_chunk: slot {s} appears twice in one step"
                )));
            }
        }
        if let Some(&max) = slots.iter().max() {
            self.ensure_slots(max + 1);
        }
        // Validate every row up front: a failure here must leave no
        // partial KV appends behind.
        for &t in tokens {
            if t as usize >= self.config.vocab_size {
                return Err(Error::Config(format!("token {t} out of vocab")));
            }
        }
        for (&s, &c) in slots.iter().zip(counts.iter()) {
            if self.seq_len_slot(s) + c > self.config.max_seq_len {
                return Err(Error::Serving(format!(
                    "slot {s}: sequence exceeds max_seq_len"
                )));
            }
        }
        let d = self.config.d_model;
        super::tensor::ensure_len(&mut self.hidden_b, rows * d);
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            self.hidden_b[i * d..(i + 1) * d]
                .copy_from_slice(&self.embedding[t * d..(t + 1) * d]);
        }
        for block in &mut self.blocks {
            block.forward_chunk(&mut self.hidden_b[..rows * d], slots, counts, &self.rope)?;
        }
        super::tensor::ensure_len(&mut self.normed_b, rows * d);
        for i in 0..rows {
            self.final_norm.forward(
                &self.hidden_b[i * d..(i + 1) * d],
                &mut self.normed_b[i * d..(i + 1) * d],
            );
        }
        let v = self.config.vocab_size;
        super::tensor::ensure_len(&mut self.batch_logits, rows * v);
        self.lm_head.forward_batch(
            &self.normed_b[..rows * d],
            rows,
            &mut self.batch_logits[..rows * v],
        )?;
        Ok(rows)
    }

    /// Feed a prompt (prefill) and greedily decode `max_new` tokens.
    /// Returns the generated token ids.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sampler: Sampler,
        rng: &mut Rng,
    ) -> Result<Vec<u32>> {
        self.reset();
        if prompt.is_empty() {
            return Err(Error::Config("empty prompt".into()));
        }
        let mut last_logits_token = None;
        for &t in prompt {
            self.forward_token(t)?;
            last_logits_token = Some(t);
        }
        let _ = last_logits_token;
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let logits = self.logits.clone();
            let next = sampler.sample(&logits, rng);
            out.push(next);
            if next == super::tokenizer::EOS {
                break;
            }
            if self.seq_len() >= self.config.max_seq_len {
                break;
            }
            self.forward_token(next)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::weights::ModelWeights;

    fn tiny_weights() -> ModelWeights {
        ModelWeights::generate(ModelConfig::tiny(), 42).unwrap()
    }

    #[test]
    fn forward_produces_finite_logits() {
        let w = tiny_weights();
        let mut m = Transformer::from_weights(&w, Backend::RsrPlusPlus, 0).unwrap();
        let logits = m.forward_token(65).unwrap();
        assert_eq!(logits.len(), w.config.vocab_size);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backends_produce_identical_greedy_tokens() {
        // The paper's §5.3 equality check: Standard vs RSR responses
        // must match token-for-token.
        let w = tiny_weights();
        let prompt: Vec<u32> = "What is 2+2?".bytes().map(|b| b as u32).collect();
        let mut outputs = Vec::new();
        for backend in [Backend::Standard, Backend::Rsr, Backend::RsrPlusPlus] {
            let mut m = Transformer::from_weights(&w, backend, 0).unwrap();
            let mut rng = Rng::new(0);
            let toks = m.generate(&prompt, 8, Sampler::Greedy, &mut rng).unwrap();
            outputs.push((backend.name(), toks));
        }
        for pair in outputs.windows(2) {
            assert_eq!(pair[0].1, pair[1].1, "{} vs {}", pair[0].0, pair[1].0);
        }
    }

    #[test]
    fn plan_store_model_matches_owned_model_token_for_token() {
        use std::sync::Arc;
        let w = tiny_weights();
        let store = crate::runtime::PlanStore::for_model(Arc::new(w.clone()), 0);
        let mut owned = Transformer::from_weights(&w, Backend::RsrPlusPlus, 0).unwrap();
        let mut shared = Transformer::from_plan_store(&w, &store).unwrap();
        let prompt = [5u32, 6, 7];
        let mut rng = Rng::new(3);
        let a = owned.generate(&prompt, 6, Sampler::Greedy, &mut rng).unwrap();
        let mut rng = Rng::new(3);
        let b = shared.generate(&prompt, 6, Sampler::Greedy, &mut rng).unwrap();
        assert_eq!(a, b, "store-served model must match owned model");
        // Every ternary matrix resolved exactly once.
        assert_eq!(store.loaded_len(), w.config.n_layers * 7 + 1);
    }

    #[test]
    fn plan_store_shape_mismatch_fails_at_build() {
        let w = tiny_weights();
        let store = crate::runtime::PlanStore::new();
        // Insert one wrong-shaped plan under a real layer name.
        let mut rng = Rng::new(5);
        let bad = crate::kernels::TernaryMatrix::random(8, 8, 1.0 / 3.0, &mut rng);
        store
            .insert_ternary(
                "layer0.wq",
                crate::kernels::TernaryRsrIndex::preprocess(&bad, 2),
                2,
                1.0,
            )
            .unwrap();
        let err = match Transformer::from_plan_store(&w, &store) {
            Err(e) => e,
            Ok(_) => panic!("expected a shape error"),
        };
        assert!(err.to_string().contains("shape"), "{err}");
    }

    #[test]
    fn generate_is_deterministic_under_greedy() {
        let w = tiny_weights();
        let mut m = Transformer::from_weights(&w, Backend::Standard, 0).unwrap();
        let prompt = [1u32, 2, 3];
        let mut rng = Rng::new(9);
        let a = m.generate(&prompt, 5, Sampler::Greedy, &mut rng).unwrap();
        let b = m.generate(&prompt, 5, Sampler::Greedy, &mut rng).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn reset_between_sequences() {
        let w = tiny_weights();
        let mut m = Transformer::from_weights(&w, Backend::Standard, 0).unwrap();
        m.forward_token(10).unwrap();
        m.forward_token(11).unwrap();
        assert_eq!(m.seq_len(), 2);
        m.reset();
        assert_eq!(m.seq_len(), 0);
    }

    #[test]
    fn forward_batch_rejects_malformed_steps() {
        let w = tiny_weights();
        let mut m = Transformer::from_weights(&w, Backend::Standard, 0).unwrap();
        // Wild slot indices fail cleanly — no wrap, no unbounded alloc.
        assert!(m.forward_batch(&[1], &[usize::MAX]).is_err());
        assert!(m.forward_batch(&[1], &[MAX_SLOTS]).is_err());
        // Duplicate slots, empty steps, length mismatch, bad token.
        assert!(m.forward_batch(&[1, 2], &[0, 0]).is_err());
        assert!(m.forward_batch(&[], &[]).is_err());
        assert!(m.forward_batch(&[1, 2], &[0]).is_err());
        assert!(m.forward_batch(&[999_999], &[0]).is_err());
        // A failed call left no partial state; a valid step still runs.
        assert_eq!(m.seq_len_slot(0), 0);
        assert!(m.forward_batch(&[1], &[1]).is_ok());
        assert_eq!(m.seq_len_slot(1), 1);
    }

    #[test]
    fn forward_chunk_rejects_malformed_steps_without_partial_state() {
        let w = tiny_weights();
        let mut m = Transformer::from_weights(&w, Backend::Standard, 0).unwrap();
        // Zero count, token/row mismatch, count-length mismatch,
        // duplicate slot, context overflow.
        assert!(m.forward_chunk(&[1], &[0], &[0]).is_err());
        assert!(m.forward_chunk(&[1, 2, 3], &[0], &[2]).is_err());
        assert!(m.forward_chunk(&[1, 2], &[0], &[1, 1]).is_err());
        assert!(m.forward_chunk(&[1, 2, 3, 4], &[0, 0], &[2, 2]).is_err());
        let max = w.config.max_seq_len;
        assert!(m.forward_chunk(&vec![1; max + 1], &[0], &[max + 1]).is_err());
        assert_eq!(m.seq_len_slot(0), 0, "failed chunk steps must leave no KV appends");
        // A valid chunk appends exactly its count.
        assert!(m.forward_chunk(&[1, 2, 3], &[0], &[3]).is_ok());
        assert_eq!(m.seq_len_slot(0), 3);
    }

    #[test]
    fn chunked_prefill_is_bitwise_token_by_token_on_owned_backends() {
        // Owned backends execute the identical per-row kernel on every
        // entry point, so each chunk row's logits must equal the
        // corresponding forward_token step to the last bit — including
        // a ragged tail chunk and a chunk covering the whole prompt.
        let w = tiny_weights();
        let prompt = [5u32, 6, 7, 8, 9, 10, 11];
        let v = w.config.vocab_size;
        let mut seq = Transformer::from_weights(&w, Backend::Standard, 0).unwrap();
        let per_step: Vec<Vec<f32>> = prompt
            .iter()
            .map(|&t| seq.forward_token(t).unwrap().to_vec())
            .collect();
        for chunk in [2usize, 3, prompt.len()] {
            let mut m = Transformer::from_weights(&w, Backend::Standard, 0).unwrap();
            let mut rows: Vec<Vec<f32>> = Vec::new();
            let mut p = 0;
            while p < prompt.len() {
                let take = chunk.min(prompt.len() - p);
                let logits = m.forward_chunk(&prompt[p..p + take], &[0], &[take]).unwrap();
                for r in 0..take {
                    rows.push(logits[r * v..(r + 1) * v].to_vec());
                }
                p += take;
            }
            assert_eq!(rows, per_step, "chunk {chunk} diverged from token-by-token");
            assert_eq!(m.seq_len_slot(0), prompt.len());
        }
    }

    #[test]
    fn rejects_out_of_vocab_and_overflow() {
        let w = tiny_weights();
        let mut m = Transformer::from_weights(&w, Backend::Standard, 0).unwrap();
        assert!(m.forward_token(100_000).is_err());
        let mut rng = Rng::new(1);
        assert!(m.generate(&[], 3, Sampler::Greedy, &mut rng).is_err());
    }
}
