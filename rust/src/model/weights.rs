//! Model weights: synthetic generation (the DESIGN.md substitution for
//! the unavailable HF 1.58-bit checkpoints) and the `.rtw` binary file
//! format (magic `RTW1`, config header, then per-tensor payloads with
//! ternary matrices 2-bit packed).

use std::io::{Read, Write};
use std::path::Path;

use super::config::ModelConfig;
use crate::error::{Error, Result};
use crate::kernels::TernaryMatrix;
use crate::util::rng::Rng;

/// Raw weights for one decoder layer.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Attention projections (`d×d`, `d×kv`, `d×kv`, `d×d`).
    pub wq: TernaryMatrix,
    pub wk: TernaryMatrix,
    pub wv: TernaryMatrix,
    pub wo: TernaryMatrix,
    /// MLP projections (`d×ff`, `d×ff`, `ff×d`).
    pub gate: TernaryMatrix,
    pub up: TernaryMatrix,
    pub down: TernaryMatrix,
    /// Per-tensor absmean-style scales.
    pub scales: [f32; 7],
    /// RMSNorm gains.
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
}

/// Full model weights (config + tensors).
#[derive(Debug, Clone)]
pub struct ModelWeights {
    /// Architecture.
    pub config: ModelConfig,
    /// Token embedding table, `vocab × d`, row-major f32.
    pub embedding: Vec<f32>,
    /// Decoder layers.
    pub layers: Vec<LayerWeights>,
    /// Final RMSNorm gain.
    pub final_norm: Vec<f32>,
    /// LM head, `d × vocab` ternary.
    pub lm_head: TernaryMatrix,
    /// LM head scale.
    pub lm_head_scale: f32,
}

impl ModelWeights {
    /// Generate synthetic weights for a config, deterministically from
    /// a seed. Ternary entries are ~uniform over {−1,0,1} (the
    /// distribution BitNet b1.58 absmean quantization produces is close
    /// to this for well-trained layers); norm gains ~N(1, 0.02);
    /// embeddings ~N(0, 0.02).
    pub fn generate(config: ModelConfig, seed: u64) -> Result<Self> {
        config.validate()?;
        let mut rng = Rng::new(seed);
        let d = config.d_model;
        let kv = config.n_kv_heads * config.head_dim();
        let ff = config.d_ff;
        let embedding: Vec<f32> =
            (0..config.vocab_size * d).map(|_| rng.normal_f32() * 0.02).collect();
        let mut layers = Vec::with_capacity(config.n_layers);
        for _ in 0..config.n_layers {
            let tern = |rows: usize, cols: usize, rng: &mut Rng| {
                TernaryMatrix::random(rows, cols, 1.0 / 3.0, rng)
            };
            layers.push(LayerWeights {
                wq: tern(d, d, &mut rng),
                wk: tern(d, kv, &mut rng),
                wv: tern(d, kv, &mut rng),
                wo: tern(d, d, &mut rng),
                gate: tern(d, ff, &mut rng),
                up: tern(d, ff, &mut rng),
                down: tern(ff, d, &mut rng),
                // Small scales keep activations bounded through depth.
                scales: [
                    1.0 / (d as f32).sqrt(),
                    1.0 / (d as f32).sqrt(),
                    1.0 / (d as f32).sqrt(),
                    1.0 / (d as f32).sqrt(),
                    1.0 / (d as f32).sqrt(),
                    1.0 / (d as f32).sqrt(),
                    1.0 / (ff as f32).sqrt(),
                ],
                attn_norm: (0..d).map(|_| 1.0 + rng.normal_f32() * 0.02).collect(),
                mlp_norm: (0..d).map(|_| 1.0 + rng.normal_f32() * 0.02).collect(),
            });
        }
        let final_norm = (0..d).map(|_| 1.0 + rng.normal_f32() * 0.02).collect();
        let lm_head = TernaryMatrix::random(d, config.vocab_size, 1.0 / 3.0, &mut rng);
        Ok(Self {
            config,
            embedding,
            layers,
            final_norm,
            lm_head,
            lm_head_scale: 1.0 / (d as f32).sqrt(),
        })
    }

    /// Serialize to the `.rtw` format.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        let c = &self.config;
        write_str(w, &c.name)?;
        for v in [
            c.vocab_size,
            c.d_model,
            c.n_layers,
            c.n_heads,
            c.n_kv_heads,
            c.d_ff,
            c.max_seq_len,
        ] {
            w.write_all(&(v as u32).to_le_bytes())?;
        }
        w.write_all(&c.rope_theta.to_le_bytes())?;
        write_f32s(w, &self.embedding)?;
        for l in &self.layers {
            for m in [&l.wq, &l.wk, &l.wv, &l.wo, &l.gate, &l.up, &l.down] {
                write_ternary(w, m)?;
            }
            for s in l.scales {
                w.write_all(&s.to_le_bytes())?;
            }
            write_f32s(w, &l.attn_norm)?;
            write_f32s(w, &l.mlp_norm)?;
        }
        write_f32s(w, &self.final_norm)?;
        write_ternary(w, &self.lm_head)?;
        w.write_all(&self.lm_head_scale.to_le_bytes())?;
        Ok(())
    }

    /// Deserialize from the `.rtw` format.
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::InvalidModel("bad magic".into()));
        }
        let name = read_str(r)?;
        let mut dims = [0u32; 7];
        for d in dims.iter_mut() {
            *d = read_u32(r)?;
        }
        let rope_theta = f32::from_le_bytes(read_arr(r)?);
        let config = ModelConfig {
            name,
            vocab_size: dims[0] as usize,
            d_model: dims[1] as usize,
            n_layers: dims[2] as usize,
            n_heads: dims[3] as usize,
            n_kv_heads: dims[4] as usize,
            d_ff: dims[5] as usize,
            max_seq_len: dims[6] as usize,
            rope_theta,
        };
        config.validate()?;
        let d = config.d_model;
        let kv = config.n_kv_heads * config.head_dim();
        let ff = config.d_ff;
        let embedding = read_f32s(r, config.vocab_size * d)?;
        let mut layers = Vec::with_capacity(config.n_layers);
        for _ in 0..config.n_layers {
            let wq = read_ternary(r, d, d)?;
            let wk = read_ternary(r, d, kv)?;
            let wv = read_ternary(r, d, kv)?;
            let wo = read_ternary(r, d, d)?;
            let gate = read_ternary(r, d, ff)?;
            let up = read_ternary(r, d, ff)?;
            let down = read_ternary(r, ff, d)?;
            let mut scales = [0.0f32; 7];
            for s in scales.iter_mut() {
                *s = f32::from_le_bytes(read_arr(r)?);
            }
            let attn_norm = read_f32s(r, d)?;
            let mlp_norm = read_f32s(r, d)?;
            layers.push(LayerWeights {
                wq,
                wk,
                wv,
                wo,
                gate,
                up,
                down,
                scales,
                attn_norm,
                mlp_norm,
            });
        }
        let final_norm = read_f32s(r, d)?;
        let lm_head = read_ternary(r, d, config.vocab_size)?;
        let lm_head_scale = f32::from_le_bytes(read_arr(r)?);
        Ok(Self { config, embedding, layers, final_norm, lm_head, lm_head_scale })
    }

    /// Every ternary weight matrix with its stable artifact name and
    /// per-tensor scale: `layer{i}.{wq,wk,wv,wo,gate,up,down}` plus
    /// `lm_head`. These names key the
    /// [`PlanStore`](crate::runtime::PlanStore) and the `.rsrz` files
    /// `rsr pack` writes, so pack-time and serve-time agree by
    /// construction.
    pub fn named_matrices(&self) -> Vec<(String, &TernaryMatrix, f32)> {
        let mut out = Vec::with_capacity(self.layers.len() * 7 + 1);
        for (i, l) in self.layers.iter().enumerate() {
            let fields: [(&str, &TernaryMatrix, f32); 7] = [
                ("wq", &l.wq, l.scales[0]),
                ("wk", &l.wk, l.scales[1]),
                ("wv", &l.wv, l.scales[2]),
                ("wo", &l.wo, l.scales[3]),
                ("gate", &l.gate, l.scales[4]),
                ("up", &l.up, l.scales[5]),
                ("down", &l.down, l.scales[6]),
            ];
            for (field, m, s) in fields {
                out.push((format!("layer{i}.{field}"), m, s));
            }
        }
        out.push(("lm_head".to_string(), &self.lm_head, self.lm_head_scale));
        out
    }

    /// All artifact names, in [`named_matrices`](Self::named_matrices)
    /// order — what a `PlanStore` must resolve to serve this model.
    pub fn matrix_names(&self) -> Vec<String> {
        self.named_matrices().into_iter().map(|(n, _, _)| n).collect()
    }

    /// Look up one matrix by artifact name.
    pub fn matrix(&self, name: &str) -> Option<(&TernaryMatrix, f32)> {
        self.named_matrices()
            .into_iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, m, s)| (m, s))
    }

    /// Save to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Self::read_from(&mut f)
    }
}

const MAGIC: &[u8; 4] = b"RTW1";

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u32(r)? as usize;
    if len > 1 << 16 {
        return Err(Error::InvalidModel("name too long".into()));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|e| Error::InvalidModel(e.to_string()))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_arr(r)?))
}

fn read_arr<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut b = [0u8; N];
    r.read_exact(&mut b)?;
    Ok(b)
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn write_ternary(w: &mut impl Write, m: &TernaryMatrix) -> Result<()> {
    w.write_all(&m.pack2())?;
    Ok(())
}

fn read_ternary(r: &mut impl Read, rows: usize, cols: usize) -> Result<TernaryMatrix> {
    let nbytes = (rows * cols).div_ceil(4);
    let mut buf = vec![0u8; nbytes];
    r.read_exact(&mut buf)?;
    TernaryMatrix::unpack2(rows, cols, &buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = ModelWeights::generate(ModelConfig::tiny(), 7).unwrap();
        let b = ModelWeights::generate(ModelConfig::tiny(), 7).unwrap();
        assert_eq!(a.embedding, b.embedding);
        assert_eq!(a.layers[0].wq, b.layers[0].wq);
        let c = ModelWeights::generate(ModelConfig::tiny(), 8).unwrap();
        assert_ne!(a.layers[0].wq, c.layers[0].wq);
    }

    #[test]
    fn rtw_round_trips() {
        let w = ModelWeights::generate(ModelConfig::tiny(), 11).unwrap();
        let mut buf = Vec::new();
        w.write_to(&mut buf).unwrap();
        let back = ModelWeights::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(w.config, back.config);
        assert_eq!(w.embedding, back.embedding);
        assert_eq!(w.layers.len(), back.layers.len());
        for (a, b) in w.layers.iter().zip(back.layers.iter()) {
            assert_eq!(a.wq, b.wq);
            assert_eq!(a.down, b.down);
            assert_eq!(a.scales, b.scales);
            assert_eq!(a.attn_norm, b.attn_norm);
        }
        assert_eq!(w.lm_head, back.lm_head);
    }

    #[test]
    fn named_matrices_cover_every_tensor() {
        let w = ModelWeights::generate(ModelConfig::tiny(), 19).unwrap();
        let names = w.matrix_names();
        assert_eq!(names.len(), w.config.n_layers * 7 + 1);
        assert_eq!(names[0], "layer0.wq");
        assert_eq!(names.last().unwrap().as_str(), "lm_head");
        let (m, s) = w.matrix("layer1.down").unwrap();
        assert_eq!(m.rows(), w.config.d_ff);
        assert_eq!(m.cols(), w.config.d_model);
        assert_eq!(s, w.layers[1].scales[6]);
        assert!(w.matrix("layer9.wq").is_none());
    }

    #[test]
    fn rejects_corrupt_files() {
        let w = ModelWeights::generate(ModelConfig::tiny(), 13).unwrap();
        let mut buf = Vec::new();
        w.write_to(&mut buf).unwrap();
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(ModelWeights::read_from(&mut bad.as_slice()).is_err());
        let truncated = &buf[..buf.len() / 2];
        assert!(ModelWeights::read_from(&mut &truncated[..]).is_err());
    }
}
