//! Rotary position embeddings (RoPE), precomputed per position.

/// Precomputed cos/sin tables for RoPE.
#[derive(Debug, Clone)]
pub struct Rope {
    head_dim: usize,
    /// `cos[pos * half + i]`, `half = head_dim / 2`.
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl Rope {
    /// Precompute tables for `max_seq_len` positions.
    pub fn new(head_dim: usize, max_seq_len: usize, theta: f32) -> Self {
        assert!(head_dim % 2 == 0, "head_dim must be even");
        let half = head_dim / 2;
        let mut cos = Vec::with_capacity(max_seq_len * half);
        let mut sin = Vec::with_capacity(max_seq_len * half);
        for pos in 0..max_seq_len {
            for i in 0..half {
                let freq = 1.0 / theta.powf(2.0 * i as f32 / head_dim as f32);
                let angle = pos as f32 * freq;
                cos.push(angle.cos());
                sin.push(angle.sin());
            }
        }
        Self { head_dim, cos, sin }
    }

    /// Rotate one head vector in place for position `pos`
    /// (pairing `(x[i], x[i+half])` — the Llama layout).
    pub fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.head_dim);
        let half = self.head_dim / 2;
        let base = pos * half;
        for i in 0..half {
            let (c, s) = (self.cos[base + i], self.sin[base + i]);
            let (a, b) = (x[i], x[i + half]);
            x[i] = a * c - b * s;
            x[i + half] = a * s + b * c;
        }
    }

    /// Apply to every head in a concatenated multi-head vector.
    pub fn apply_heads(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len() % self.head_dim, 0);
        for head in x.chunks_exact_mut(self.head_dim) {
            self.apply(head, pos);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn position_zero_is_identity() {
        let rope = Rope::new(8, 4, 10_000.0);
        let mut x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let orig = x.clone();
        rope.apply(&mut x, 0);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rotation_preserves_norm() {
        let rope = Rope::new(16, 32, 10_000.0);
        let mut x: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        rope.apply(&mut x, 17);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4);
    }

    #[test]
    fn relative_angle_property() {
        // The dot product of rotated q (pos p) and rotated k (pos q)
        // depends only on p − q for a single frequency pair.
        let rope = Rope::new(2, 16, 10_000.0);
        let q = [1.0f32, 0.0];
        let k = [1.0f32, 0.0];
        let dot_at = |pq: usize, pk: usize| {
            let mut qq = q;
            let mut kk = k;
            rope.apply(&mut qq, pq);
            rope.apply(&mut kk, pk);
            qq[0] * kk[0] + qq[1] * kk[1]
        };
        assert!((dot_at(3, 1) - dot_at(7, 5)).abs() < 1e-5);
        assert!((dot_at(4, 4) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn apply_heads_rotates_each_head() {
        let rope = Rope::new(4, 8, 10_000.0);
        let mut multi = vec![1.0f32, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0];
        rope.apply_heads(&mut multi, 3);
        // Both heads identical input → identical output.
        assert_eq!(multi[..4], multi[4..]);
    }
}
