//! Multi-head attention with grouped-query KV heads, RoPE and per-slot
//! KV caches — single-token (decode) forward, matching the paper's §5.3
//! "one feedforward pass per token" setting where every projection is a
//! vector–ternary-matrix product, plus a lockstep chunked forward
//! ([`Attention::forward_chunk`]) where the projections amortize the
//! shared index across every stacked row — decode slots contribute one
//! row each, prefilling slots contribute a whole prompt chunk — while
//! RoPE, cache appends and the attention reduction stay per-row.

use std::sync::Arc;

use super::bitlinear::BitLinear;
use super::config::ModelConfig;
use super::kv_cache::KvCache;
use super::rope::Rope;
use super::tensor::{ensure_len, softmax};
use crate::error::Result;
use crate::runtime::kv_pool::KvPool;

/// One attention layer: Q/K/V/O projections (all `BitLinear`) + one KV
/// cache per decode slot (slot 0 is the single-sequence path). Every
/// cache draws its pages from the layer's [`KvPool`] — the serving
/// engine hands all layers (and all workers) one shared pool so the
/// `--kv-budget` ceiling is global.
pub struct Attention {
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    wq: BitLinear,
    wk: BitLinear,
    wv: BitLinear,
    wo: BitLinear,
    caches: Vec<KvCache>,
    kv_pool: Arc<KvPool>,
    // Scratch (no allocation in the decode path).
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
    // Stacked batch scratch (grown on the first batched step).
    qb: Vec<f32>,
    kb: Vec<f32>,
    vb: Vec<f32>,
    ctxb: Vec<f32>,
}

impl Attention {
    /// Assemble from projection layers, with a private unbudgeted KV
    /// pool (the single-sequence / non-serving path).
    pub fn new(
        cfg: &ModelConfig,
        wq: BitLinear,
        wk: BitLinear,
        wv: BitLinear,
        wo: BitLinear,
    ) -> Self {
        let pool = Arc::new(KvPool::unbounded(KvPool::DEFAULT_PAGE_TOKENS));
        Self::with_pool(cfg, wq, wk, wv, wo, pool)
    }

    /// Assemble from projection layers, drawing KV pages from a shared
    /// pool (the serving engine's budget-governed path).
    pub fn with_pool(
        cfg: &ModelConfig,
        wq: BitLinear,
        wk: BitLinear,
        wv: BitLinear,
        wo: BitLinear,
        kv_pool: Arc<KvPool>,
    ) -> Self {
        let kv_dim = cfg.n_kv_heads * cfg.head_dim();
        Self {
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim(),
            wq,
            wk,
            wv,
            wo,
            caches: vec![KvCache::new_in(cfg.max_seq_len, kv_dim, Arc::clone(&kv_pool))],
            kv_pool,
            q: vec![0.0; cfg.n_heads * cfg.head_dim()],
            k: vec![0.0; kv_dim],
            v: vec![0.0; kv_dim],
            scores: vec![0.0; cfg.max_seq_len],
            ctx: vec![0.0; cfg.n_heads * cfg.head_dim()],
            qb: Vec::new(),
            kb: Vec::new(),
            vb: Vec::new(),
            ctxb: Vec::new(),
        }
    }

    /// Attach `--profile-layers` probes to the four projections, named
    /// `layer{i}.wq` / `.wk` / `.wv` / `.wo` (the plan-store names, so
    /// the profile rows line up with `rsr tune` output).
    pub(crate) fn attach_probes(
        &mut self,
        profile: &crate::util::obs::LayerProfile,
        layer: usize,
    ) {
        self.wq.attach_probe(profile, &format!("layer{layer}.wq"));
        self.wk.attach_probe(profile, &format!("layer{layer}.wk"));
        self.wv.attach_probe(profile, &format!("layer{layer}.wv"));
        self.wo.attach_probe(profile, &format!("layer{layer}.wo"));
    }

    /// Cached sequence length (slot 0 — the single-sequence path).
    pub fn seq_len(&self) -> usize {
        self.caches[0].len()
    }

    /// KV slots currently allocated (≥ 1).
    pub fn slots(&self) -> usize {
        self.caches.len()
    }

    /// Grow to at least `n` per-slot KV caches. Existing slots keep
    /// their cached state; new slots start empty — and, being paged,
    /// cost nothing until positions are appended.
    pub fn ensure_slots(&mut self, n: usize) {
        let (cap, kv_dim) = (self.caches[0].capacity(), self.k.len());
        while self.caches.len() < n {
            self.caches
                .push(KvCache::new_in(cap, kv_dim, Arc::clone(&self.kv_pool)));
        }
    }

    /// Cached sequence length of one slot.
    pub fn seq_len_slot(&self, slot: usize) -> usize {
        self.caches[slot].len()
    }

    /// Clear one slot's KV cache for a new sequence (slot reuse in the
    /// continuous-batching engine).
    pub fn reset_slot(&mut self, slot: usize) {
        self.caches[slot].reset();
    }

    /// Clear every slot's KV cache.
    pub fn reset(&mut self) {
        for c in &mut self.caches {
            c.reset();
        }
    }

    /// Bytes held by prepared weights (all four projections).
    pub fn weight_bytes(&self) -> usize {
        self.wq.weight_bytes()
            + self.wk.weight_bytes()
            + self.wv.weight_bytes()
            + self.wo.weight_bytes()
    }

    /// Decode-step forward: attend the normalized hidden `x` at
    /// position `pos` against everything cached so far (causal).
    /// Single-sequence path — uses slot 0's cache.
    pub fn forward(&mut self, x: &[f32], pos: usize, rope: &Rope, out: &mut [f32]) -> Result<()> {
        self.wq.forward(x, &mut self.q)?;
        self.wk.forward(x, &mut self.k)?;
        self.wv.forward(x, &mut self.v)?;

        rope.apply_heads(&mut self.q, pos);
        rope.apply_heads(&mut self.k, pos);
        let cache = &mut self.caches[0];
        cache.append(&self.k, &self.v)?;

        let t = cache.len(); // positions 0..t-1 (inclusive of current)
        let hd = self.head_dim;
        let group = self.n_heads / self.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();

        for h in 0..self.n_heads {
            let kv_h = h / group;
            let qh = &self.q[h * hd..(h + 1) * hd];
            let scores = &mut self.scores[..t];
            for (p, s) in scores.iter_mut().enumerate() {
                let krow = cache.key(p);
                let kh = &krow[kv_h * hd..(kv_h + 1) * hd];
                *s = qh.iter().zip(kh.iter()).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax(scores);
            let ctx_h = &mut self.ctx[h * hd..(h + 1) * hd];
            ctx_h.fill(0.0);
            for (p, &w) in scores.iter().enumerate() {
                let vrow = cache.value(p);
                let vh = &vrow[kv_h * hd..(kv_h + 1) * hd];
                for (c, &vv) in ctx_h.iter_mut().zip(vh.iter()) {
                    *c += w * vv;
                }
            }
        }
        self.wo.forward(&self.ctx, out)
    }

    /// Lockstep chunked forward over the live slots: slot `slots[i]`
    /// contributes `counts[i]` consecutive rows of `xs` (row-major
    /// `Σ counts × d_model`, already normed), one per token, starting
    /// at that slot's own cache position. A decode slot feeds one row
    /// (`counts[i] == 1` — the classic lockstep decode step); a
    /// prefilling slot feeds a whole prompt chunk, which is where the
    /// paper's index-reuse argument meets the sequence axis.
    ///
    /// The Q/K/V/O projections run **batched over every stacked row** —
    /// the shared plan index is read once per step instead of once per
    /// token, the win the batched RSR kernels exist for. RoPE is
    /// applied per row at the row's own position, the chunk's K/V rows
    /// are all appended to the slot's cache, and the attention
    /// reduction loops rows with exactly the arithmetic of
    /// [`forward`](Self::forward): the row at chunk offset `j` attends
    /// positions `0..=base+j` only. Because every later chunk row is
    /// already in the cache when the earlier ones attend, the causal
    /// mask *within* the chunk is this per-row window truncation — no
    /// score is ever computed against a future position.
    pub fn forward_chunk(
        &mut self,
        xs: &[f32],
        slots: &[usize],
        counts: &[usize],
        rope: &Rope,
        out: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(slots.len(), counts.len());
        let rows: usize = counts.iter().sum();
        let q_dim = self.n_heads * self.head_dim;
        let kv_dim = self.k.len();
        if let Some(&max) = slots.iter().max() {
            // Same slot cap as the transformer: each slot is a full KV
            // cache, so a wild index fails instead of overflowing
            // `max + 1` or allocating without bound.
            if max >= super::transformer::MAX_SLOTS {
                return Err(crate::error::Error::Config(format!(
                    "forward_chunk: slot {max} exceeds the slot cap {}",
                    super::transformer::MAX_SLOTS
                )));
            }
            self.ensure_slots(max + 1);
        }
        ensure_len(&mut self.qb, rows * q_dim);
        ensure_len(&mut self.kb, rows * kv_dim);
        ensure_len(&mut self.vb, rows * kv_dim);
        ensure_len(&mut self.ctxb, rows * q_dim);
        self.wq.forward_batch(xs, rows, &mut self.qb[..rows * q_dim])?;
        self.wk.forward_batch(xs, rows, &mut self.kb[..rows * kv_dim])?;
        self.wv.forward_batch(xs, rows, &mut self.vb[..rows * kv_dim])?;

        // Per-position RoPE + multi-position KV append: the row at
        // chunk offset `j` of slot `i` sits at position `base + j`,
        // `base` being the slot's cache fill before this step.
        let mut row = 0usize;
        for (i, &slot) in slots.iter().enumerate() {
            let base = self.caches[slot].len();
            for j in 0..counts[i] {
                let pos = base + j;
                rope.apply_heads(&mut self.qb[row * q_dim..(row + 1) * q_dim], pos);
                rope.apply_heads(&mut self.kb[row * kv_dim..(row + 1) * kv_dim], pos);
                self.caches[slot].append(
                    &self.kb[row * kv_dim..(row + 1) * kv_dim],
                    &self.vb[row * kv_dim..(row + 1) * kv_dim],
                )?;
                row += 1;
            }
        }

        let hd = self.head_dim;
        let group = self.n_heads / self.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut row = 0usize;
        for (i, &slot) in slots.iter().enumerate() {
            let cache = &self.caches[slot];
            // Every chunk row is in the cache by now; the causal window
            // of the row at offset `j` ends at its own position.
            let base = cache.len() - counts[i];
            for j in 0..counts[i] {
                let t = base + j + 1;
                for h in 0..self.n_heads {
                    let kv_h = h / group;
                    let qh = &self.qb[row * q_dim + h * hd..row * q_dim + (h + 1) * hd];
                    let scores = &mut self.scores[..t];
                    for (p, s) in scores.iter_mut().enumerate() {
                        let kh = &cache.key(p)[kv_h * hd..(kv_h + 1) * hd];
                        *s = qh.iter().zip(kh.iter()).map(|(a, b)| a * b).sum::<f32>()
                            * scale;
                    }
                    softmax(scores);
                    let ctx_h =
                        &mut self.ctxb[row * q_dim + h * hd..row * q_dim + (h + 1) * hd];
                    ctx_h.fill(0.0);
                    for (p, &w) in scores.iter().enumerate() {
                        let vh = &cache.value(p)[kv_h * hd..(kv_h + 1) * hd];
                        for (c, &vv) in ctx_h.iter_mut().zip(vh.iter()) {
                            *c += w * vv;
                        }
                    }
                }
                row += 1;
            }
        }
        self.wo.forward_batch(&self.ctxb[..rows * q_dim], rows, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Backend, TernaryMatrix};
    use crate::util::rng::Rng;

    fn make_attn(cfg: &ModelConfig, backend: Backend, seed: u64) -> Attention {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let kv = cfg.n_kv_heads * cfg.head_dim();
        let mk = |rows: usize, cols: usize, rng: &mut Rng| {
            BitLinear::new(
                TernaryMatrix::random(rows, cols, 1.0 / 3.0, rng),
                1.0,
                backend,
                0,
            )
            .unwrap()
        };
        let wq = mk(d, d, &mut rng);
        let wk = mk(d, kv, &mut rng);
        let wv = mk(d, kv, &mut rng);
        let wo = mk(d, d, &mut rng);
        Attention::new(cfg, wq, wk, wv, wo)
    }

    #[test]
    fn decode_steps_accumulate_cache() {
        let cfg = ModelConfig::tiny();
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut attn = make_attn(&cfg, Backend::RsrPlusPlus, 179);
        let mut rng = Rng::new(181);
        let mut out = vec![0.0; cfg.d_model];
        for pos in 0..5 {
            let x = rng.f32_vec(cfg.d_model, -1.0, 1.0);
            attn.forward(&x, pos, &rope, &mut out).unwrap();
            assert_eq!(attn.seq_len(), pos + 1);
            assert!(out.iter().all(|v| v.is_finite()));
        }
        attn.reset();
        assert_eq!(attn.seq_len(), 0);
    }

    #[test]
    fn backends_agree_through_attention() {
        let cfg = ModelConfig::tiny();
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut std_attn = make_attn(&cfg, Backend::Standard, 191);
        let mut rsr_attn = make_attn(&cfg, Backend::RsrPlusPlus, 191);
        let mut rng = Rng::new(193);
        let mut a = vec![0.0; cfg.d_model];
        let mut b = vec![0.0; cfg.d_model];
        for pos in 0..4 {
            let x = rng.f32_vec(cfg.d_model, -1.0, 1.0);
            std_attn.forward(&x, pos, &rope, &mut a).unwrap();
            rsr_attn.forward(&x, pos, &rope, &mut b).unwrap();
            for (x1, x2) in a.iter().zip(b.iter()) {
                assert!((x1 - x2).abs() < 1e-2 * (1.0 + x1.abs()), "{x1} vs {x2}");
            }
        }
    }

    #[test]
    fn chunked_prefill_rows_match_sequential_decode_bitwise() {
        // One chunk of 5 positions vs 5 single-token steps: per row the
        // projections, RoPE, causal window and reduction perform the
        // identical f32 sequence, so outputs must match to the last bit
        // (owned backends route batched rows through the same per-row
        // kernel).
        let cfg = ModelConfig::tiny();
        let d = cfg.d_model;
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut seq = make_attn(&cfg, Backend::Standard, 211);
        let mut chunked = make_attn(&cfg, Backend::Standard, 211);
        let mut rng = Rng::new(213);
        let n = 5;
        let xs = rng.f32_vec(n * d, -1.0, 1.0);
        let mut expect = vec![0.0; n * d];
        for pos in 0..n {
            let mut out = vec![0.0; d];
            seq.forward(&xs[pos * d..(pos + 1) * d], pos, &rope, &mut out).unwrap();
            expect[pos * d..(pos + 1) * d].copy_from_slice(&out);
        }
        let mut out = vec![0.0; n * d];
        chunked.forward_chunk(&xs, &[0], &[n], &rope, &mut out).unwrap();
        assert_eq!(out, expect, "chunked rows must be bit-identical to decode steps");
        assert_eq!(chunked.seq_len(), n);

        // A follow-up chunk continues from the cached positions: split
        // 3 + 2 must also match.
        let mut split = make_attn(&cfg, Backend::Standard, 211);
        let mut o1 = vec![0.0; 3 * d];
        let mut o2 = vec![0.0; 2 * d];
        split.forward_chunk(&xs[..3 * d], &[0], &[3], &rope, &mut o1).unwrap();
        split.forward_chunk(&xs[3 * d..], &[0], &[2], &rope, &mut o2).unwrap();
        assert_eq!(&o1[..], &expect[..3 * d]);
        assert_eq!(&o2[..], &expect[3 * d..]);
    }

    #[test]
    fn first_token_attends_only_to_itself() {
        // With a single cached position softmax over one score = 1, so
        // ctx == v: output must equal wo(v).
        let cfg = ModelConfig::tiny();
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut attn = make_attn(&cfg, Backend::Standard, 197);
        let mut rng = Rng::new(199);
        let x = rng.f32_vec(cfg.d_model, -1.0, 1.0);
        let mut out = vec![0.0; cfg.d_model];
        attn.forward(&x, 0, &rope, &mut out).unwrap();
        // Recompute v and wo(v) manually via fresh layers with the same
        // seed for construction.
        let mut attn2 = make_attn(&cfg, Backend::Standard, 197);
        let mut v = vec![0.0; cfg.n_kv_heads * cfg.head_dim()];
        attn2.wv.forward(&x, &mut v).unwrap();
        // GQA expansion: each kv head serves group heads → ctx is v
        // repeated per head group.
        let hd = cfg.head_dim();
        let group = cfg.n_heads / cfg.n_kv_heads;
        let mut ctx = vec![0.0; cfg.n_heads * hd];
        for h in 0..cfg.n_heads {
            let kv_h = h / group;
            ctx[h * hd..(h + 1) * hd].copy_from_slice(&v[kv_h * hd..(kv_h + 1) * hd]);
        }
        let mut expect = vec![0.0; cfg.d_model];
        attn2.wo.forward(&ctx, &mut expect).unwrap();
        for (g, e) in out.iter().zip(expect.iter()) {
            assert!((g - e).abs() < 1e-4, "{g} vs {e}");
        }
    }
}
