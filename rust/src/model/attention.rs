//! Multi-head attention with grouped-query KV heads, RoPE and a KV
//! cache — single-token (decode) forward, matching the paper's §5.3
//! "one feedforward pass per token" setting where every projection is a
//! vector–ternary-matrix product.

use super::bitlinear::BitLinear;
use super::config::ModelConfig;
use super::kv_cache::KvCache;
use super::rope::Rope;
use super::tensor::softmax;
use crate::error::Result;

/// One attention layer: Q/K/V/O projections (all `BitLinear`) + cache.
pub struct Attention {
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    wq: BitLinear,
    wk: BitLinear,
    wv: BitLinear,
    wo: BitLinear,
    cache: KvCache,
    // Scratch (no allocation in the decode path).
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
}

impl Attention {
    /// Assemble from projection layers.
    pub fn new(
        cfg: &ModelConfig,
        wq: BitLinear,
        wk: BitLinear,
        wv: BitLinear,
        wo: BitLinear,
    ) -> Self {
        let kv_dim = cfg.n_kv_heads * cfg.head_dim();
        Self {
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim(),
            wq,
            wk,
            wv,
            wo,
            cache: KvCache::new(cfg.max_seq_len, kv_dim),
            q: vec![0.0; cfg.n_heads * cfg.head_dim()],
            k: vec![0.0; kv_dim],
            v: vec![0.0; kv_dim],
            scores: vec![0.0; cfg.max_seq_len],
            ctx: vec![0.0; cfg.n_heads * cfg.head_dim()],
        }
    }

    /// Cached sequence length.
    pub fn seq_len(&self) -> usize {
        self.cache.len()
    }

    /// Clear the KV cache for a new sequence.
    pub fn reset(&mut self) {
        self.cache.reset();
    }

    /// Bytes held by prepared weights (all four projections).
    pub fn weight_bytes(&self) -> usize {
        self.wq.weight_bytes()
            + self.wk.weight_bytes()
            + self.wv.weight_bytes()
            + self.wo.weight_bytes()
    }

    /// Decode-step forward: attend the normalized hidden `x` at
    /// position `pos` against everything cached so far (causal).
    pub fn forward(&mut self, x: &[f32], pos: usize, rope: &Rope, out: &mut [f32]) -> Result<()> {
        self.wq.forward(x, &mut self.q)?;
        self.wk.forward(x, &mut self.k)?;
        self.wv.forward(x, &mut self.v)?;

        rope.apply_heads(&mut self.q, pos);
        rope.apply_heads(&mut self.k, pos);
        self.cache.append(&self.k, &self.v)?;

        let t = self.cache.len(); // positions 0..t-1 (inclusive of current)
        let hd = self.head_dim;
        let group = self.n_heads / self.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();

        for h in 0..self.n_heads {
            let kv_h = h / group;
            let qh = &self.q[h * hd..(h + 1) * hd];
            let scores = &mut self.scores[..t];
            for (p, s) in scores.iter_mut().enumerate() {
                let krow = self.cache.key(p);
                let kh = &krow[kv_h * hd..(kv_h + 1) * hd];
                *s = qh.iter().zip(kh.iter()).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax(scores);
            let ctx_h = &mut self.ctx[h * hd..(h + 1) * hd];
            ctx_h.fill(0.0);
            for (p, &w) in scores.iter().enumerate() {
                let vrow = self.cache.value(p);
                let vh = &vrow[kv_h * hd..(kv_h + 1) * hd];
                for (c, &vv) in ctx_h.iter_mut().zip(vh.iter()) {
                    *c += w * vv;
                }
            }
        }
        self.wo.forward(&self.ctx, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Backend, TernaryMatrix};
    use crate::util::rng::Rng;

    fn make_attn(cfg: &ModelConfig, backend: Backend, seed: u64) -> Attention {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let kv = cfg.n_kv_heads * cfg.head_dim();
        let mk = |rows: usize, cols: usize, rng: &mut Rng| {
            BitLinear::new(
                TernaryMatrix::random(rows, cols, 1.0 / 3.0, rng),
                1.0,
                backend,
                0,
            )
            .unwrap()
        };
        let wq = mk(d, d, &mut rng);
        let wk = mk(d, kv, &mut rng);
        let wv = mk(d, kv, &mut rng);
        let wo = mk(d, d, &mut rng);
        Attention::new(cfg, wq, wk, wv, wo)
    }

    #[test]
    fn decode_steps_accumulate_cache() {
        let cfg = ModelConfig::tiny();
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut attn = make_attn(&cfg, Backend::RsrPlusPlus, 179);
        let mut rng = Rng::new(181);
        let mut out = vec![0.0; cfg.d_model];
        for pos in 0..5 {
            let x = rng.f32_vec(cfg.d_model, -1.0, 1.0);
            attn.forward(&x, pos, &rope, &mut out).unwrap();
            assert_eq!(attn.seq_len(), pos + 1);
            assert!(out.iter().all(|v| v.is_finite()));
        }
        attn.reset();
        assert_eq!(attn.seq_len(), 0);
    }

    #[test]
    fn backends_agree_through_attention() {
        let cfg = ModelConfig::tiny();
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut std_attn = make_attn(&cfg, Backend::Standard, 191);
        let mut rsr_attn = make_attn(&cfg, Backend::RsrPlusPlus, 191);
        let mut rng = Rng::new(193);
        let mut a = vec![0.0; cfg.d_model];
        let mut b = vec![0.0; cfg.d_model];
        for pos in 0..4 {
            let x = rng.f32_vec(cfg.d_model, -1.0, 1.0);
            std_attn.forward(&x, pos, &rope, &mut a).unwrap();
            rsr_attn.forward(&x, pos, &rope, &mut b).unwrap();
            for (x1, x2) in a.iter().zip(b.iter()) {
                assert!((x1 - x2).abs() < 1e-2 * (1.0 + x1.abs()), "{x1} vs {x2}");
            }
        }
    }

    #[test]
    fn first_token_attends_only_to_itself() {
        // With a single cached position softmax over one score = 1, so
        // ctx == v: output must equal wo(v).
        let cfg = ModelConfig::tiny();
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq_len, cfg.rope_theta);
        let mut attn = make_attn(&cfg, Backend::Standard, 197);
        let mut rng = Rng::new(199);
        let x = rng.f32_vec(cfg.d_model, -1.0, 1.0);
        let mut out = vec![0.0; cfg.d_model];
        attn.forward(&x, 0, &rope, &mut out).unwrap();
        // Recompute v and wo(v) manually via fresh layers with the same
        // seed for construction.
        let mut attn2 = make_attn(&cfg, Backend::Standard, 197);
        let mut v = vec![0.0; cfg.n_kv_heads * cfg.head_dim()];
        attn2.wv.forward(&x, &mut v).unwrap();
        // GQA expansion: each kv head serves group heads → ctx is v
        // repeated per head group.
        let hd = cfg.head_dim();
        let group = cfg.n_heads / cfg.n_kv_heads;
        let mut ctx = vec![0.0; cfg.n_heads * hd];
        for h in 0..cfg.n_heads {
            let kv_h = h / group;
            ctx[h * hd..(h + 1) * hd].copy_from_slice(&v[kv_h * hd..(kv_h + 1) * hd]);
        }
        let mut expect = vec![0.0; cfg.d_model];
        attn2.wo.forward(&ctx, &mut expect).unwrap();
        for (g, e) in out.iter().zip(expect.iter()) {
            assert!((g - e).abs() < 1e-4, "{g} vs {e}");
        }
    }
}
