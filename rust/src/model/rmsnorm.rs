//! RMSNorm — the normalization used by Llama/Falcon-family models.

/// RMS normalization with a learned (here: synthetic) gain vector.
#[derive(Debug, Clone)]
pub struct RmsNorm {
    weight: Vec<f32>,
    eps: f32,
}

impl RmsNorm {
    /// Build from a gain vector.
    pub fn new(weight: Vec<f32>, eps: f32) -> Self {
        Self { weight, eps }
    }

    /// Hidden width.
    pub fn dim(&self) -> usize {
        self.weight.len()
    }

    /// Gain vector (weights serialization).
    pub fn weight(&self) -> &[f32] {
        &self.weight
    }

    /// `out = x / rms(x) * weight`.
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.weight.len());
        debug_assert_eq!(out.len(), x.len());
        let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
        let inv = 1.0 / (ms + self.eps).sqrt();
        for ((o, &xi), &w) in out.iter_mut().zip(x.iter()).zip(self.weight.iter()) {
            *o = xi * inv * w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_gain_normalizes_rms_to_one() {
        let norm = RmsNorm::new(vec![1.0; 4], 1e-6);
        let x = [2.0f32, -2.0, 2.0, -2.0];
        let mut out = [0.0f32; 4];
        norm.forward(&x, &mut out);
        let rms = (out.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-4);
        assert_eq!(out[0], -out[1]);
    }

    #[test]
    fn gain_scales_output() {
        let norm = RmsNorm::new(vec![2.0, 2.0], 1e-6);
        let base = RmsNorm::new(vec![1.0, 1.0], 1e-6);
        let x = [3.0f32, 4.0];
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        norm.forward(&x, &mut a);
        base.forward(&x, &mut b);
        assert!((a[0] - 2.0 * b[0]).abs() < 1e-6);
    }

    #[test]
    fn zero_vector_is_finite() {
        let norm = RmsNorm::new(vec![1.0; 3], 1e-6);
        let mut out = [0.0f32; 3];
        norm.forward(&[0.0; 3], &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
