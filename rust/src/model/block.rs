//! One decoder block: pre-norm attention + pre-norm MLP, both residual.

use super::attention::Attention;
use super::mlp::Mlp;
use super::rmsnorm::RmsNorm;
use super::rope::Rope;
use super::tensor::add_assign;
use crate::error::Result;

/// A decoder block.
pub struct Block {
    attn_norm: RmsNorm,
    attn: Attention,
    mlp_norm: RmsNorm,
    mlp: Mlp,
    // Scratch.
    normed: Vec<f32>,
    branch: Vec<f32>,
}

impl Block {
    /// Assemble a block.
    pub fn new(attn_norm: RmsNorm, attn: Attention, mlp_norm: RmsNorm, mlp: Mlp) -> Self {
        let d = attn_norm.dim();
        Self { attn_norm, attn, mlp_norm, mlp, normed: vec![0.0; d], branch: vec![0.0; d] }
    }

    /// Clear the attention KV cache.
    pub fn reset(&mut self) {
        self.attn.reset();
    }

    /// Cached sequence length.
    pub fn seq_len(&self) -> usize {
        self.attn.seq_len()
    }

    /// Bytes held by prepared weights.
    pub fn weight_bytes(&self) -> usize {
        self.attn.weight_bytes() + self.mlp.weight_bytes()
    }

    /// In-place residual update of the hidden state `h` for position `pos`.
    pub fn forward(&mut self, h: &mut [f32], pos: usize, rope: &Rope) -> Result<()> {
        self.attn_norm.forward(h, &mut self.normed);
        self.attn.forward(&self.normed, pos, rope, &mut self.branch)?;
        add_assign(h, &self.branch);

        self.mlp_norm.forward(h, &mut self.normed);
        self.mlp.forward(&self.normed, &mut self.branch)?;
        add_assign(h, &self.branch);
        Ok(())
    }
}
