//! One decoder block: pre-norm attention + pre-norm MLP, both residual.

use super::attention::Attention;
use super::mlp::Mlp;
use super::rmsnorm::RmsNorm;
use super::rope::Rope;
use super::tensor::{add_assign, ensure_len};
use crate::error::Result;

/// A decoder block.
pub struct Block {
    attn_norm: RmsNorm,
    attn: Attention,
    mlp_norm: RmsNorm,
    mlp: Mlp,
    // Scratch.
    normed: Vec<f32>,
    branch: Vec<f32>,
    // Stacked batch scratch (grown on the first batched step).
    normed_b: Vec<f32>,
    branch_b: Vec<f32>,
}

impl Block {
    /// Assemble a block.
    pub fn new(attn_norm: RmsNorm, attn: Attention, mlp_norm: RmsNorm, mlp: Mlp) -> Self {
        let d = attn_norm.dim();
        Self {
            attn_norm,
            attn,
            mlp_norm,
            mlp,
            normed: vec![0.0; d],
            branch: vec![0.0; d],
            normed_b: Vec::new(),
            branch_b: Vec::new(),
        }
    }

    /// Attach `--profile-layers` probes to every projection in this
    /// block (`layer` is the block index used in plan-store names).
    pub(crate) fn attach_probes(
        &mut self,
        profile: &crate::util::obs::LayerProfile,
        layer: usize,
    ) {
        self.attn.attach_probes(profile, layer);
        self.mlp.attach_probes(profile, layer);
    }

    /// Clear every slot's KV cache.
    pub fn reset(&mut self) {
        self.attn.reset();
    }

    /// Cached sequence length (slot 0).
    pub fn seq_len(&self) -> usize {
        self.attn.seq_len()
    }

    /// KV slots currently allocated.
    pub fn slots(&self) -> usize {
        self.attn.slots()
    }

    /// Grow to at least `n` KV slots.
    pub fn ensure_slots(&mut self, n: usize) {
        self.attn.ensure_slots(n);
    }

    /// Cached sequence length of one slot.
    pub fn seq_len_slot(&self, slot: usize) -> usize {
        self.attn.seq_len_slot(slot)
    }

    /// Clear one slot's KV cache.
    pub fn reset_slot(&mut self, slot: usize) {
        self.attn.reset_slot(slot);
    }

    /// Bytes held by prepared weights.
    pub fn weight_bytes(&self) -> usize {
        self.attn.weight_bytes() + self.mlp.weight_bytes()
    }

    /// In-place residual update of the hidden state `h` for position `pos`.
    pub fn forward(&mut self, h: &mut [f32], pos: usize, rope: &Rope) -> Result<()> {
        self.attn_norm.forward(h, &mut self.normed);
        self.attn.forward(&self.normed, pos, rope, &mut self.branch)?;
        add_assign(h, &self.branch);

        self.mlp_norm.forward(h, &mut self.normed);
        self.mlp.forward(&self.normed, &mut self.branch)?;
        add_assign(h, &self.branch);
        Ok(())
    }

    /// Lockstep residual update of the stacked hidden states `hs`
    /// (row-major `Σ counts × d`: slot `slots[i]` owns `counts[i]`
    /// consecutive rows — one per token it feeds this step, so a decode
    /// slot owns one row and a prefilling slot owns its whole chunk).
    /// Norms and residual adds are per-row (identical arithmetic to
    /// [`forward`](Self::forward)); the `BitLinear` projections inside
    /// attention and the MLP run batched over every stacked row.
    pub fn forward_chunk(
        &mut self,
        hs: &mut [f32],
        slots: &[usize],
        counts: &[usize],
        rope: &Rope,
    ) -> Result<()> {
        let rows: usize = counts.iter().sum();
        let d = self.attn_norm.dim();
        debug_assert_eq!(hs.len(), rows * d);
        ensure_len(&mut self.normed_b, rows * d);
        ensure_len(&mut self.branch_b, rows * d);
        for i in 0..rows {
            self.attn_norm
                .forward(&hs[i * d..(i + 1) * d], &mut self.normed_b[i * d..(i + 1) * d]);
        }
        self.attn.forward_chunk(
            &self.normed_b[..rows * d],
            slots,
            counts,
            rope,
            &mut self.branch_b[..rows * d],
        )?;
        for i in 0..rows {
            add_assign(&mut hs[i * d..(i + 1) * d], &self.branch_b[i * d..(i + 1) * d]);
        }
        for i in 0..rows {
            self.mlp_norm
                .forward(&hs[i * d..(i + 1) * d], &mut self.normed_b[i * d..(i + 1) * d]);
        }
        self.mlp.forward_chunk(&self.normed_b[..rows * d], rows, &mut self.branch_b[..rows * d])?;
        for i in 0..rows {
            add_assign(&mut hs[i * d..(i + 1) * d], &self.branch_b[i * d..(i + 1) * d]);
        }
        Ok(())
    }
}
