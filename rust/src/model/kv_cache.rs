//! Per-layer key/value cache for autoregressive decoding.

use crate::error::{Error, Result};

/// KV cache for one layer: `max_seq_len × (n_kv_heads · head_dim)`
/// rows for keys and values.
#[derive(Debug, Clone)]
pub struct KvCache {
    kv_dim: usize,
    max_seq_len: usize,
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// Allocate an empty cache.
    pub fn new(max_seq_len: usize, kv_dim: usize) -> Self {
        Self {
            kv_dim,
            max_seq_len,
            len: 0,
            k: vec![0.0; max_seq_len * kv_dim],
            v: vec![0.0; max_seq_len * kv_dim],
        }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing has been appended.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Capacity in positions.
    pub fn capacity(&self) -> usize {
        self.max_seq_len
    }

    /// Append one position's K and V rows.
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) -> Result<()> {
        if k_row.len() != self.kv_dim || v_row.len() != self.kv_dim {
            return Err(Error::ShapeMismatch("kv row width".into()));
        }
        if self.len >= self.max_seq_len {
            return Err(Error::Serving(format!(
                "KV cache full at {} positions",
                self.max_seq_len
            )));
        }
        let off = self.len * self.kv_dim;
        self.k[off..off + self.kv_dim].copy_from_slice(k_row);
        self.v[off..off + self.kv_dim].copy_from_slice(v_row);
        self.len += 1;
        Ok(())
    }

    /// Key row at position `pos`.
    pub fn key(&self, pos: usize) -> &[f32] {
        debug_assert!(pos < self.len);
        &self.k[pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }

    /// Value row at position `pos`.
    pub fn value(&self, pos: usize) -> &[f32] {
        debug_assert!(pos < self.len);
        &self.v[pos * self.kv_dim..(pos + 1) * self.kv_dim]
    }

    /// Drop all cached positions (new request on a reused slot).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Heap bytes.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(4, 3);
        c.append(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]).unwrap();
        c.append(&[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.key(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.value(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn overflow_is_an_error() {
        let mut c = KvCache::new(1, 2);
        c.append(&[0.0; 2], &[0.0; 2]).unwrap();
        assert!(c.append(&[0.0; 2], &[0.0; 2]).is_err());
    }

    #[test]
    fn wrong_width_is_an_error() {
        let mut c = KvCache::new(2, 2);
        assert!(c.append(&[0.0; 3], &[0.0; 2]).is_err());
    }

    #[test]
    fn reset_reuses_capacity() {
        let mut c = KvCache::new(2, 2);
        c.append(&[1.0; 2], &[1.0; 2]).unwrap();
        c.reset();
        assert!(c.is_empty());
        c.append(&[2.0; 2], &[2.0; 2]).unwrap();
        assert_eq!(c.key(0), &[2.0, 2.0]);
    }
}
