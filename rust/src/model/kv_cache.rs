//! Per-layer key/value cache for autoregressive decoding — a page
//! table over [`KvPool`] grants.
//!
//! Storage is allocated page-by-page (`pool.page_tokens()` positions
//! each) as positions are appended, and every page is returned to the
//! pool on [`reset`](KvCache::reset) or drop — so a retired slot costs
//! nothing and `max_slots` bounds concurrency, not memory. Reads
//! ([`key`](KvCache::key) / [`value`](KvCache::value)) return the same
//! single-position `kv_dim`-wide rows the contiguous layout returned,
//! holding the same values — the attention arithmetic consumes an
//! identical f32 sequence, so paged decode/prefill is bit-identical to
//! the pre-pool layout (pinned by `rust/tests/prefill.rs`).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::runtime::kv_pool::{page_bytes, KvPool};

/// One granted page: `page_tokens` K rows and V rows, owned by the
/// cache that acquired it (the pool tracks grants, not storage).
#[derive(Debug)]
struct Page {
    k: Box<[f32]>,
    v: Box<[f32]>,
}

/// KV cache for one layer: up to `max_seq_len` positions of
/// `n_kv_heads · head_dim` K and V lanes, paged on demand.
#[derive(Debug)]
pub struct KvCache {
    kv_dim: usize,
    max_seq_len: usize,
    len: usize,
    pages: Vec<Page>,
    pool: Arc<KvPool>,
}

impl KvCache {
    /// An empty cache with its own unbudgeted pool (the standalone /
    /// single-sequence path; no page grant can ever fail).
    pub fn new(max_seq_len: usize, kv_dim: usize) -> Self {
        Self::new_in(
            max_seq_len,
            kv_dim,
            Arc::new(KvPool::unbounded(KvPool::DEFAULT_PAGE_TOKENS)),
        )
    }

    /// An empty cache drawing pages from a shared pool (the serving
    /// path: one pool governs every layer × slot × worker).
    pub fn new_in(max_seq_len: usize, kv_dim: usize, pool: Arc<KvPool>) -> Self {
        Self { kv_dim, max_seq_len, len: 0, pages: Vec::new(), pool }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing has been appended.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Capacity in positions (the sequence-length ceiling; physical
    /// pages are granted lazily up to it).
    pub fn capacity(&self) -> usize {
        self.max_seq_len
    }

    /// Pages currently held.
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// The pool this cache draws from.
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Append one position's K and V rows, acquiring a page grant at
    /// each page boundary. A refused grant is the named budget error —
    /// the engine sheds or evicts on it; nothing panics.
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) -> Result<()> {
        if k_row.len() != self.kv_dim || v_row.len() != self.kv_dim {
            return Err(Error::ShapeMismatch("kv row width".into()));
        }
        if self.len >= self.max_seq_len {
            return Err(Error::Serving(format!(
                "KV cache full at {} positions",
                self.max_seq_len
            )));
        }
        let pt = self.pool.page_tokens();
        let (page, slot) = (self.len / pt, self.len % pt);
        if page == self.pages.len() {
            if !self.pool.try_acquire() {
                return Err(Error::KvBudgetExceeded(format!(
                    "kv pool exhausted at {} of {} pages",
                    self.pool.pages_in_use(),
                    self.pool.total_pages()
                )));
            }
            let lanes = pt * self.kv_dim;
            self.pages.push(Page {
                k: vec![0.0; lanes].into_boxed_slice(),
                v: vec![0.0; lanes].into_boxed_slice(),
            });
        }
        let off = slot * self.kv_dim;
        let p = &mut self.pages[page];
        p.k[off..off + self.kv_dim].copy_from_slice(k_row);
        p.v[off..off + self.kv_dim].copy_from_slice(v_row);
        self.len += 1;
        Ok(())
    }

    /// Key row at position `pos`.
    pub fn key(&self, pos: usize) -> &[f32] {
        debug_assert!(pos < self.len);
        let pt = self.pool.page_tokens();
        let off = (pos % pt) * self.kv_dim;
        &self.pages[pos / pt].k[off..off + self.kv_dim]
    }

    /// Value row at position `pos`.
    pub fn value(&self, pos: usize) -> &[f32] {
        debug_assert!(pos < self.len);
        let pt = self.pool.page_tokens();
        let off = (pos % pt) * self.kv_dim;
        &self.pages[pos / pt].v[off..off + self.kv_dim]
    }

    /// Drop all cached positions and return every page to the pool
    /// (new request on a reused slot — a retired slot holds zero
    /// pages, the fix for the eager `max_slots × max_seq_len`
    /// over-allocation).
    pub fn reset(&mut self) {
        self.pool.release(self.pages.len());
        self.pages.clear();
        self.len = 0;
    }

    /// Heap bytes currently held (granted pages only).
    pub fn bytes(&self) -> usize {
        self.pages.len() * page_bytes(self.pool.page_tokens(), self.kv_dim)
    }
}

impl Drop for KvCache {
    /// Pages go back to the pool when the cache dies — a worker's
    /// panic-rebuild drops the old model (and every cache in it) after
    /// the replacement is built, so grants never leak across rebuilds.
    fn drop(&mut self) {
        self.pool.release(self.pages.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(4, 3);
        c.append(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]).unwrap();
        c.append(&[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.key(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.value(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn overflow_is_an_error() {
        let mut c = KvCache::new(1, 2);
        c.append(&[0.0; 2], &[0.0; 2]).unwrap();
        assert!(c.append(&[0.0; 2], &[0.0; 2]).is_err());
    }

    #[test]
    fn wrong_width_is_an_error() {
        let mut c = KvCache::new(2, 2);
        assert!(c.append(&[0.0; 3], &[0.0; 2]).is_err());
    }

    #[test]
    fn reset_reuses_capacity() {
        let mut c = KvCache::new(2, 2);
        c.append(&[1.0; 2], &[1.0; 2]).unwrap();
        c.reset();
        assert!(c.is_empty());
        c.append(&[2.0; 2], &[2.0; 2]).unwrap();
        assert_eq!(c.key(0), &[2.0, 2.0]);
    }

    #[test]
    fn reads_are_identical_across_page_boundaries() {
        // page_tokens 2 → positions 0..6 span 3 pages; every row must
        // read back exactly what was appended, same as the contiguous
        // layout held.
        let pool = Arc::new(KvPool::bounded(2, 3, 1024).unwrap());
        let mut c = KvCache::new_in(8, 3, pool);
        let rows: Vec<[f32; 3]> =
            (0..6).map(|i| [i as f32, i as f32 + 0.5, -(i as f32)]).collect();
        for r in &rows {
            c.append(r, r).unwrap();
        }
        assert_eq!(c.pages_held(), 3);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(c.key(i), &r[..], "key row {i}");
            assert_eq!(c.value(i), &r[..], "value row {i}");
        }
    }

    #[test]
    fn pages_grow_lazily_and_return_on_reset() {
        let pool = Arc::new(KvPool::unbounded(2));
        let mut c = KvCache::new_in(64, 2, Arc::clone(&pool));
        assert_eq!(pool.pages_in_use(), 0, "no eager allocation");
        c.append(&[1.0; 2], &[1.0; 2]).unwrap();
        assert_eq!(pool.pages_in_use(), 1);
        c.append(&[1.0; 2], &[1.0; 2]).unwrap();
        assert_eq!(pool.pages_in_use(), 1, "second position fits the page");
        c.append(&[1.0; 2], &[1.0; 2]).unwrap();
        assert_eq!(pool.pages_in_use(), 2);
        c.reset();
        assert_eq!(pool.pages_in_use(), 0, "retirement returns every page");
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn drop_returns_pages_to_the_pool() {
        let pool = Arc::new(KvPool::unbounded(2));
        {
            let mut c = KvCache::new_in(8, 2, Arc::clone(&pool));
            for _ in 0..5 {
                c.append(&[0.0; 2], &[0.0; 2]).unwrap();
            }
            assert_eq!(pool.pages_in_use(), 3);
        }
        assert_eq!(pool.pages_in_use(), 0, "drop releases grants");
    }

    #[test]
    fn exhausted_pool_is_a_named_error_not_a_panic() {
        // One-page pool shared by two caches: the second page grant
        // must fail with the KvBudgetExceeded variant and leave the
        // cache consistent (the appended prefix intact).
        let pool = Arc::new(KvPool::bounded(2, 2, page_bytes(2, 2) as u64).unwrap());
        let mut a = KvCache::new_in(8, 2, Arc::clone(&pool));
        let mut b = KvCache::new_in(8, 2, Arc::clone(&pool));
        a.append(&[1.0; 2], &[1.0; 2]).unwrap();
        let err = b.append(&[2.0; 2], &[2.0; 2]).unwrap_err();
        assert!(
            matches!(err, Error::KvBudgetExceeded(_)),
            "expected KvBudgetExceeded, got {err}"
        );
        assert_eq!(b.len(), 0);
        // Freeing the first cache lets the second proceed.
        a.reset();
        b.append(&[2.0; 2], &[2.0; 2]).unwrap();
        assert_eq!(b.key(0), &[2.0, 2.0]);
    }
}
