//! Small dense-vector ops used by the transformer substrate.
//!
//! Weight matmuls go through [`super::bitlinear::BitLinear`]; these are
//! the surrounding elementwise / reduction ops.

/// In-place softmax over a slice (numerically stable).
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// SiLU (swish) activation: `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// `a += b` elementwise.
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x += y;
    }
}

/// `a *= b` elementwise.
pub fn mul_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x *= y;
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

/// Grow `v` to at least `n` elements (zero-filled). The batched decode
/// path sizes its stacked-activation scratch with this: buffers only
/// ever grow, so steady-state steps allocate nothing.
pub(crate) fn ensure_len(v: &mut Vec<f32>, n: usize) {
    if v.len() < n {
        v.resize(n, 0.0);
    }
}

/// Index of the maximum element (greedy decoding).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut xs = vec![1.0f32, 2.0, 3.0];
        softmax(&mut xs);
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_is_stable_for_large_inputs() {
        let mut xs = vec![1000.0f32, 1000.0, 999.0];
        softmax(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn silu_known_values() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(1.0) - 0.7310586).abs() < 1e-5);
        assert!(silu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn vector_ops() {
        let mut a = vec![1.0, 2.0];
        add_assign(&mut a, &[3.0, 4.0]);
        assert_eq!(a, vec![4.0, 6.0]);
        mul_assign(&mut a, &[2.0, 0.5]);
        assert_eq!(a, vec![8.0, 3.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
