//! Timing harness and report formatting (the criterion substitute).

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Result of timing one configuration.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Label (e.g. "rsr++ n=4096").
    pub label: String,
    /// Per-iteration wall times.
    pub summary: Summary,
}

impl Measurement {
    /// Mean milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean() * 1e3
    }

    /// Sample stddev in milliseconds.
    pub fn std_ms(&self) -> f64 {
        self.summary.stddev() * 1e3
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn measure<T>(
    label: impl Into<String>,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut summary = Summary::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed();
        std::hint::black_box(&out);
        summary.push(dt.as_secs_f64());
    }
    Measurement { label: label.into(), summary }
}

/// Adaptive iteration count: aim for ~`budget` total, bounded.
pub fn iters_for(single_run: Duration, budget: Duration, min: usize, max: usize) -> usize {
    if single_run.is_zero() {
        return max;
    }
    let n = (budget.as_secs_f64() / single_run.as_secs_f64()) as usize;
    n.clamp(min, max)
}

/// An aligned text table writer for bench reports (markdown-flavored so
/// EXPERIMENTS.md can embed the output verbatim).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render as a markdown table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths.iter()) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Print to stdout with a title.
    pub fn print(&self, title: &str) {
        println!("\n## {title}\n");
        print!("{}", self.render());
    }
}

/// Write a bench result JSON under `target/bench-results/<name>.json`.
pub fn write_json(name: &str, json: &Json) {
    let dir = std::path::Path::new("target/bench-results");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(format!("{name}.json")), json.to_string());
    }
}

/// Format a mean ± std pair in ms.
pub fn ms(m: &Measurement) -> String {
    if m.mean_ms() < 0.1 {
        format!("{:.1}±{:.1}µs", m.mean_ms() * 1e3, m.std_ms() * 1e3)
    } else {
        format!("{:.2}±{:.2}ms", m.mean_ms(), m.std_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_and_records() {
        let m = measure("t", 1, 5, || (0..100).sum::<u64>());
        assert_eq!(m.summary.len(), 5);
        assert!(m.mean_ms() >= 0.0);
    }

    #[test]
    fn iters_for_clamps() {
        assert_eq!(
            iters_for(Duration::from_millis(100), Duration::from_secs(1), 3, 50),
            10
        );
        assert_eq!(
            iters_for(Duration::from_millis(1), Duration::from_secs(10), 3, 50),
            50
        );
        assert_eq!(
            iters_for(Duration::from_secs(10), Duration::from_secs(1), 3, 50),
            3
        );
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(&["n", "time"]);
        t.row(&["1024".into(), "1.5ms".into()]);
        let r = t.render();
        assert!(r.contains("| n    | time  |"));
        assert!(r.contains("| 1024 | 1.5ms |"));
        assert!(r.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into()]);
    }
}
