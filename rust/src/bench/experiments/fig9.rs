//! Fig 9 / App F.1: finding the optimal k — runtime vs k for each n,
//! for RSR (9a) and RSR++ (9b). The red-dot k* per n should grow
//! with n and match the analytic argmin of Eq 6/7 within ±1–2.

use crate::bench::harness::{write_json, Table};
use crate::bench::workloads::{binary_workload, SEED};
use crate::kernels::index::RsrIndex;
use crate::kernels::optimal_k::{
    empirical_k_sweep, k_max, optimal_k_rsr, optimal_k_rsrpp,
};
use crate::kernels::rsr::RsrPlan;
use crate::kernels::rsrpp::RsrPlusPlusPlan;
use crate::util::json::Json;

fn sizes(full: bool) -> Vec<usize> {
    if full {
        vec![1 << 11, 1 << 12, 1 << 13, 1 << 14]
    } else {
        vec![1 << 11, 1 << 12]
    }
}

/// Run the Fig 9 reproduction (both panels).
pub fn run(full: bool) {
    let reps = if full { 3 } else { 2 };
    let mut json_entries = Vec::new();

    for (algo, analytic) in [
        ("RSR (9a)", optimal_k_rsr as fn(usize) -> usize),
        ("RSR++ (9b)", optimal_k_rsrpp as fn(usize) -> usize),
    ] {
        let mut table = Table::new(&["n", "k sweep (ms by k)", "k* measured", "k* analytic"]);
        for &n in &sizes(full) {
            let (b, v) = binary_workload(n, SEED ^ n as u64);
            let mut out = vec![0.0f32; n];
            // Pre-build one plan per k so the sweep times inference only.
            let is_rsr = algo.starts_with("RSR (");
            let mut plans_rsr: Vec<Option<RsrPlan>> = Vec::new();
            let mut plans_pp: Vec<Option<RsrPlusPlusPlan>> = Vec::new();
            for k in 1..=k_max(n) {
                if is_rsr {
                    plans_rsr.push(Some(
                        RsrPlan::new(RsrIndex::preprocess(&b, k)).unwrap(),
                    ));
                    plans_pp.push(None);
                } else {
                    plans_pp.push(Some(
                        RsrPlusPlusPlan::new(RsrIndex::preprocess(&b, k)).unwrap(),
                    ));
                    plans_rsr.push(None);
                }
            }
            let (k_opt, times) = empirical_k_sweep(n, reps, |k| {
                if is_rsr {
                    plans_rsr[k - 1].as_mut().unwrap().execute(&v, &mut out).unwrap();
                } else {
                    plans_pp[k - 1].as_mut().unwrap().execute(&v, &mut out).unwrap();
                }
            });
            let sweep_str = times
                .iter()
                .map(|(k, ms)| format!("{k}:{ms:.1}"))
                .collect::<Vec<_>>()
                .join(" ");
            table.row(&[
                format!("2^{}", n.trailing_zeros()),
                sweep_str,
                k_opt.to_string(),
                analytic(n).to_string(),
            ]);
            json_entries.push(Json::obj(vec![
                ("algo", Json::str(algo)),
                ("n", Json::num(n as f64)),
                ("k_opt_measured", Json::num(k_opt as f64)),
                ("k_opt_analytic", Json::num(analytic(n) as f64)),
                (
                    "sweep_ms",
                    Json::nums(times.iter().map(|&(_, ms)| ms).collect::<Vec<_>>()),
                ),
            ]));
        }
        table.print(&format!("Fig 9 — optimal k sweep: {algo}"));
    }
    println!(
        "\npaper reference: u-shaped runtime in k; k* grows with n \
         (e.g. k*≈10–14 at n=2^13..2^16 for RSR++)"
    );
    write_json("fig9", &Json::obj(vec![("entries", Json::Arr(json_entries))]));
}
