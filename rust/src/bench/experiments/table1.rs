//! Table 1: average per-BitLinear inference time on GPU for the three
//! 1.58-bit models — simulated with the T4 cost model over each
//! model's actual layer shapes (see DESIGN.md §Substitutions).
//! Paper: Llama3-8B 392→225µs, Falcon3-3B 560→206µs,
//! Falcon3-10B 364→210µs (~2.5×).

use crate::bench::gpusim::{model_latency_us, GpuParams, LayerShape};
use crate::bench::harness::{write_json, Table};
use crate::model::config::ModelConfig;
use crate::util::json::Json;

fn shapes_of(cfg: &ModelConfig) -> Vec<LayerShape> {
    let d = cfg.d_model;
    let kv = cfg.n_kv_heads * cfg.head_dim();
    let ff = cfg.d_ff;
    vec![
        LayerShape { n_in: d, n_out: d },   // wq
        LayerShape { n_in: d, n_out: kv },  // wk
        LayerShape { n_in: d, n_out: kv },  // wv
        LayerShape { n_in: d, n_out: d },   // wo
        LayerShape { n_in: d, n_out: ff },  // gate
        LayerShape { n_in: d, n_out: ff },  // up
        LayerShape { n_in: ff, n_out: d },  // down
    ]
}

/// Paper's Table 1 reference values (µs): (standard, rsr).
const PAPER: [(&str, f64, f64); 3] = [
    ("Llama3-8B-1.58bit", 392.0, 225.0),
    ("Falcon3-3B-1.58bit", 560.0, 206.0),
    ("Falcon3-10B-1.58bit", 364.0, 210.0),
];

/// Run the Table 1 reproduction.
pub fn run(_full: bool) {
    let p = GpuParams::default();
    let configs = [
        ModelConfig::llama3_8b_proxy(),
        ModelConfig::falcon3_3b_proxy(),
        ModelConfig::falcon3_10b_proxy(),
    ];
    let mut table = Table::new(&[
        "model", "Standard (µs, sim)", "RSR (µs, sim)", "speedup (sim)",
        "paper Std (µs)", "paper RSR (µs)",
    ]);
    let mut json_rows = Vec::new();

    for (cfg, (paper_name, paper_std, paper_rsr)) in configs.iter().zip(PAPER) {
        let shapes = shapes_of(cfg);
        let std_us = model_latency_us(&p, &shapes, false);
        let rsr_us = model_latency_us(&p, &shapes, true);
        table.row(&[
            paper_name.to_string(),
            format!("{std_us:.0}"),
            format!("{rsr_us:.0}"),
            format!("{:.2}x", std_us / rsr_us),
            format!("{paper_std:.0}"),
            format!("{paper_rsr:.0}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", Json::str(paper_name)),
            ("standard_us_sim", Json::num(std_us)),
            ("rsr_us_sim", Json::num(rsr_us)),
            ("paper_standard_us", Json::num(paper_std)),
            ("paper_rsr_us", Json::num(paper_rsr)),
        ]));
    }

    table.print("Table 1 — average GPU inference time per BitLinear call (simulated)");
    println!(
        "\npaper reference: ~2.5x on a Tesla T4; the cost model is \
         calibrated to the same device class — who-wins and the \
         rough factor are the reproduction target, not exact µs"
    );
    write_json("table1", &Json::obj(vec![("rows", Json::Arr(json_rows))]));
}
