//! `rsr bench-serve` — the serving-layer perf trajectory: decode
//! throughput as a function of batch size, plus time-to-first-token as
//! a function of prompt length.
//!
//! Sweeps the continuous-batching batch size over a synthetic model
//! (default `B ∈ {1, 4, 8, 16}` on one `n = 1024` layer stack) by
//! driving [`Transformer::forward_batch`] — the exact lockstep step the
//! serving engine's continuous loop executes — with every slot live,
//! and records tokens/sec to `BENCH_serving.json` (CI uploads it as a
//! workflow artifact and the bench-record job commits it on main).
//! This is the number the batched RSR kernels exist for: the shared
//! plan index is read once per **step** instead of once per sequence,
//! so per-step cost grows sublinearly in `B` and decode tokens/sec
//! should rise monotonically from `B = 1` on paper-scale layers.
//!
//! The second sweep (`--prompt-lens`, default `{16, 128, 512}`)
//! measures TTFT for one slot prefilling through
//! [`Transformer::forward_chunk`] at the configured `--prefill-chunk`
//! against the chunk-1 baseline — the same reuse argument applied to
//! the sequence axis, and the latency a prompt-heavy caller feels.
//!
//! Timing is a plain wall-clock loop rather than
//! [`crate::tune::microbench`]: a decode step mutates the KV caches
//! (sequence length grows per call), so the microbench's calibrated
//! inner-repeat would measure ever-longer attention windows and
//! overflow `max_seq_len`. Every batch size decodes the same number of
//! steps from the same prefill depth, so the attention cost is
//! identical across the sweep and the comparison stays honest.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::bench::harness::Table;
use crate::error::Result;
use crate::model::config::ModelConfig;
use crate::model::tensor::argmax;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::runtime::PlanStore;
use crate::util::json::Json;

/// Unmeasured decode steps per batch size (first-touch faults, branch
/// history) before the timed window opens.
const WARMUP_STEPS: usize = 2;

/// Options for one bench-serve run.
#[derive(Debug, Clone)]
pub struct ServeBenchOpts {
    /// Batch sizes to sweep.
    pub batches: Vec<usize>,
    /// Hidden width of the synthetic model (layer matrices are
    /// `d_model × d_model` and `d_model × d_ff` — the paper's `n`).
    pub d_model: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Decoder blocks.
    pub n_layers: usize,
    /// Prompt tokens prefilled per slot before the timed window.
    pub prompt_len: usize,
    /// Timed decode steps per batch size.
    pub steps: usize,
    /// Prompt lengths for the TTFT sweep (empty → skip the sweep).
    pub prompt_lens: Vec<usize>,
    /// Prefill chunk the TTFT sweep runs at (compared against chunk 1).
    pub prefill_chunk: usize,
    /// Where to write the JSON record (`None` → stdout table only).
    pub json_path: Option<PathBuf>,
}

impl Default for ServeBenchOpts {
    fn default() -> Self {
        Self {
            batches: vec![1, 4, 8, 16],
            d_model: 1024,
            d_ff: 2048,
            n_layers: 1,
            prompt_len: 4,
            steps: 32,
            prompt_lens: vec![16, 128, 512],
            prefill_chunk: 8,
            json_path: Some(PathBuf::from("BENCH_serving.json")),
        }
    }
}

fn synthetic_config(opts: &ServeBenchOpts) -> ModelConfig {
    // The context must cover both sweeps: the decode window and the
    // longest TTFT prompt.
    let decode_window = opts.prompt_len + WARMUP_STEPS + opts.steps;
    let longest_prompt = opts.prompt_lens.iter().copied().max().unwrap_or(0);
    ModelConfig {
        name: format!("bench-serve-{}", opts.d_model),
        vocab_size: 270,
        d_model: opts.d_model,
        n_layers: opts.n_layers,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: opts.d_ff,
        max_seq_len: decode_window.max(longest_prompt) + 2,
        rope_theta: 10_000.0,
    }
}

/// Run the sweep; returns the JSON record that was (optionally)
/// written. Preprocessing (Algorithm 1) runs **once** through a shared
/// [`PlanStore`] — every batch size executes the same compiled plans,
/// so the sweep isolates the batching effect.
pub fn run(opts: &ServeBenchOpts) -> Result<Json> {
    let cfg = synthetic_config(opts);
    cfg.validate()?;
    let vocab = cfg.vocab_size;
    println!(
        "bench-serve: {} layer(s) of n={} (d_ff {}), prompt {}, {} timed steps",
        cfg.n_layers, cfg.d_model, cfg.d_ff, opts.prompt_len, opts.steps
    );
    let weights = Arc::new(ModelWeights::generate(cfg.clone(), 0xBE5E)?);
    let store = PlanStore::for_model(Arc::clone(&weights), 0);
    store.preload(&weights.matrix_names())?;

    let mut measured: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &b in &opts.batches {
        let mut model = Transformer::from_plan_store(&weights, &store)?;
        model.ensure_slots(b);
        let slots: Vec<usize> = (0..b).collect();
        // Lockstep prefill: step j feeds a (deterministic, per-slot
        // distinct) prompt token j to every slot; the final prefill
        // step's logits seed greedy decode, mirroring the engine.
        let mut inputs: Vec<u32> =
            (0..b).map(|s| ((s * 7 + 11) % 256) as u32).collect();
        for j in 0..opts.prompt_len.max(1) {
            let logits = model.forward_batch(&inputs, &slots)?;
            let last = j + 1 >= opts.prompt_len.max(1);
            for (row, inp) in inputs.iter_mut().enumerate() {
                *inp = if last {
                    argmax(&logits[row * vocab..(row + 1) * vocab]) as u32
                } else {
                    ((row * 13 + (j + 1) * 31 + 17) % 256) as u32
                };
            }
        }
        let mut decode = |steps: usize, model: &mut Transformer| -> Result<()> {
            for _ in 0..steps {
                let logits = model.forward_batch(&inputs, &slots)?;
                for (row, inp) in inputs.iter_mut().enumerate() {
                    *inp = argmax(&logits[row * vocab..(row + 1) * vocab]) as u32;
                }
            }
            Ok(())
        };
        decode(WARMUP_STEPS, &mut model)?;
        let t0 = Instant::now();
        decode(opts.steps, &mut model)?;
        let dt = t0.elapsed();

        let tokens = (b * opts.steps) as f64;
        let tps = tokens / dt.as_secs_f64().max(1e-12);
        let ms_step = dt.as_secs_f64() * 1e3 / opts.steps as f64;
        measured.push((b, ms_step, ms_step / b as f64, tps));
    }

    // The speedup baseline is the smallest swept batch (B=1 when
    // present), whatever order --batches listed them in.
    let base_tps = measured
        .iter()
        .min_by_key(|&&(b, ..)| b)
        .map_or(1.0, |&(_, _, _, tps)| tps)
        .max(1e-12);
    let base_b = measured.iter().map(|&(b, ..)| b).min().unwrap_or(1);
    let mut table = Table::new(&[
        "batch",
        "steps",
        "ms/step",
        "ms/token",
        "decode tok/s",
        &format!("vs B={base_b}"),
    ]);
    let mut rows = Vec::new();
    for &(b, ms_step, ms_token, tps) in &measured {
        table.row(&[
            b.to_string(),
            opts.steps.to_string(),
            format!("{ms_step:.3}"),
            format!("{ms_token:.3}"),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / base_tps),
        ]);
        rows.push(Json::obj(vec![
            ("batch", Json::num(b as f64)),
            ("ms_per_step", Json::num(ms_step)),
            ("ms_per_token", Json::num(ms_token)),
            ("decode_tokens_per_sec", Json::num(tps)),
            ("speedup_vs_smallest_batch", Json::num(tps / base_tps)),
        ]));
    }

    table.print("bench-serve: continuous batched decode throughput by batch size");

    // TTFT sweep: one slot, chunked prefill at `prefill_chunk` vs the
    // one-token chunk-1 baseline, per prompt length. Chunking must
    // sample the identical first token (bit-identical prefill) — the
    // sweep refuses to report numbers over a wrong kernel.
    let mut ttft_rows = Vec::new();
    if !opts.prompt_lens.is_empty() {
        use super::prefill::chunked_prefill_ttft;
        let chunk = opts.prefill_chunk.max(1);
        let mut ttft_table = Table::new(&[
            "prompt len",
            &format!("ttft ms (chunk={chunk})"),
            "prefill tok/s",
            "ttft ms (chunk=1)",
            "speedup",
        ]);
        let mut model = Transformer::from_plan_store(&weights, &store)?;
        for &plen in &opts.prompt_lens {
            let prompt: Vec<u32> = (0..plen).map(|j| ((j * 7 + 3) % 256) as u32).collect();
            // Unmeasured warmup (scratch growth), then one timed run per
            // path — bench-prefill is the high-resolution instrument;
            // this sweep tracks the serve-shaped trajectory.
            chunked_prefill_ttft(&mut model, &prompt, chunk)?;
            let (dt_chunk, tok_chunk) = chunked_prefill_ttft(&mut model, &prompt, chunk)?;
            let (dt_one, tok_one) = chunked_prefill_ttft(&mut model, &prompt, 1)?;
            if tok_chunk != tok_one {
                return Err(crate::error::Error::Config(format!(
                    "bench-serve: prompt {plen} sampled token {tok_chunk} at chunk \
                     {chunk} but {tok_one} at chunk 1 — chunked prefill must be \
                     bit-identical"
                )));
            }
            let (s_chunk, s_one) =
                (dt_chunk.as_secs_f64().max(1e-12), dt_one.as_secs_f64().max(1e-12));
            let tps = plen as f64 / s_chunk;
            ttft_table.row(&[
                plen.to_string(),
                format!("{:.3}", s_chunk * 1e3),
                format!("{tps:.1}"),
                format!("{:.3}", s_one * 1e3),
                format!("{:.2}x", s_one / s_chunk),
            ]);
            ttft_rows.push(Json::obj(vec![
                ("prompt_len", Json::num(plen as f64)),
                ("prefill_chunk", Json::num(chunk as f64)),
                ("ttft_ms", Json::num(s_chunk * 1e3)),
                ("prefill_tokens_per_sec", Json::num(tps)),
                ("ttft_ms_chunk1", Json::num(s_one * 1e3)),
                ("speedup_vs_chunk1", Json::num(s_one / s_chunk)),
            ]));
        }
        ttft_table
            .print("bench-serve: time-to-first-token by prompt length (chunked prefill)");
    }

    let record = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("d_ff", Json::num(cfg.d_ff as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("prompt_len", Json::num(opts.prompt_len as f64)),
        ("steps", Json::num(opts.steps as f64)),
        ("prefill_chunk", Json::num(opts.prefill_chunk.max(1) as f64)),
        ("batches", Json::Arr(rows)),
        ("ttft", Json::Arr(ttft_rows)),
    ]);
    if let Some(path) = &opts.json_path {
        match std::fs::write(path, record.to_string()) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_records_every_batch() {
        let opts = ServeBenchOpts {
            batches: vec![1, 2],
            d_model: 64,
            d_ff: 96,
            n_layers: 1,
            prompt_len: 2,
            steps: 2,
            prompt_lens: vec![5, 9],
            prefill_chunk: 4,
            json_path: None,
        };
        let record = run(&opts).unwrap();
        let rows = record.get("batches").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("batch").unwrap().as_f64(), Some(2.0));
        assert!(rows[0].get("decode_tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(rows[1].get("ms_per_token").unwrap().as_f64().unwrap() > 0.0);
        // TTFT sweep: one row per prompt length, chunk recorded.
        let ttft = record.get("ttft").unwrap().as_arr().unwrap();
        assert_eq!(ttft.len(), 2);
        assert_eq!(ttft[0].get("prompt_len").unwrap().as_f64(), Some(5.0));
        assert_eq!(ttft[1].get("prefill_chunk").unwrap().as_f64(), Some(4.0));
        assert!(ttft[0].get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(ttft[1].get("speedup_vs_chunk1").unwrap().as_f64().unwrap() > 0.0);
    }
}
