//! `rsr bench-serve` — the serving-layer perf trajectory: decode
//! throughput as a function of batch size, plus time-to-first-token as
//! a function of prompt length.
//!
//! Sweeps the continuous-batching batch size over a synthetic model
//! (default `B ∈ {1, 4, 8, 16}` on one `n = 1024` layer stack) by
//! driving [`Transformer::forward_batch`] — the exact lockstep step the
//! serving engine's continuous loop executes — with every slot live,
//! and records tokens/sec to `BENCH_serving.json` (CI uploads it as a
//! workflow artifact and the bench-record job commits it on main).
//! This is the number the batched RSR kernels exist for: the shared
//! plan index is read once per **step** instead of once per sequence,
//! so per-step cost grows sublinearly in `B` and decode tokens/sec
//! should rise monotonically from `B = 1` on paper-scale layers.
//!
//! The second sweep (`--prompt-lens`, default `{16, 128, 512}`)
//! measures TTFT for one slot prefilling through
//! [`Transformer::forward_chunk`] at the configured `--prefill-chunk`
//! against the chunk-1 baseline — the same reuse argument applied to
//! the sequence axis, and the latency a prompt-heavy caller feels.
//!
//! Timing is a plain wall-clock loop rather than
//! [`crate::tune::microbench`]: a decode step mutates the KV caches
//! (sequence length grows per call), so the microbench's calibrated
//! inner-repeat would measure ever-longer attention windows and
//! overflow `max_seq_len`. Every batch size decodes the same number of
//! steps from the same prefill depth, so the attention cost is
//! identical across the sweep and the comparison stays honest.
//!
//! The third sweep is an **open-loop overload run** (`--overload-requests`,
//! `0` skips it): Poisson arrivals at `--overload-rps` are fired at a
//! deliberately under-provisioned engine (1 worker, queue capacity 2)
//! with a per-request deadline, and the run records shed rate,
//! deadline-miss rate and end-to-end p50/p99 — the request-lifecycle
//! trajectory (does backpressure shed instead of queueing unboundedly,
//! does every admitted request reach exactly one terminal outcome).
//! Unlike the closed-loop sweeps above, arrivals do not wait for
//! service: this is the load shape a shared endpoint actually sees.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::bench::harness::Table;
use crate::error::{Error, Result};
use crate::kernels::Backend;
use crate::model::config::ModelConfig;
use crate::model::tensor::argmax;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::runtime::PlanStore;
use crate::serving::engine::{EngineConfig, InferenceEngine};
use crate::serving::request::Request;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Unmeasured decode steps per batch size (first-touch faults, branch
/// history) before the timed window opens.
const WARMUP_STEPS: usize = 2;

/// Options for one bench-serve run.
#[derive(Debug, Clone)]
pub struct ServeBenchOpts {
    /// Batch sizes to sweep.
    pub batches: Vec<usize>,
    /// Hidden width of the synthetic model (layer matrices are
    /// `d_model × d_model` and `d_model × d_ff` — the paper's `n`).
    pub d_model: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Decoder blocks.
    pub n_layers: usize,
    /// Prompt tokens prefilled per slot before the timed window.
    pub prompt_len: usize,
    /// Timed decode steps per batch size.
    pub steps: usize,
    /// Prompt lengths for the TTFT sweep (empty → skip the sweep).
    pub prompt_lens: Vec<usize>,
    /// Prefill chunk the TTFT sweep runs at (compared against chunk 1).
    pub prefill_chunk: usize,
    /// Requests fired in the open-loop overload run (`0` → skip it).
    pub overload_requests: usize,
    /// Mean Poisson arrival rate of the overload run, requests/sec.
    pub overload_rps: f64,
    /// Per-request deadline in the overload run, milliseconds.
    pub overload_deadline_ms: u64,
    /// Where to write the JSON record (`None` → stdout table only).
    pub json_path: Option<PathBuf>,
}

impl Default for ServeBenchOpts {
    fn default() -> Self {
        Self {
            batches: vec![1, 4, 8, 16],
            d_model: 1024,
            d_ff: 2048,
            n_layers: 1,
            prompt_len: 4,
            steps: 32,
            prompt_lens: vec![16, 128, 512],
            prefill_chunk: 8,
            overload_requests: 48,
            overload_rps: 2000.0,
            overload_deadline_ms: 60,
            json_path: Some(PathBuf::from("BENCH_serving.json")),
        }
    }
}

fn synthetic_config(opts: &ServeBenchOpts) -> ModelConfig {
    // The context must cover both sweeps: the decode window and the
    // longest TTFT prompt.
    let decode_window = opts.prompt_len + WARMUP_STEPS + opts.steps;
    let longest_prompt = opts.prompt_lens.iter().copied().max().unwrap_or(0);
    ModelConfig {
        name: format!("bench-serve-{}", opts.d_model),
        vocab_size: 270,
        d_model: opts.d_model,
        n_layers: opts.n_layers,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: opts.d_ff,
        max_seq_len: decode_window.max(longest_prompt) + 2,
        rope_theta: 10_000.0,
    }
}

/// Run the sweep; returns the JSON record that was (optionally)
/// written. Preprocessing (Algorithm 1) runs **once** through a shared
/// [`PlanStore`] — every batch size executes the same compiled plans,
/// so the sweep isolates the batching effect.
pub fn run(opts: &ServeBenchOpts) -> Result<Json> {
    let cfg = synthetic_config(opts);
    cfg.validate()?;
    let vocab = cfg.vocab_size;
    println!(
        "bench-serve: {} layer(s) of n={} (d_ff {}), prompt {}, {} timed steps",
        cfg.n_layers, cfg.d_model, cfg.d_ff, opts.prompt_len, opts.steps
    );
    let weights = Arc::new(ModelWeights::generate(cfg.clone(), 0xBE5E)?);
    let store = PlanStore::for_model(Arc::clone(&weights), 0);
    store.preload(&weights.matrix_names())?;

    let mut measured: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &b in &opts.batches {
        let mut model = Transformer::from_plan_store(&weights, &store)?;
        model.ensure_slots(b);
        let slots: Vec<usize> = (0..b).collect();
        // Lockstep prefill: step j feeds a (deterministic, per-slot
        // distinct) prompt token j to every slot; the final prefill
        // step's logits seed greedy decode, mirroring the engine.
        let mut inputs: Vec<u32> =
            (0..b).map(|s| ((s * 7 + 11) % 256) as u32).collect();
        for j in 0..opts.prompt_len.max(1) {
            let logits = model.forward_batch(&inputs, &slots)?;
            let last = j + 1 >= opts.prompt_len.max(1);
            for (row, inp) in inputs.iter_mut().enumerate() {
                *inp = if last {
                    argmax(&logits[row * vocab..(row + 1) * vocab]) as u32
                } else {
                    ((row * 13 + (j + 1) * 31 + 17) % 256) as u32
                };
            }
        }
        let mut decode = |steps: usize, model: &mut Transformer| -> Result<()> {
            for _ in 0..steps {
                let logits = model.forward_batch(&inputs, &slots)?;
                for (row, inp) in inputs.iter_mut().enumerate() {
                    *inp = argmax(&logits[row * vocab..(row + 1) * vocab]) as u32;
                }
            }
            Ok(())
        };
        decode(WARMUP_STEPS, &mut model)?;
        let t0 = Instant::now();
        decode(opts.steps, &mut model)?;
        let dt = t0.elapsed();

        let tokens = (b * opts.steps) as f64;
        let tps = tokens / dt.as_secs_f64().max(1e-12);
        let ms_step = dt.as_secs_f64() * 1e3 / opts.steps as f64;
        measured.push((b, ms_step, ms_step / b as f64, tps));
    }

    // The speedup baseline is the smallest swept batch (B=1 when
    // present), whatever order --batches listed them in.
    let base_tps = measured
        .iter()
        .min_by_key(|&&(b, ..)| b)
        .map_or(1.0, |&(_, _, _, tps)| tps)
        .max(1e-12);
    let base_b = measured.iter().map(|&(b, ..)| b).min().unwrap_or(1);
    let mut table = Table::new(&[
        "batch",
        "steps",
        "ms/step",
        "ms/token",
        "decode tok/s",
        &format!("vs B={base_b}"),
    ]);
    let mut rows = Vec::new();
    for &(b, ms_step, ms_token, tps) in &measured {
        table.row(&[
            b.to_string(),
            opts.steps.to_string(),
            format!("{ms_step:.3}"),
            format!("{ms_token:.3}"),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / base_tps),
        ]);
        rows.push(Json::obj(vec![
            ("batch", Json::num(b as f64)),
            ("ms_per_step", Json::num(ms_step)),
            ("ms_per_token", Json::num(ms_token)),
            ("decode_tokens_per_sec", Json::num(tps)),
            ("speedup_vs_smallest_batch", Json::num(tps / base_tps)),
        ]));
    }

    table.print("bench-serve: continuous batched decode throughput by batch size");

    // TTFT sweep: one slot, chunked prefill at `prefill_chunk` vs the
    // one-token chunk-1 baseline, per prompt length. Chunking must
    // sample the identical first token (bit-identical prefill) — the
    // sweep refuses to report numbers over a wrong kernel.
    let mut ttft_rows = Vec::new();
    if !opts.prompt_lens.is_empty() {
        use super::prefill::chunked_prefill_ttft;
        let chunk = opts.prefill_chunk.max(1);
        let mut ttft_table = Table::new(&[
            "prompt len",
            &format!("ttft ms (chunk={chunk})"),
            "prefill tok/s",
            "ttft ms (chunk=1)",
            "speedup",
        ]);
        let mut model = Transformer::from_plan_store(&weights, &store)?;
        for &plen in &opts.prompt_lens {
            let prompt: Vec<u32> = (0..plen).map(|j| ((j * 7 + 3) % 256) as u32).collect();
            // Unmeasured warmup (scratch growth), then one timed run per
            // path — bench-prefill is the high-resolution instrument;
            // this sweep tracks the serve-shaped trajectory.
            chunked_prefill_ttft(&mut model, &prompt, chunk)?;
            let (dt_chunk, tok_chunk) = chunked_prefill_ttft(&mut model, &prompt, chunk)?;
            let (dt_one, tok_one) = chunked_prefill_ttft(&mut model, &prompt, 1)?;
            if tok_chunk != tok_one {
                return Err(crate::error::Error::Config(format!(
                    "bench-serve: prompt {plen} sampled token {tok_chunk} at chunk \
                     {chunk} but {tok_one} at chunk 1 — chunked prefill must be \
                     bit-identical"
                )));
            }
            let (s_chunk, s_one) =
                (dt_chunk.as_secs_f64().max(1e-12), dt_one.as_secs_f64().max(1e-12));
            let tps = plen as f64 / s_chunk;
            ttft_table.row(&[
                plen.to_string(),
                format!("{:.3}", s_chunk * 1e3),
                format!("{tps:.1}"),
                format!("{:.3}", s_one * 1e3),
                format!("{:.2}x", s_one / s_chunk),
            ]);
            ttft_rows.push(Json::obj(vec![
                ("prompt_len", Json::num(plen as f64)),
                ("prefill_chunk", Json::num(chunk as f64)),
                ("ttft_ms", Json::num(s_chunk * 1e3)),
                ("prefill_tokens_per_sec", Json::num(tps)),
                ("ttft_ms_chunk1", Json::num(s_one * 1e3)),
                ("speedup_vs_chunk1", Json::num(s_one / s_chunk)),
            ]));
        }
        ttft_table
            .print("bench-serve: time-to-first-token by prompt length (chunked prefill)");
    }

    // Open-loop overload run (module doc §overload): its engine is
    // separate from the sweep model above — deliberately
    // under-provisioned so Poisson bursts overflow the bounded queue
    // and per-request deadlines bite. The streaming and fairness runs
    // ride the same gate: all three instrument serving dynamics rather
    // than kernel throughput.
    let (overload, streaming, fairness) = if opts.overload_requests > 0 {
        (overload_run(opts)?, streaming_run(opts)?, fairness_run(opts)?)
    } else {
        (Json::Null, Json::Null, Json::Null)
    };

    let record = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("d_ff", Json::num(cfg.d_ff as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("prompt_len", Json::num(opts.prompt_len as f64)),
        ("steps", Json::num(opts.steps as f64)),
        ("prefill_chunk", Json::num(opts.prefill_chunk.max(1) as f64)),
        ("batches", Json::Arr(rows)),
        ("ttft", Json::Arr(ttft_rows)),
        ("overload", overload),
        ("streaming", streaming),
        ("fairness", fairness),
    ]);
    if let Some(path) = &opts.json_path {
        match std::fs::write(path, record.to_string()) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }
    Ok(record)
}

/// Tokens of prompt fed to every overload request.
const OVERLOAD_PROMPT_LEN: usize = 8;
/// Decode budget per overload request (deadline usually retires the
/// request first — the budget bounds the run, the deadline shapes it).
const OVERLOAD_MAX_NEW: usize = 32;
/// Request queue capacity of the overload engine: small on purpose, so
/// backpressure (not memory) absorbs the arrival bursts.
const OVERLOAD_QUEUE_CAP: usize = 2;

/// Classify one terminal response into the overload tallies.
fn tally(
    resp: &crate::serving::request::Response,
    sent_at: &HashMap<u64, Instant>,
    ok: &mut usize,
    missed: &mut usize,
    failed: &mut usize,
    latencies_ms: &mut Vec<f64>,
) {
    let lat = sent_at
        .get(&resp.id)
        .map(|t| t.elapsed().as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    if resp.error.is_none() {
        *ok += 1;
        latencies_ms.push(lat);
    } else if resp.code == Some("deadline_exceeded") {
        *missed += 1;
    } else {
        *failed += 1;
    }
}

/// Nearest-rank percentile over an already-sorted sample (ms).
fn percentile_ms(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p / 100.0).round() as usize;
    sorted[idx]
}

/// Fire `overload_requests` requests with Poisson inter-arrivals at an
/// engine sized to saturate (1 worker, 2 slots, queue capacity
/// [`OVERLOAD_QUEUE_CAP`]) and account every terminal outcome:
/// admitted/shed at submit, ok/deadline-missed/failed/hung at drain.
/// The invariant this instruments is exactly-one-terminal-outcome —
/// `hung > 0` in the record means an admitted request never got its
/// response, which the lifecycle CI job treats as a failure.
fn overload_run(opts: &ServeBenchOpts) -> Result<Json> {
    let n = opts.overload_requests;
    let lambda = opts.overload_rps.max(1.0);
    let deadline = Duration::from_millis(opts.overload_deadline_ms.max(1));
    let cfg = ModelConfig {
        name: format!("bench-serve-overload-{}", opts.d_model),
        vocab_size: 270,
        d_model: opts.d_model,
        n_layers: opts.n_layers,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: opts.d_ff,
        max_seq_len: OVERLOAD_PROMPT_LEN + OVERLOAD_MAX_NEW + 4,
        rope_theta: 10_000.0,
    };
    cfg.validate()?;
    println!(
        "bench-serve overload: {n} requests at ~{lambda:.0}/s, deadline {}ms, \
         queue cap {OVERLOAD_QUEUE_CAP}",
        opts.overload_deadline_ms
    );
    // Standard backend: no preprocessing startup, and a service rate
    // low enough that the arrival process actually overloads it.
    let weights = Arc::new(ModelWeights::generate(cfg, 0x0A11)?);
    let engine = InferenceEngine::start(
        weights,
        EngineConfig {
            workers: 1,
            queue_capacity: OVERLOAD_QUEUE_CAP,
            batch: crate::serving::batcher::BatchPolicy {
                max_slots: 2,
                prefill_chunk: 4,
                ..Default::default()
            },
            backend: Backend::Standard,
            ..Default::default()
        },
    )?;

    let mut rng = Rng::new(0x0A11_0AD5);
    let mut sent_at: HashMap<u64, Instant> = HashMap::new();
    let (mut admitted, mut shed_full, mut shed_dead) = (0usize, 0usize, 0usize);
    let (mut ok, mut missed, mut failed) = (0usize, 0usize, 0usize);
    let mut latencies_ms: Vec<f64> = Vec::new();
    let mut pending = 0usize;
    for i in 0..n {
        // Exponential inter-arrival via inverse transform; 1 - u keeps
        // the log argument strictly positive.
        let gap = -(1.0 - rng.next_f64()).ln() / lambda;
        std::thread::sleep(Duration::from_secs_f64(gap));
        let prompt: Vec<u32> = (0..OVERLOAD_PROMPT_LEN)
            .map(|j| ((i * 13 + j * 7 + 3) % 256) as u32)
            .collect();
        let id = i as u64;
        sent_at.insert(id, Instant::now());
        let req = Request::new(id, prompt, OVERLOAD_MAX_NEW).with_deadline(deadline);
        match engine.submit(req) {
            Ok(()) => {
                admitted += 1;
                pending += 1;
            }
            Err(Error::DeadlineExceeded(_)) => shed_dead += 1,
            Err(_) => shed_full += 1,
        }
        // Open loop: absorb whatever has finished without ever waiting.
        while let Some(resp) = engine.recv_timeout(Duration::ZERO) {
            pending -= 1;
            tally(&resp, &sent_at, &mut ok, &mut missed, &mut failed, &mut latencies_ms);
        }
    }
    // Drain: every admitted request owes exactly one terminal response.
    // The bound is a hang detector, not a tuning knob.
    let drain_deadline = Instant::now() + Duration::from_secs(60);
    while pending > 0 && Instant::now() < drain_deadline {
        if let Some(resp) = engine.recv_timeout(Duration::from_millis(200)) {
            pending -= 1;
            tally(&resp, &sent_at, &mut ok, &mut missed, &mut failed, &mut latencies_ms);
        }
    }
    let hung = pending;
    // The engine's own accounting of the same run — embedded in the
    // record so the perf trajectory carries the serving counters and
    // latency histograms alongside the bench-side tallies.
    let engine_snapshot = engine.snapshot();
    // KV-pool trajectory of the run: peak page occupancy is the
    // memory high-water mark the budget planner sizes against, and
    // evictions stay 0 here (the overload engine is unbudgeted) —
    // recorded so a regression that starts evicting shows up in the
    // committed record.
    let kv_evictions = engine.kv_pool().evictions();
    let kv_pages_peak = engine.kv_pool().peak_pages_in_use();
    engine.shutdown();
    if hung > 0 {
        eprintln!(
            "warning: {hung} admitted request(s) never reached a terminal \
             outcome — lifecycle invariant violated"
        );
    }

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let shed = shed_full + shed_dead;
    let shed_rate = shed as f64 / n.max(1) as f64;
    let miss_rate = (missed + shed_dead) as f64 / n.max(1) as f64;
    let (p50, p99) = (percentile_ms(&latencies_ms, 50.0), percentile_ms(&latencies_ms, 99.0));
    let mut table = Table::new(&[
        "requests", "admitted", "shed", "shed %", "miss %", "ok", "p50 ms", "p99 ms", "hung",
    ]);
    table.row(&[
        n.to_string(),
        admitted.to_string(),
        shed.to_string(),
        format!("{:.1}", shed_rate * 100.0),
        format!("{:.1}", miss_rate * 100.0),
        ok.to_string(),
        format!("{p50:.2}"),
        format!("{p99:.2}"),
        hung.to_string(),
    ]);
    table.print("bench-serve: open-loop overload (Poisson arrivals, bounded queue)");

    Ok(Json::obj(vec![
        ("requests", Json::num(n as f64)),
        ("rps", Json::num(lambda)),
        ("deadline_ms", Json::num(opts.overload_deadline_ms as f64)),
        ("queue_capacity", Json::num(OVERLOAD_QUEUE_CAP as f64)),
        ("admitted", Json::num(admitted as f64)),
        ("shed_queue_full", Json::num(shed_full as f64)),
        ("shed_deadline", Json::num(shed_dead as f64)),
        ("shed_rate", Json::num(shed_rate)),
        ("deadline_missed", Json::num(missed as f64)),
        ("deadline_miss_rate", Json::num(miss_rate)),
        ("completed_ok", Json::num(ok as f64)),
        ("failed", Json::num(failed as f64)),
        ("hung", Json::num(hung as f64)),
        ("p50_ms", Json::num(p50)),
        ("p99_ms", Json::num(p99)),
        ("kv_evictions_total", Json::num(kv_evictions as f64)),
        ("kv_pages_in_use_peak", Json::num(kv_pages_peak as f64)),
        ("engine", engine_snapshot),
    ]))
}

/// Requests per arm of the streaming TTFT comparison.
const STREAMING_REQS: usize = 6;
/// Prompt/decode shape of the streaming comparison: enough decode
/// steps that first-frame and last-frame latency visibly diverge.
const STREAMING_PROMPT_LEN: usize = 8;
const STREAMING_MAX_NEW: usize = 16;

/// Streaming TTFT: the time-to-first-frame a `"stream": true` caller
/// sees vs the single-line latency the same request costs a
/// non-streaming caller. Both arms run the identical engine and
/// request shape; the gap is the latency the token-frame wire path
/// removes from "first visible output".
fn streaming_run(opts: &ServeBenchOpts) -> Result<Json> {
    use crate::serving::request::Frame;
    let cfg = ModelConfig {
        name: format!("bench-serve-streaming-{}", opts.d_model),
        vocab_size: 270,
        d_model: opts.d_model,
        n_layers: opts.n_layers,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: opts.d_ff,
        max_seq_len: STREAMING_PROMPT_LEN + STREAMING_MAX_NEW + 4,
        rope_theta: 10_000.0,
    };
    cfg.validate()?;
    let weights = Arc::new(ModelWeights::generate(cfg, 0x57E0)?);
    let engine = InferenceEngine::start(
        weights,
        EngineConfig { workers: 1, backend: Backend::Standard, ..Default::default() },
    )?;
    let prompt = |i: usize| -> Vec<u32> {
        (0..STREAMING_PROMPT_LEN).map(|j| ((i * 13 + j * 7 + 3) % 256) as u32).collect()
    };
    let wait = Duration::from_secs(30);
    let mut first_ms: Vec<f64> = Vec::new();
    let mut stream_total_ms: Vec<f64> = Vec::new();
    let mut full_ms: Vec<f64> = Vec::new();
    for i in 0..STREAMING_REQS {
        // Streamed arm: the first token frame is the first visible
        // output; the done frame closes the request.
        let t0 = Instant::now();
        engine.submit(
            Request::new(i as u64, prompt(i), STREAMING_MAX_NEW).with_stream(true),
        )?;
        let mut first: Option<Duration> = None;
        loop {
            match engine.recv_frame_timeout(wait) {
                Some(Frame::Token { .. }) => {
                    first.get_or_insert_with(|| t0.elapsed());
                }
                Some(Frame::Done(_)) => break,
                None => {
                    return Err(Error::Serving(
                        "streaming bench: engine produced no frame within 30s".into(),
                    ))
                }
            }
        }
        let total = t0.elapsed();
        first_ms.push(first.unwrap_or(total).as_secs_f64() * 1e3);
        stream_total_ms.push(total.as_secs_f64() * 1e3);
        // Non-streaming twin: the single terminal line is both the
        // first and the last byte the caller sees.
        let t0 = Instant::now();
        engine.submit(Request::new(
            (STREAMING_REQS + i) as u64,
            prompt(i),
            STREAMING_MAX_NEW,
        ))?;
        if engine.recv_timeout(wait).is_none() {
            return Err(Error::Serving(
                "streaming bench: engine produced no response within 30s".into(),
            ));
        }
        full_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    engine.shutdown();
    for v in [&mut first_ms, &mut stream_total_ms, &mut full_ms] {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    let (ttfb_p50, stream_p50, full_p50) = (
        percentile_ms(&first_ms, 50.0),
        percentile_ms(&stream_total_ms, 50.0),
        percentile_ms(&full_ms, 50.0),
    );
    let mut table =
        Table::new(&["requests", "ttfb p50 ms", "stream total p50 ms", "non-stream p50 ms", "ttfb speedup"]);
    table.row(&[
        STREAMING_REQS.to_string(),
        format!("{ttfb_p50:.2}"),
        format!("{stream_p50:.2}"),
        format!("{full_p50:.2}"),
        format!("{:.2}x", full_p50 / ttfb_p50.max(1e-9)),
    ]);
    table.print("bench-serve: streaming time-to-first-frame vs non-streaming");
    Ok(Json::obj(vec![
        ("requests_per_arm", Json::num(STREAMING_REQS as f64)),
        ("max_new", Json::num(STREAMING_MAX_NEW as f64)),
        ("ttfb_stream_p50_ms", Json::num(ttfb_p50)),
        ("total_stream_p50_ms", Json::num(stream_p50)),
        ("total_non_stream_p50_ms", Json::num(full_p50)),
        ("ttfb_speedup_vs_non_stream", Json::num(full_p50 / ttfb_p50.max(1e-9))),
    ]))
}

/// Fairness-run shape: one aggressive client floods the queue before
/// three polite clients submit one burst each.
const FAIRNESS_AGGRESSIVE_REQS: usize = 12;
const FAIRNESS_POLITE_CLIENTS: usize = 3;
const FAIRNESS_POLITE_REQS: usize = 3;
const FAIRNESS_MAX_NEW: usize = 4;

/// Fairness under overload: client 0 floods the fair-admission queue,
/// then three polite clients each submit a small burst. With one
/// strictly sequential worker, completion order equals pickup order,
/// so each client's mean completion index measures how long the queue
/// made it wait. Weighted round-robin keeps the polite means low even
/// though the aggressive client submitted first; a FIFO would push
/// them all behind the flood.
fn fairness_run(opts: &ServeBenchOpts) -> Result<Json> {
    let total =
        FAIRNESS_AGGRESSIVE_REQS + FAIRNESS_POLITE_CLIENTS * FAIRNESS_POLITE_REQS;
    let cfg = ModelConfig {
        name: format!("bench-serve-fairness-{}", opts.d_model),
        vocab_size: 270,
        d_model: opts.d_model,
        n_layers: opts.n_layers,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: opts.d_ff,
        max_seq_len: 8 + FAIRNESS_MAX_NEW + 4,
        rope_theta: 10_000.0,
    };
    cfg.validate()?;
    let weights = Arc::new(ModelWeights::generate(cfg, 0xFA12)?);
    let engine = InferenceEngine::start(
        weights,
        EngineConfig {
            workers: 1,
            queue_capacity: total + 4,
            batch: crate::serving::batcher::BatchPolicy {
                max_slots: 1,
                ..Default::default()
            },
            backend: Backend::Standard,
            ..Default::default()
        },
    )?;
    let prompt = |i: usize| -> Vec<u32> {
        (0..6).map(|j| ((i * 13 + j * 7 + 3) % 256) as u32).collect()
    };
    // id → client lane. Client 0 floods first; 1..=3 submit after.
    let mut lane_of: HashMap<u64, usize> = HashMap::new();
    let mut next_id = 0u64;
    let submit = |engine: &InferenceEngine,
                  lane_of: &mut HashMap<u64, usize>,
                  next_id: &mut u64,
                  client: usize|
     -> Result<()> {
        let id = *next_id;
        *next_id += 1;
        lane_of.insert(id, client);
        engine.submit(
            Request::new(id, prompt(id as usize), FAIRNESS_MAX_NEW)
                .with_client(client as u64),
        )
    };
    for _ in 0..FAIRNESS_AGGRESSIVE_REQS {
        submit(&engine, &mut lane_of, &mut next_id, 0)?;
    }
    for client in 1..=FAIRNESS_POLITE_CLIENTS {
        for _ in 0..FAIRNESS_POLITE_REQS {
            submit(&engine, &mut lane_of, &mut next_id, client)?;
        }
    }
    // Drain every terminal, recording completion order per client.
    let mut index_sums = vec![0.0f64; FAIRNESS_POLITE_CLIENTS + 1];
    let mut counts = vec![0usize; FAIRNESS_POLITE_CLIENTS + 1];
    for position in 0..total {
        let Some(resp) = engine.recv_timeout(Duration::from_secs(30)) else {
            return Err(Error::Serving(
                "fairness bench: engine produced no response within 30s".into(),
            ));
        };
        let lane = lane_of[&resp.id];
        index_sums[lane] += position as f64;
        counts[lane] += 1;
    }
    let conserved =
        matches!(engine.snapshot().get("conserved"), Some(Json::Bool(true)));
    engine.shutdown();
    let means: Vec<f64> = index_sums
        .iter()
        .zip(&counts)
        .map(|(s, &c)| s / (c.max(1) as f64))
        .collect();
    // Spread across the POLITE clients only: fairness means they wait
    // about equally; the aggressive client's mean is reported but is
    // expected (and correct) to be high.
    let polite = &means[1..];
    let spread = polite.iter().fold(0.0f64, |m, &x| m.max(x))
        - polite.iter().fold(f64::MAX, |m, &x| m.min(x));
    let mut table = Table::new(&["client", "requests", "completed", "mean completion idx"]);
    let mut per_client = Vec::new();
    for (client, mean) in means.iter().enumerate() {
        let submitted = if client == 0 {
            FAIRNESS_AGGRESSIVE_REQS
        } else {
            FAIRNESS_POLITE_REQS
        };
        table.row(&[
            format!("{client}{}", if client == 0 { " (aggressive)" } else { "" }),
            submitted.to_string(),
            counts[client].to_string(),
            format!("{mean:.1}"),
        ]);
        per_client.push(Json::obj(vec![
            ("client", Json::num(client as f64)),
            ("requests", Json::num(submitted as f64)),
            ("completed", Json::num(counts[client] as f64)),
            ("mean_completion_index", Json::num(*mean)),
        ]));
    }
    table.print("bench-serve: per-client completion under one aggressive client");
    Ok(Json::obj(vec![
        ("aggressive_client", Json::num(0.0)),
        ("total_requests", Json::num(total as f64)),
        ("per_client", Json::Arr(per_client)),
        ("polite_mean_index_spread", Json::num(spread)),
        ("conserved", Json::Bool(conserved)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_records_every_batch() {
        let opts = ServeBenchOpts {
            batches: vec![1, 2],
            d_model: 64,
            d_ff: 96,
            n_layers: 1,
            prompt_len: 2,
            steps: 2,
            prompt_lens: vec![5, 9],
            prefill_chunk: 4,
            overload_requests: 0,
            overload_rps: 1000.0,
            overload_deadline_ms: 50,
            json_path: None,
        };
        let record = run(&opts).unwrap();
        let rows = record.get("batches").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("batch").unwrap().as_f64(), Some(2.0));
        assert!(rows[0].get("decode_tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(rows[1].get("ms_per_token").unwrap().as_f64().unwrap() > 0.0);
        // TTFT sweep: one row per prompt length, chunk recorded.
        let ttft = record.get("ttft").unwrap().as_arr().unwrap();
        assert_eq!(ttft.len(), 2);
        assert_eq!(ttft[0].get("prompt_len").unwrap().as_f64(), Some(5.0));
        assert_eq!(ttft[1].get("prefill_chunk").unwrap().as_f64(), Some(4.0));
        assert!(ttft[0].get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(ttft[1].get("speedup_vs_chunk1").unwrap().as_f64().unwrap() > 0.0);
        // overload_requests: 0 skips the serving-dynamics runs.
        assert!(matches!(record.get("overload"), Some(Json::Null)));
        assert!(matches!(record.get("streaming"), Some(Json::Null)));
        assert!(matches!(record.get("fairness"), Some(Json::Null)));
    }

    #[test]
    fn streaming_and_fairness_runs_record_their_fields() {
        let opts = ServeBenchOpts {
            d_model: 64,
            d_ff: 96,
            n_layers: 1,
            ..Default::default()
        };
        let s = streaming_run(&opts).unwrap();
        assert!(s.get("ttfb_stream_p50_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(s.get("total_stream_p50_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(s.get("total_non_stream_p50_ms").unwrap().as_f64().unwrap() > 0.0);
        let fr = fairness_run(&opts).unwrap();
        assert!(matches!(fr.get("conserved"), Some(Json::Bool(true))));
        let per = fr.get("per_client").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 1 + FAIRNESS_POLITE_CLIENTS);
        // Every submitted request completed (nothing hung or vanished).
        let done: f64 = per
            .iter()
            .map(|c| c.get("completed").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(done, fr.get("total_requests").unwrap().as_f64().unwrap());
    }

    #[test]
    fn overload_run_accounts_for_every_request() {
        // Tiny model, fast arrivals, short deadline: whatever mix of
        // shed/missed/ok this machine produces, the accounting must
        // conserve requests and nothing may hang.
        let opts = ServeBenchOpts {
            d_model: 64,
            d_ff: 96,
            n_layers: 1,
            overload_requests: 8,
            overload_rps: 5000.0,
            overload_deadline_ms: 40,
            ..Default::default()
        };
        let rec = overload_run(&opts).unwrap();
        let g = |k: &str| rec.get(k).unwrap().as_f64().unwrap();
        assert_eq!(g("requests"), 8.0);
        assert_eq!(
            g("hung"),
            0.0,
            "every admitted request must reach exactly one terminal outcome"
        );
        let admitted = g("admitted");
        assert_eq!(admitted + g("shed_queue_full") + g("shed_deadline"), 8.0);
        assert_eq!(g("completed_ok") + g("deadline_missed") + g("failed"), admitted);
        assert!((0.0..=1.0).contains(&g("shed_rate")));
        assert!((0.0..=1.0).contains(&g("deadline_miss_rate")));
        // The embedded engine snapshot carries the same run, conserved.
        let engine = rec.get("engine").expect("engine snapshot embedded");
        assert!(matches!(engine.get("conserved"), Some(Json::Bool(true))));
        assert_eq!(engine.get("inflight").unwrap().as_f64(), Some(0.0));
        // KV trajectory fields: the first request is always admitted
        // (queue starts empty), so the pool saw real occupancy; an
        // unbudgeted engine never evicts.
        assert_eq!(g("kv_evictions_total"), 0.0);
        assert!(g("kv_pages_in_use_peak") > 0.0);
    }
}
