//! Fig 11 / App F.3: RSR vs an optimized dense library ("NumPy" in the
//! paper). Our optimized-library baseline is the PJRT-compiled XLA
//! dense matvec (Eigen dot under the CPU client) executed through the
//! AOT artifacts — the same class of BLAS-backed library NumPy
//! delegates to. Binary (11a) and ternary (11b) weights.
//! Paper's headline: up to 24× at n = 2^15.
//!
//! Requires `make artifacts`; sizes are capped by the artifact set
//! (dense_matvec_n{1024,2048,4096}).

use crate::bench::harness::{measure, ms, write_json, Table};
use crate::bench::workloads::SEED;
use crate::kernels::index::TernaryRsrIndex;
use crate::kernels::optimal_k::optimal_k_rsrpp;
use crate::kernels::rsrpp::{RsrPlusPlusPlan, TernaryRsrPlusPlusPlan};
use crate::kernels::{BinaryMatrix, TernaryMatrix};
use crate::kernels::index::RsrIndex;
use crate::runtime::{Engine, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Run the Fig 11 reproduction. Skips (with a message) when artifacts
/// are missing.
pub fn run(full: bool) {
    let engine = match Engine::load(Engine::default_dir()) {
        Ok(e) => e,
        Err(e) => {
            println!("fig11 skipped: {e}");
            return;
        }
    };
    let sizes: Vec<usize> =
        if full { vec![1024, 2048, 4096] } else { vec![1024, 2048] };
    let reps = if full { 4 } else { 3 }; // paper: average of 4

    let mut table = Table::new(&[
        "n", "weights", "XLA dense (BLAS-class)", "RSR++ (rust)", "speedup",
    ]);
    let mut json_rows = Vec::new();

    for &n in &sizes {
        let artifact = format!("dense_matvec_n{n}");
        if engine.spec(&artifact).is_none() {
            println!("  (no artifact {artifact}; skipping n={n})");
            continue;
        }
        let exe = match engine.executable(&artifact) {
            Ok(e) => e,
            Err(e) => {
                println!("  (cannot compile {artifact}: {e}; skipping n={n})");
                continue;
            }
        };
        let mut rng = Rng::new(SEED ^ n as u64);

        // ---- binary panel (11a)
        let b = BinaryMatrix::random(n, n, 0.5, &mut rng);
        let v = rng.f32_vec(n, -1.0, 1.0);
        let w_dense: Vec<f32> =
            b.to_dense().iter().map(|&x| x as f32).collect();
        let m_blas = measure(format!("xla n={n} bin"), 1, reps, || {
            exe.run_f32(&[
                Tensor::F32(v.clone(), vec![n]),
                Tensor::F32(w_dense.clone(), vec![n, n]),
            ])
            .unwrap()
        });
        let k = optimal_k_rsrpp(n);
        let mut plan = RsrPlusPlusPlan::new(RsrIndex::preprocess(&b, k)).unwrap();
        let mut out = vec![0.0f32; n];
        let m_rsr = measure(format!("rsr++ n={n} bin"), 1, reps, || {
            plan.execute(&v, &mut out).unwrap();
        });
        let speedup = m_blas.summary.mean() / m_rsr.summary.mean();
        table.row(&[
            format!("2^{}", n.trailing_zeros()),
            "binary".into(),
            ms(&m_blas),
            ms(&m_rsr),
            format!("{speedup:.1}x"),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("weights", Json::str("binary")),
            ("blas_ms", Json::num(m_blas.mean_ms())),
            ("rsr_ms", Json::num(m_rsr.mean_ms())),
            ("speedup", Json::num(speedup)),
        ]));

        // ---- ternary panel (11b)
        let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
        let w_dense: Vec<f32> = a.data().iter().map(|&x| x as f32).collect();
        let m_blas = measure(format!("xla n={n} tern"), 1, reps, || {
            exe.run_f32(&[
                Tensor::F32(v.clone(), vec![n]),
                Tensor::F32(w_dense.clone(), vec![n, n]),
            ])
            .unwrap()
        });
        let mut plan =
            TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap();
        let m_rsr = measure(format!("rsr++ n={n} tern"), 1, reps, || {
            plan.execute(&v, &mut out).unwrap();
        });
        let speedup = m_blas.summary.mean() / m_rsr.summary.mean();
        table.row(&[
            format!("2^{}", n.trailing_zeros()),
            "ternary".into(),
            ms(&m_blas),
            ms(&m_rsr),
            format!("{speedup:.1}x"),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("weights", Json::str("ternary")),
            ("blas_ms", Json::num(m_blas.mean_ms())),
            ("rsr_ms", Json::num(m_rsr.mean_ms())),
            ("speedup", Json::num(speedup)),
        ]));
    }

    table.print("Fig 11 — RSR vs optimized dense library (XLA/PJRT ≈ NumPy)");
    println!(
        "\npaper reference: up to 24x at n=2^15 vs np.dot; here the \
         baseline includes PJRT host-transfer overhead per call, and \
         sizes are capped by the AOT artifact set — the shape (RSR \
         winning, margin growing with n) is the reproduction target"
    );
    write_json("fig11", &Json::obj(vec![("rows", Json::Arr(json_rows))]));
}
