//! Fig 10 / App F.2: RSR++ vs RSR head-to-head (native), reporting the
//! paper's improvement percentage `(T(RSR) − T(RSR++)) / T(RSR) × 100`.
//! Paper's headline: up to 25% improvement.

use crate::bench::harness::{measure, ms, write_json, Table};
use crate::bench::workloads::{binary_workload, fig4_sizes, SEED};
use crate::kernels::index::RsrIndex;
use crate::kernels::optimal_k::optimal_k_rsrpp;
use crate::kernels::rsr::RsrPlan;
use crate::kernels::rsrpp::RsrPlusPlusPlan;
use crate::util::json::Json;

/// Run the Fig 10 reproduction.
pub fn run(full: bool) {
    let sizes = fig4_sizes(full);
    let reps = if full { 10 } else { 5 };
    let mut table = Table::new(&["n", "k", "RSR", "RSR++", "improvement %"]);
    let mut json_rows = Vec::new();

    for &n in &sizes {
        // Same k for both (isolates the step-2 subroutine difference —
        // the comparison Fig 10 makes).
        let k = optimal_k_rsrpp(n);
        let (b, v) = binary_workload(n, SEED ^ n as u64);
        let idx = RsrIndex::preprocess(&b, k);
        let mut rsr = RsrPlan::new(idx.clone()).unwrap();
        let mut rsrpp = RsrPlusPlusPlan::new(idx).unwrap();
        let mut out = vec![0.0f32; n];

        let m_rsr = measure(format!("rsr n={n}"), 1, reps, || {
            rsr.execute(&v, &mut out).unwrap();
        });
        let m_pp = measure(format!("rsr++ n={n}"), 1, reps, || {
            rsrpp.execute(&v, &mut out).unwrap();
        });
        let improvement =
            (m_rsr.summary.mean() - m_pp.summary.mean()) / m_rsr.summary.mean() * 100.0;

        table.row(&[
            format!("2^{}", n.trailing_zeros()),
            k.to_string(),
            ms(&m_rsr),
            ms(&m_pp),
            format!("{improvement:.1}%"),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("k", Json::num(k as f64)),
            ("rsr_ms", Json::num(m_rsr.mean_ms())),
            ("rsrpp_ms", Json::num(m_pp.mean_ms())),
            ("improvement_pct", Json::num(improvement)),
        ]));
    }

    table.print("Fig 10 — RSR++ vs RSR (same index, step-2 subroutine swap)");
    println!("\npaper reference: RSR++ up to 25% faster than RSR");
    write_json("fig10", &Json::obj(vec![("rows", Json::Arr(json_rows))]));
}
