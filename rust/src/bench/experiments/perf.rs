//! §Perf driver: warm, repeated measurements of every ternary backend
//! across sizes — the before/after evidence for EXPERIMENTS.md §Perf.
//!
//! "Before" = the straightforward gather pipeline (`rsr`, `rsr++`);
//! "after" = the fused scatter + single-fold hot path (`rsr-fused`).
//! Baselines (`standard`, `standard-packed`) bracket the comparison.

use crate::bench::harness::{measure, ms, write_json, Table};
use crate::bench::workloads::{ternary_workload, SEED};
use crate::kernels::optimal_k::{k_max, optimal_k_rsrpp};
use crate::kernels::Backend;
use crate::model::bitlinear::BitLinear;
use crate::util::json::Json;

/// Pick the empirically fastest k for a backend at size n.
fn best_k(n: usize, backend: Backend, a: &crate::kernels::TernaryMatrix, v: &[f32]) -> usize {
    let analytic = optimal_k_rsrpp(n);
    let lo = analytic.saturating_sub(4).max(1);
    let hi = (analytic + 1).min(k_max(n));
    let mut out = vec![0.0f32; n];
    let mut best = (f64::INFINITY, analytic);
    for k in lo..=hi {
        let mut layer = BitLinear::new(a.clone(), 1.0, backend, k).unwrap();
        layer.forward(v, &mut out).unwrap(); // warm
        let t0 = std::time::Instant::now();
        layer.forward(v, &mut out).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        if secs < best.0 {
            best = (secs, k);
        }
    }
    best.1
}

/// Run the §Perf comparison.
pub fn run(full: bool) {
    let sizes: Vec<usize> =
        if full { vec![2048, 4096, 8192] } else { vec![2048, 4096] };
    let reps = if full { 10 } else { 6 };
    let backends = [
        Backend::Standard,
        Backend::StandardPacked,
        Backend::Rsr,
        Backend::RsrPlusPlus,
        Backend::Tensorized,
        Backend::RsrFused,
    ];
    let mut table = Table::new(&["n", "backend", "k", "time", "vs rsr++"]);
    let mut json_rows = Vec::new();

    for &n in &sizes {
        let (a, v) = ternary_workload(n, SEED ^ n as u64);
        let mut out = vec![0.0f32; n];
        let mut rsrpp_mean = 0.0;
        for backend in backends {
            let k = match backend {
                Backend::Standard | Backend::StandardPacked => 0,
                _ => best_k(n, backend, &a, &v),
            };
            let mut layer = BitLinear::new(a.clone(), 1.0, backend, k.max(1)).unwrap();
            let m = measure(format!("{} n={n}", backend.name()), 2, reps, || {
                layer.forward(&v, &mut out).unwrap();
            });
            if backend == Backend::RsrPlusPlus {
                rsrpp_mean = m.summary.mean();
            }
            let rel = if rsrpp_mean > 0.0 {
                format!("{:.2}x", rsrpp_mean / m.summary.mean())
            } else {
                "-".into()
            };
            table.row(&[
                format!("2^{}", n.trailing_zeros()),
                backend.name().to_string(),
                if k == 0 { "-".into() } else { k.to_string() },
                ms(&m),
                rel,
            ]);
            json_rows.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("backend", Json::str(backend.name())),
                ("k", Json::num(k as f64)),
                ("ms", Json::num(m.mean_ms())),
            ]));
        }
    }
    table.print("§Perf — ternary v·A across backends (warm, empirical k)");
    println!(
        "\n'vs rsr++' > 1 means faster than the unfused RSR++ gather \
         pipeline; rsr-fused is the optimized hot path (scatter keys, \
         shared pass over v, single fold)"
    );
    write_json("perf", &Json::obj(vec![("rows", Json::Arr(json_rows))]));
}
