//! Fig 4: native RSR / RSR++ / Standard on binary matrices,
//! `n = 2^11..2^16`, optimal k per n, average of 10 runs.
//! Paper's headline: up to 29× speedup at `n = 2^16`.

use std::time::Duration;

use crate::bench::harness::{iters_for, measure, ms, write_json, Table};
use crate::bench::workloads::{binary_workload, fig4_sizes, SEED};
use crate::kernels::index::RsrIndex;
use crate::kernels::optimal_k::{optimal_k_rsr, optimal_k_rsrpp};
use crate::kernels::rsr::RsrPlan;
use crate::kernels::rsrpp::RsrPlusPlusPlan;
use crate::kernels::standard::standard_mul_binary_u8;
use crate::util::json::Json;
use crate::util::timer::time;

/// Probe k in a window around the analytic optimum and return the
/// empirically fastest (App F.1's procedure, trimmed to a window so
/// Fig 4 setup stays cheap; the full sweep lives in the fig9 bench).
fn empirical_k(
    n: usize,
    analytic: usize,
    b: &crate::kernels::BinaryMatrix,
    v: &[f32],
    plusplus: bool,
) -> usize {
    use crate::kernels::optimal_k::k_max;
    let lo = analytic.saturating_sub(4).max(1);
    let hi = (analytic + 1).min(k_max(n));
    let mut best = (f64::INFINITY, analytic);
    let mut out = vec![0.0f32; n];
    for k in lo..=hi {
        let idx = RsrIndex::preprocess(b, k);
        let secs = if plusplus {
            let mut plan = RsrPlusPlusPlan::new(idx).unwrap();
            plan.execute(v, &mut out).unwrap(); // warm
            let t0 = std::time::Instant::now();
            plan.execute(v, &mut out).unwrap();
            t0.elapsed().as_secs_f64()
        } else {
            let mut plan = RsrPlan::new(idx).unwrap();
            plan.execute(v, &mut out).unwrap();
            let t0 = std::time::Instant::now();
            plan.execute(v, &mut out).unwrap();
            t0.elapsed().as_secs_f64()
        };
        if secs < best.0 {
            best = (secs, k);
        }
    }
    best.1
}

/// Run the Fig 4 reproduction.
pub fn run(full: bool) {
    let sizes = fig4_sizes(full);
    let reps = if full { 10 } else { 5 }; // paper: average of 10
    let mut table = Table::new(&[
        "n", "k*", "Standard", "RSR", "RSR++", "speedup (RSR++ vs Std)",
    ]);
    let mut json_rows = Vec::new();

    for &n in &sizes {
        let (b, v) = binary_workload(n, SEED ^ n as u64);
        // The paper uses the *empirically* optimal k per n (App F.1).
        // The analytic argmin (Eq 6/7) ignores cache effects, so probe
        // a window around it and keep the fastest.
        let k_rsr = empirical_k(n, optimal_k_rsr(n), &b, &v, false);
        let k_pp = empirical_k(n, optimal_k_rsrpp(n), &b, &v, true);

        // Preprocess (excluded from inference timing, as in the paper).
        let mut rsr = RsrPlan::new(RsrIndex::preprocess(&b, k_rsr)).unwrap();
        let mut rsrpp = RsrPlusPlusPlan::new(RsrIndex::preprocess(&b, k_pp)).unwrap();

        // The paper's Standard baseline: dense byte array double loop.
        let dense = b.to_dense();
        let mut out = vec![0.0f32; n];

        // Adaptive reps so quick mode stays quick at large n.
        let (_, single) = time(|| {
            out.copy_from_slice(&standard_mul_binary_u8(&v, &dense, n, n));
        });
        let std_iters = iters_for(single, Duration::from_secs(8), 3, reps);

        let m_std = measure(format!("standard n={n}"), 1, std_iters, || {
            standard_mul_binary_u8(&v, &dense, n, n)
        });
        let m_rsr = measure(format!("rsr n={n}"), 1, reps, || {
            rsr.execute(&v, &mut out).unwrap();
        });
        let m_pp = measure(format!("rsr++ n={n}"), 1, reps, || {
            rsrpp.execute(&v, &mut out).unwrap();
        });

        let speedup = m_std.summary.mean() / m_pp.summary.mean();
        table.row(&[
            format!("2^{}", n.trailing_zeros()),
            format!("{k_rsr}/{k_pp}"),
            ms(&m_std),
            ms(&m_rsr),
            ms(&m_pp),
            format!("{speedup:.1}x"),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("k_rsr", Json::num(k_rsr as f64)),
            ("k_rsrpp", Json::num(k_pp as f64)),
            ("standard_ms", Json::num(m_std.mean_ms())),
            ("rsr_ms", Json::num(m_rsr.mean_ms())),
            ("rsrpp_ms", Json::num(m_pp.mean_ms())),
            ("speedup", Json::num(speedup)),
        ]));
    }

    table.print("Fig 4 — native binary matmul: RSR/RSR++/Standard");
    println!(
        "\npaper reference: RSR++ up to 29x over Standard at n=2^16 \
         (C++ on the authors' Xeon; shape — growing speedup in n — is \
         the reproduction target)"
    );
    write_json("fig4", &Json::obj(vec![("rows", Json::Arr(json_rows))]));
}
