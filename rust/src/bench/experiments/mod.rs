//! One driver per paper table/figure (see DESIGN.md §5 for the
//! experiment index) plus our own ablations. Each `run(full)` prints a
//! markdown table mirroring the paper's rows and writes a JSON record
//! under `target/bench-results/`.

pub mod ablations;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig9;
pub mod kernels;
pub mod perf;
pub mod prefill;
pub mod serving;
pub mod table1;
