//! Ablations of our own design choices (DESIGN.md §5 last row):
//!
//! 1. **Gather vs scatter** — RSR's permutation+segment (gather) vs the
//!    tensorized key form (scatter-add): same math, different memory
//!    access pattern.
//! 2. **Baseline strength** — paper's dense-loop Standard vs our
//!    bit-packed word-at-a-time baseline: how much of RSR's win
//!    survives against a stronger no-preprocessing baseline.
//! 3. **k sensitivity** — runtime at k* vs k*±2 (how sharp the optimum
//!    is — relevant to deployments that share one k across layers).
//! 4. **q-bit extension cost** — per-plane overhead of the App D.3
//!    generalization (q = 2, 3, 4).

use crate::bench::harness::{measure, ms, write_json, Table};
use crate::bench::workloads::{binary_workload, SEED};
use crate::kernels::index::RsrIndex;
use crate::kernels::optimal_k::{k_max, optimal_k_rsrpp};
use crate::kernels::qbit::{QbitMatrix, QbitRsrPlan};
use crate::kernels::rsrpp::RsrPlusPlusPlan;
use crate::kernels::standard::{packed_mul_binary, standard_mul_binary_u8};
use crate::kernels::tensorized::TensorizedIndex;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Run all ablations.
pub fn run(full: bool) {
    let n = if full { 8192 } else { 2048 };
    let reps = if full { 8 } else { 4 };
    let k = optimal_k_rsrpp(n);
    let (b, v) = binary_workload(n, SEED ^ 0xAB1A);
    let mut out = vec![0.0f32; n];
    let mut json = Vec::new();

    // 1. gather vs scatter
    let mut gather = RsrPlusPlusPlan::new(RsrIndex::preprocess(&b, k)).unwrap();
    let scatter = TensorizedIndex::preprocess(&b, k);
    let m_gather = measure("gather", 1, reps, || {
        gather.execute(&v, &mut out).unwrap();
    });
    let m_scatter = measure("scatter", 1, reps, || {
        scatter.execute(&v, &mut out).unwrap();
    });
    let mut t1 = Table::new(&["variant", "time", "index bytes"]);
    t1.row(&[
        "gather (σ + L, RSR++)".into(),
        ms(&m_gather),
        gather.index_bytes().to_string(),
    ]);
    t1.row(&["scatter (keys, tensorized)".into(), ms(&m_scatter), scatter.bytes().to_string()]);
    t1.print(&format!("Ablation 1 — gather vs scatter segmented sum (n={n}, k={k})"));
    json.push(Json::obj(vec![
        ("ablation", Json::str("gather_vs_scatter")),
        ("gather_ms", Json::num(m_gather.mean_ms())),
        ("scatter_ms", Json::num(m_scatter.mean_ms())),
    ]));

    // 2. baseline strength
    let dense = b.to_dense();
    let m_dense = measure("std dense", 1, reps, || {
        standard_mul_binary_u8(&v, &dense, n, n)
    });
    let m_packed = measure("std packed", 1, reps, || packed_mul_binary(&v, &b));
    let mut t2 = Table::new(&["baseline", "time", "RSR++ speedup vs it"]);
    t2.row(&[
        "dense u8 loop (paper's Standard)".into(),
        ms(&m_dense),
        format!("{:.1}x", m_dense.summary.mean() / m_gather.summary.mean()),
    ]);
    t2.row(&[
        "bit-packed word loop (stronger)".into(),
        ms(&m_packed),
        format!("{:.1}x", m_packed.summary.mean() / m_gather.summary.mean()),
    ]);
    t2.print(&format!("Ablation 2 — baseline strength (n={n})"));
    json.push(Json::obj(vec![
        ("ablation", Json::str("baseline_strength")),
        ("dense_ms", Json::num(m_dense.mean_ms())),
        ("packed_ms", Json::num(m_packed.mean_ms())),
        ("rsrpp_ms", Json::num(m_gather.mean_ms())),
    ]));

    // 3. k sensitivity around k*
    let mut t3 = Table::new(&["k", "time", "Δ vs k*"]);
    let mut base_ms = 0.0;
    for dk in [-2i32, -1, 0, 1, 2] {
        let kk = (k as i32 + dk).clamp(1, k_max(n) as i32) as usize;
        let mut plan = RsrPlusPlusPlan::new(RsrIndex::preprocess(&b, kk)).unwrap();
        let m = measure(format!("k={kk}"), 1, reps, || {
            plan.execute(&v, &mut out).unwrap();
        });
        if dk == 0 {
            base_ms = m.mean_ms();
        }
        let delta = if base_ms > 0.0 {
            format!("{:+.0}%", (m.mean_ms() - base_ms) / base_ms * 100.0)
        } else {
            "-".into()
        };
        t3.row(&[
            format!("{kk}{}", if dk == 0 { " (k*)" } else { "" }),
            ms(&m),
            delta,
        ]);
    }
    t3.print(&format!("Ablation 3 — k sensitivity around k*={k} (n={n})"));

    // 4. q-bit extension cost
    let qn = if full { 2048 } else { 1024 };
    let mut rng = Rng::new(SEED ^ 0x9B17);
    let qv = rng.f32_vec(qn, -1.0, 1.0);
    let mut t4 = Table::new(&["q", "planes", "time", "vs q=2"]);
    let mut q2_ms = 0.0;
    for q in [2u32, 3, 4] {
        let w = QbitMatrix::random(qn, qn, q, &mut rng);
        let mut plan = QbitRsrPlan::preprocess(&w, optimal_k_rsrpp(qn)).unwrap();
        let mut qout = vec![0.0f32; qn];
        let m = measure(format!("q={q}"), 1, reps, || {
            plan.execute(&qv, &mut qout).unwrap();
        });
        if q == 2 {
            q2_ms = m.mean_ms();
        }
        t4.row(&[
            q.to_string(),
            (2 * (q - 1)).to_string(),
            ms(&m),
            format!("{:.1}x", m.mean_ms() / q2_ms),
        ]);
        json.push(Json::obj(vec![
            ("ablation", Json::str("qbit")),
            ("q", Json::num(q as f64)),
            ("ms", Json::num(m.mean_ms())),
        ]));
    }
    t4.print(&format!("Ablation 4 — q-bit generalization cost (n={qn})"));
    println!(
        "\nexpected: scatter ≈ gather (same O(n) pass, no σ storage); \
         packed baseline narrows but does not erase RSR's win; runtime \
         is flat within ±1 of k*; q-bit cost grows ~linearly in plane \
         count 2(q−1)"
    );
    write_json("ablations", &Json::obj(vec![("entries", Json::Arr(json))]));
}
