//! `rsr bench-prefill` — the chunked-prefill perf trajectory:
//! time-to-first-token as a function of the prefill chunk size.
//!
//! Sweeps `--chunks` (default `{1, 4, 8, 16}`) over a synthetic
//! `n = 1024` layer stack by prefilling the same prompt through
//! [`Transformer::forward_chunk`] in chunk-sized steps — the exact
//! lockstep step the serving engine's continuous loop executes for a
//! prefilling slot — and records TTFT and prefill tokens/sec to
//! `BENCH_prefill.json` (CI's bench-record job commits it to the repo,
//! so the trajectory accumulates). Chunk `1` is the old
//! one-token-per-step path and anchors the speedup column; chunking
//! amortizes one shared-index read per layer across the whole chunk,
//! so throughput should rise with the chunk on paper-scale layers.
//!
//! The sweep double-checks correctness while it measures: every chunk
//! size must greedily sample the **same first token** as chunk 1
//! (chunked prefill is bit-identical by construction — see
//! `rust/tests/prefill.rs` for the full pin), so a silently wrong
//! kernel can never publish a benchmark number.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::bench::harness::Table;
use crate::error::{Error, Result};
use crate::model::config::ModelConfig;
use crate::model::tensor::argmax;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::runtime::PlanStore;
use crate::util::json::Json;

/// Options for one bench-prefill run.
#[derive(Debug, Clone)]
pub struct PrefillBenchOpts {
    /// Prefill chunk sizes to sweep (1 = the one-token baseline).
    pub chunks: Vec<usize>,
    /// Hidden width of the synthetic model (the paper's `n`).
    pub d_model: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Decoder blocks.
    pub n_layers: usize,
    /// Prompt tokens prefilled per measurement.
    pub prompt_len: usize,
    /// Timed repetitions per chunk size (the minimum is reported —
    /// standard wall-clock practice for a mutating workload).
    pub trials: usize,
    /// Where to write the JSON record (`None` → stdout table only).
    pub json_path: Option<PathBuf>,
}

impl Default for PrefillBenchOpts {
    fn default() -> Self {
        Self {
            chunks: vec![1, 4, 8, 16],
            d_model: 1024,
            d_ff: 2048,
            n_layers: 1,
            prompt_len: 256,
            trials: 3,
            json_path: Some(PathBuf::from("BENCH_prefill.json")),
        }
    }
}

/// Prefill `prompt` into slot 0 in `chunk`-token steps through
/// [`Transformer::forward_chunk`] and return the wall time together
/// with the greedily sampled first generated token. Resets slot 0
/// first; shared with `bench-serve`'s TTFT sweep so both report the
/// same methodology.
pub(crate) fn chunked_prefill_ttft(
    model: &mut Transformer,
    prompt: &[u32],
    chunk: usize,
) -> Result<(Duration, u32)> {
    let chunk = chunk.max(1);
    let vocab = model.config().vocab_size;
    model.reset_slot(0);
    let t0 = Instant::now();
    let mut first = 0u32;
    let mut p = 0;
    while p < prompt.len() {
        let take = chunk.min(prompt.len() - p);
        let logits = model.forward_chunk(&prompt[p..p + take], &[0], &[take])?;
        p += take;
        if p == prompt.len() {
            let last = take - 1;
            first = argmax(&logits[last * vocab..(last + 1) * vocab]) as u32;
        }
    }
    Ok((t0.elapsed(), first))
}

fn synthetic_config(opts: &PrefillBenchOpts) -> ModelConfig {
    ModelConfig {
        name: format!("bench-prefill-{}", opts.d_model),
        vocab_size: 270,
        d_model: opts.d_model,
        n_layers: opts.n_layers,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: opts.d_ff,
        max_seq_len: opts.prompt_len + 2,
        rope_theta: 10_000.0,
    }
}

/// Run the sweep; returns the JSON record that was (optionally)
/// written. Preprocessing (Algorithm 1) runs **once** through a shared
/// [`PlanStore`] — every chunk size executes the same compiled plans,
/// so the sweep isolates the chunking effect.
pub fn run(opts: &PrefillBenchOpts) -> Result<Json> {
    if opts.chunks.is_empty() || opts.prompt_len == 0 {
        return Err(Error::Config("bench-prefill needs chunks and a prompt".into()));
    }
    let cfg = synthetic_config(opts);
    cfg.validate()?;
    println!(
        "bench-prefill: {} layer(s) of n={} (d_ff {}), prompt {}, best of {} trial(s)",
        cfg.n_layers, cfg.d_model, cfg.d_ff, opts.prompt_len, opts.trials
    );
    let weights = Arc::new(ModelWeights::generate(cfg.clone(), 0xF111)?);
    let store = PlanStore::for_model(Arc::clone(&weights), 0);
    store.preload(&weights.matrix_names())?;
    let prompt: Vec<u32> =
        (0..opts.prompt_len).map(|j| ((j * 7 + 3) % 256) as u32).collect();

    let mut model = Transformer::from_plan_store(&weights, &store)?;
    let mut measured: Vec<(usize, f64)> = Vec::new();
    let mut first_tokens: Vec<u32> = Vec::new();
    for &chunk in &opts.chunks {
        // One unmeasured pass per chunk (first-touch scratch growth).
        let (_, warm_tok) = chunked_prefill_ttft(&mut model, &prompt, chunk)?;
        let mut best = f64::INFINITY;
        let mut tok = warm_tok;
        for _ in 0..opts.trials.max(1) {
            let (dt, t) = chunked_prefill_ttft(&mut model, &prompt, chunk)?;
            best = best.min(dt.as_secs_f64());
            tok = t;
        }
        measured.push((chunk, best));
        first_tokens.push(tok);
    }
    // Correctness gate: every chunk size must sample the same first
    // token (bit-identical prefill) — a benchmark over a wrong kernel
    // is worse than no benchmark.
    for (i, &t) in first_tokens.iter().enumerate() {
        if t != first_tokens[0] {
            return Err(Error::Config(format!(
                "bench-prefill: chunk {} sampled token {t}, chunk {} sampled {} — \
                 chunked prefill must be bit-identical",
                opts.chunks[i], opts.chunks[0], first_tokens[0]
            )));
        }
    }

    // The speedup baseline is chunk 1 when swept, else the smallest.
    let base = measured
        .iter()
        .min_by_key(|&&(c, _)| c)
        .map_or(1.0, |&(_, s)| s)
        .max(1e-12);
    let base_c = measured.iter().map(|&(c, _)| c).min().unwrap_or(1);
    let mut table = Table::new(&[
        "chunk",
        "ttft ms",
        "prefill tok/s",
        &format!("vs chunk={base_c}"),
    ]);
    let mut rows = Vec::new();
    for &(chunk, secs) in &measured {
        let tps = opts.prompt_len as f64 / secs.max(1e-12);
        table.row(&[
            chunk.to_string(),
            format!("{:.3}", secs * 1e3),
            format!("{tps:.1}"),
            format!("{:.2}x", base / secs.max(1e-12)),
        ]);
        rows.push(Json::obj(vec![
            ("chunk", Json::num(chunk as f64)),
            ("ttft_ms", Json::num(secs * 1e3)),
            ("prefill_tokens_per_sec", Json::num(tps)),
            ("speedup_vs_smallest_chunk", Json::num(base / secs.max(1e-12))),
        ]));
    }
    let record = Json::obj(vec![
        ("bench", Json::str("prefill")),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("d_ff", Json::num(cfg.d_ff as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("prompt_len", Json::num(opts.prompt_len as f64)),
        ("trials", Json::num(opts.trials as f64)),
        ("first_token", Json::num(first_tokens[0] as f64)),
        ("chunks", Json::Arr(rows)),
    ]);
    table.print("bench-prefill: time-to-first-token by prefill chunk");
    if let Some(path) = &opts.json_path {
        match std::fs::write(path, record.to_string()) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_records_every_chunk() {
        let opts = PrefillBenchOpts {
            chunks: vec![1, 4],
            d_model: 64,
            d_ff: 96,
            n_layers: 1,
            prompt_len: 9,
            trials: 1,
            json_path: None,
        };
        let record = run(&opts).unwrap();
        let rows = record.get("chunks").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("chunk").unwrap().as_f64(), Some(4.0));
        assert!(rows[0].get("prefill_tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(rows[1].get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn rejects_empty_sweeps() {
        let opts = PrefillBenchOpts { chunks: vec![], ..Default::default() };
        assert!(run(&opts).is_err());
        let opts = PrefillBenchOpts { prompt_len: 0, ..Default::default() };
        assert!(run(&opts).is_err());
    }
}
