//! Fig 12 / App F.4: single vector–ternary-matrix multiplication on
//! GPU — simulated via the calibrated T4 cost model (see
//! `bench::gpusim` and DESIGN.md §Substitutions), cross-checked with a
//! real CPU-thread scaling measurement of the same tensorized kernel.
//! Paper's headline: up to 2× speedup, shrinking as n grows.

use crate::bench::gpusim::{speedup, vecmat_rsr_latency, vecmat_standard_latency, GpuParams};
use crate::bench::harness::{write_json, Table};
use crate::bench::workloads::fig12_sizes;
use crate::util::json::Json;

/// Run the Fig 12 reproduction.
pub fn run(full: bool) {
    let p = GpuParams::default();
    let mut table = Table::new(&[
        "n", "Standard (µs, sim)", "RSR tensorized (µs, sim)", "speedup (sim)",
    ]);
    let mut json_rows = Vec::new();

    for &n in &fig12_sizes() {
        let std_us = vecmat_standard_latency(&p, n).as_secs_f64() * 1e6;
        let rsr_us = vecmat_rsr_latency(&p, n).as_secs_f64() * 1e6;
        let s = speedup(&p, n);
        table.row(&[
            format!("2^{}", n.trailing_zeros()),
            format!("{std_us:.0}"),
            format!("{rsr_us:.0}"),
            format!("{s:.2}x"),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("standard_us", Json::num(std_us)),
            ("rsr_us", Json::num(rsr_us)),
            ("speedup", Json::num(s)),
        ]));
    }
    table.print("Fig 12 — GPU vector-ternary-matmul (T4 cost model)");

    // Hardware-independent cross-check: the tensorized kernel's block
    // decomposition measured across real threads on this machine.
    let threads: Vec<usize> = if full { vec![1, 2, 4] } else { vec![1, 2] };
    let measured = crate::bench::gpusim::measured_parallel_speedup(
        if full { 4096 } else { 2048 },
        8,
        &threads,
    );
    let mut t2 = Table::new(&["threads", "tensorized RSR (ms, measured)"]);
    for (t, ms) in &measured {
        t2.row(&[t.to_string(), format!("{ms:.2}")]);
    }
    t2.print("Fig 12 cross-check — tensorized kernel, real CPU threads");
    println!(
        "\npaper reference: ~2x at 2^11 shrinking toward 1x by 2^14; \
         note this host has {} core(s), so thread scaling may be flat \
         here — the simulated panel carries the GPU claim",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    write_json(
        "fig12",
        &Json::obj(vec![
            ("sim_rows", Json::Arr(json_rows)),
            (
                "measured_threads",
                Json::Arr(
                    measured
                        .iter()
                        .map(|&(t, ms)| {
                            Json::obj(vec![
                                ("threads", Json::num(t as f64)),
                                ("ms", Json::num(ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
}
