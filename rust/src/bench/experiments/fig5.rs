//! Fig 5: memory consumption after preprocessing — RSR indices vs the
//! dense matrix an optimized library keeps (f32, as NumPy stores it).
//! Paper's headline: ≤17% of the original at `n = 2^16` (5.99×).
//!
//! This is exact byte accounting, not sampling: every structure knows
//! its heap size.

use crate::bench::harness::{write_json, Table};
use crate::bench::workloads::{fig4_sizes, ternary_workload, SEED};
use crate::kernels::index::TernaryRsrIndex;
use crate::kernels::optimal_k::optimal_k_rsrpp;
use crate::util::json::Json;

fn mb(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Run the Fig 5 reproduction.
pub fn run(full: bool) {
    let sizes = fig4_sizes(full);
    let mut table = Table::new(&[
        "n", "k*", "dense f32 (MB)", "dense i8 (MB)", "2-bit packed (MB)",
        "RSR index (MB)", "vs f32", "peak preprocess (MB)",
    ]);
    let mut json_rows = Vec::new();

    for &n in &sizes {
        let k = optimal_k_rsrpp(n);
        let (a, _) = ternary_workload(n, SEED ^ n as u64);
        let idx = TernaryRsrIndex::preprocess(&a, k);

        let dense_f32 = n * n * 4; // what NumPy holds for np.dot
        let dense_i8 = a.dense_bytes();
        let packed2 = a.packed2_bytes();
        let index = idx.bytes();
        // Peak during preprocessing: matrix + index coexist (the
        // paper's green line), after which the matrix is dropped.
        let peak = dense_i8 + index;
        let ratio = dense_f32 as f64 / index as f64;

        table.row(&[
            format!("2^{}", n.trailing_zeros()),
            k.to_string(),
            format!("{:.1}", mb(dense_f32)),
            format!("{:.1}", mb(dense_i8)),
            format!("{:.1}", mb(packed2)),
            format!("{:.1}", mb(index)),
            format!("{ratio:.2}x"),
            format!("{:.1}", mb(peak)),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("k", Json::num(k as f64)),
            ("dense_f32", Json::num(dense_f32 as f64)),
            ("index", Json::num(index as f64)),
            ("ratio_vs_f32", Json::num(ratio)),
        ]));
    }

    table.print("Fig 5 — memory after preprocessing (ternary matrices)");
    println!(
        "\npaper reference: index ≤17% of the matrix (5.99x) at n=2^16; \
         ratio vs the f32 the NumPy baseline holds is the comparable \
         column (the paper measured NumPy float storage)"
    );
    write_json("fig5", &Json::obj(vec![("rows", Json::Arr(json_rows))]));
}
