//! Fig 6: 1.58-bit LLM inference on CPU — three model shapes × three
//! datasets, one generated token per prompt (a single feed-forward
//! pass), Standard vs RSR, with the output-equality check.
//! Paper's headline: up to 5.24× speedup.
//!
//! Models are the DESIGN.md proxies for the HF checkpoints (matching
//! layer dims, synthetic ternary weights); datasets are the synthetic
//! generators. Quick mode uses trimmed model depth and fewer prompts.

use std::time::Duration;

use crate::bench::harness::{measure, write_json, Table};
use crate::data::datasets::{Dataset, DatasetKind};
use crate::kernels::Backend;
use crate::model::config::ModelConfig;
use crate::model::tokenizer::Tokenizer;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::util::json::Json;

fn model_configs(full: bool) -> Vec<ModelConfig> {
    if full {
        vec![
            ModelConfig::llama3_8b_proxy(),
            ModelConfig::falcon3_3b_proxy(),
            ModelConfig::falcon3_10b_proxy(),
        ]
    } else {
        // Quick mode: same aspect ratios, 1/8 width, depth 2 — CI-fast
        // while keeping the Llama>Falcon10B>Falcon3B cost ordering.
        let shrink = |mut c: ModelConfig| {
            c.d_model /= 8;
            c.d_ff /= 8;
            c.n_layers = 2;
            c.n_heads /= 8;
            c.n_kv_heads = (c.n_kv_heads / 8).max(1);
            c.name = format!("{}-quick", c.name);
            c
        };
        vec![
            shrink(ModelConfig::llama3_8b_proxy()),
            shrink(ModelConfig::falcon3_3b_proxy()),
            shrink(ModelConfig::falcon3_10b_proxy()),
        ]
    }
}

/// One feed-forward pass per prompt (paper §5.3: "we generated a single
/// token by running one feedforward pass"), returning mean ms/token
/// and the argmax token ids for the equality check.
fn time_model(
    model: &mut Transformer,
    prompts: &[Vec<u32>],
    reps: usize,
) -> (f64, Vec<u32>) {
    let mut tokens = Vec::with_capacity(prompts.len());
    // Correctness pass (also warms caches).
    for p in prompts {
        model.reset();
        for &t in p {
            model.forward_token(t).unwrap();
        }
        tokens.push(crate::model::tensor::argmax(model.last_logits()) as u32);
    }
    // Timing pass.
    let m = measure("model", 0, reps, || {
        for p in prompts {
            model.reset();
            for &t in p {
                model.forward_token(t).unwrap();
            }
        }
    });
    let per_prompt_ms = m.mean_ms() / prompts.len() as f64;
    (per_prompt_ms, tokens)
}

/// Run the Fig 6 reproduction.
pub fn run(full: bool) {
    let tokenizer = Tokenizer::new();
    let n_prompts = if full { 8 } else { 4 };
    let reps = if full { 3 } else { 2 };
    let mut table = Table::new(&[
        "model", "dataset", "Standard (ms/tok)", "RSR++ (ms/tok)", "speedup",
        "outputs equal",
    ]);
    let mut json_rows = Vec::new();

    for cfg in model_configs(full) {
        let weights = ModelWeights::generate(cfg.clone(), 0xF156 ^ cfg.d_model as u64)
            .unwrap();
        let mut std_model =
            Transformer::from_weights(&weights, Backend::Standard, 0).unwrap();
        let mut rsr_model =
            Transformer::from_weights(&weights, Backend::RsrPlusPlus, 0).unwrap();

        for kind in DatasetKind::ALL {
            let ds = Dataset::generate(kind, n_prompts, 0xDA7A);
            let prompts: Vec<Vec<u32>> = ds
                .prompts
                .iter()
                .map(|p| {
                    let mut t = tokenizer.encode_with_bos(p);
                    t.truncate(cfg.max_seq_len - 1);
                    t
                })
                .collect();

            let (std_ms, std_tokens) = time_model(&mut std_model, &prompts, reps);
            let (rsr_ms, rsr_tokens) = time_model(&mut rsr_model, &prompts, reps);
            let equal = std_tokens == rsr_tokens;
            let speedup = std_ms / rsr_ms;

            table.row(&[
                cfg.name.clone(),
                kind.name().to_string(),
                format!("{std_ms:.2}"),
                format!("{rsr_ms:.2}"),
                format!("{speedup:.2}x"),
                equal.to_string(),
            ]);
            json_rows.push(Json::obj(vec![
                ("model", Json::str(cfg.name.clone())),
                ("dataset", Json::str(kind.name())),
                ("standard_ms", Json::num(std_ms)),
                ("rsr_ms", Json::num(rsr_ms)),
                ("speedup", Json::num(speedup)),
                ("outputs_equal", Json::Bool(equal)),
            ]));
            assert!(equal, "RSR output must match Standard (paper §5.3 check)");
        }
    }

    table.print("Fig 6 — 1.58-bit LLM inference on CPU (1 token / feed-forward)");
    println!(
        "\npaper reference: up to 5.24x (PyTorch baseline with low-level \
         optimizations; our Standard is a plain loop, so the comparable \
         claim is RSR winning consistently across models and datasets)"
    );
    write_json("fig6", &Json::obj(vec![("rows", Json::Arr(json_rows))]));
    let _ = Duration::ZERO;
}
