//! `rsr bench-kernels` — the kernel-layer perf trajectory.
//!
//! Times one `v·A` through every hot-path backend on a grid of
//! `n×m` shapes and writes the numbers to `BENCH_kernels.json`, so the
//! repo records its kernel performance machine-readably from PR to PR
//! (CI runs a 1-shape smoke on every push and uploads the JSON as a
//! workflow artifact; the default grid is square
//! `n ∈ {1024, 4096, 8192}`, and `--shapes` adds the rectangular
//! layer shapes real models serve, e.g. `4096x11008`).
//!
//! Timing goes through [`crate::tune::microbench`] — the **same**
//! calibrated inner-repeat/median-of-trials path the autotuner ranks
//! candidates with — so the recorded trajectory and `rsr tune`'s
//! decisions never disagree about methodology.
//!
//! Backends:
//! * `standard` — dense `O(n²)` i8 multiply (the paper's baseline);
//! * `rsr` — Algorithm 2 on the flat plan;
//! * `rsrpp` — Algorithm 2 + 3 on the flat plan (SIMD-dispatched
//!   segmented sums, pairwise fold);
//! * `rsr_parallel` — RSR++ across the shared worker pool;
//! * `batched_per_vec` — batched RSR++ (segment-major interleaved
//!   layout), reported **per vector** at the configured batch size;
//! * `tl` — the table-lookup plan ([`crate::kernels::TlPlan`]),
//!   runtime-dispatched to the host's best column loop.

use std::path::PathBuf;
use std::time::Duration;

use crate::bench::harness::Table;
use crate::error::{Error, Result};
use crate::kernels::batched::BatchedTernaryRsrPlan;
use crate::kernels::flat::TernaryFlatPlan;
use crate::kernels::index::TernaryRsrIndex;
use crate::kernels::optimal_k::optimal_k_rsrpp;
use crate::kernels::parallel::ParallelTernaryRsrPlan;
use crate::kernels::rsr::TernaryRsrPlan;
use crate::kernels::rsrpp::TernaryRsrPlusPlusPlan;
use crate::kernels::standard::standard_mul_ternary_i8;
use crate::kernels::tl::{TlPlan, TL_GROUP};
use crate::kernels::TernaryMatrix;
use crate::tune::microbench::{bench, BenchOpts, BenchResult};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Options for one bench-kernels run.
#[derive(Debug, Clone)]
pub struct KernelBenchOpts {
    /// `(n, m)` shapes to sweep (`--sizes N` adds the square `N×N`;
    /// `--shapes NxM` adds rectangles).
    pub shapes: Vec<(usize, usize)>,
    /// Trials per backend per shape (the reported figure is their
    /// median).
    pub reps: usize,
    /// Batch size for the batched backend.
    pub batch: usize,
    /// Thread count for the parallel backend (`0` → the shared
    /// process-wide pool).
    pub threads: usize,
    /// Soft measurement budget per backend per shape.
    pub budget: Duration,
    /// Where to write the JSON record (`None` → stdout table only).
    pub json_path: Option<PathBuf>,
}

impl Default for KernelBenchOpts {
    fn default() -> Self {
        Self {
            shapes: vec![(1024, 1024), (4096, 4096), (8192, 8192)],
            reps: 5,
            batch: 8,
            threads: 0,
            budget: Duration::from_millis(250),
            json_path: Some(PathBuf::from("BENCH_kernels.json")),
        }
    }
}

fn median_ms(r: &BenchResult) -> f64 {
    r.median_ns / 1e6
}

fn fmt_ms(r: &BenchResult) -> String {
    crate::tune::microbench::human_ns(r.median_ns)
}

fn speedup(standard: &BenchResult, other: &BenchResult) -> f64 {
    standard.median_ns / other.median_ns.max(1e-9)
}

/// Run the grid; returns the JSON record that was (optionally) written.
/// Failing to write a requested `json_path` is an **error**, not a
/// warning — CI records the trajectory from this file, and a silently
/// missing record reads as "bench never ran".
pub fn run(opts: &KernelBenchOpts) -> Result<Json> {
    let mut table = Table::new(&[
        "shape",
        "k",
        "standard",
        "rsr",
        "rsr++",
        "rsr++ parallel",
        "batched/vec",
        "tl",
        "rsr++ speedup",
    ]);
    let mut shapes_json = Vec::new();
    let bench_opts = BenchOpts { trials: opts.reps.max(1), budget: opts.budget };

    for &(n, m) in &opts.shapes {
        let k = optimal_k_rsrpp(n);
        let mut rng = Rng::new(0xBE7C + n as u64 + ((m as u64) << 24));
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(n, -1.0, 1.0);
        let vs = rng.f32_vec(opts.batch * n, -1.0, 1.0);
        let mut out = vec![0.0f32; m];
        let mut bout = vec![0.0f32; opts.batch * m];

        // Preprocess once; cloning the index for each plan is a bulk
        // copy, not a repeat of Algorithm 1's sorting passes.
        let idx = TernaryRsrIndex::preprocess(&a, k);
        let tl = TlPlan::from_flat(&TernaryFlatPlan::from_index(&idx)?, TL_GROUP)?;
        let mut lut = tl.scratch();
        let mut rsr = TernaryRsrPlan::new(idx.clone()).expect("fresh index");
        let mut rsrpp = TernaryRsrPlusPlusPlan::new(idx.clone()).expect("fresh index");
        let mut par =
            ParallelTernaryRsrPlan::new(idx.clone(), opts.threads).expect("fresh index");
        let mut bat = BatchedTernaryRsrPlan::new(idx, opts.batch).expect("fresh index");

        let m_std = bench(bench_opts, || {
            std::hint::black_box(standard_mul_ternary_i8(&v, &a));
        });
        let m_rsr = bench(bench_opts, || rsr.execute(&v, &mut out).unwrap());
        let m_pp = bench(bench_opts, || rsrpp.execute(&v, &mut out).unwrap());
        let m_par = bench(bench_opts, || par.execute(&v, &mut out).unwrap());
        let m_bat = bench(bench_opts, || {
            bat.execute(&vs, opts.batch, &mut bout).unwrap()
        });
        let m_tl = bench(bench_opts, || {
            tl.execute(&v, &mut out, &mut lut).unwrap()
        });
        let bat_per_vec_ms = median_ms(&m_bat) / opts.batch as f64;

        table.row(&[
            format!("{n}x{m}"),
            k.to_string(),
            fmt_ms(&m_std),
            fmt_ms(&m_rsr),
            fmt_ms(&m_pp),
            fmt_ms(&m_par),
            format!("{bat_per_vec_ms:.3}ms"),
            fmt_ms(&m_tl),
            format!("{:.2}x", speedup(&m_std, &m_pp)),
        ]);

        shapes_json.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("m", Json::num(m as f64)),
            ("k", Json::num(k as f64)),
            (
                "ms",
                Json::obj(vec![
                    ("standard", Json::num(median_ms(&m_std))),
                    ("rsr", Json::num(median_ms(&m_rsr))),
                    ("rsrpp", Json::num(median_ms(&m_pp))),
                    ("rsr_parallel", Json::num(median_ms(&m_par))),
                    ("batched_per_vec", Json::num(bat_per_vec_ms)),
                    ("tl", Json::num(median_ms(&m_tl))),
                ]),
            ),
            (
                "speedup_vs_standard",
                Json::obj(vec![
                    ("rsr", Json::num(speedup(&m_std, &m_rsr))),
                    ("rsrpp", Json::num(speedup(&m_std, &m_pp))),
                    ("rsr_parallel", Json::num(speedup(&m_std, &m_par))),
                    (
                        "batched_per_vec",
                        Json::num(median_ms(&m_std) / bat_per_vec_ms.max(1e-12)),
                    ),
                    ("tl", Json::num(speedup(&m_std, &m_tl))),
                ]),
            ),
        ]));
    }

    let record = Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("reps", Json::num(opts.reps as f64)),
        ("batch", Json::num(opts.batch as f64)),
        (
            "threads",
            Json::num(if opts.threads == 0 {
                crate::util::threadpool::default_threads() as f64
            } else {
                opts.threads as f64
            }),
        ),
        ("shapes", Json::Arr(shapes_json)),
    ]);

    table.print("bench-kernels: standard vs RSR vs RSR++ vs parallel/batched/TL");
    if let Some(path) = &opts.json_path {
        std::fs::write(path, record.to_string()).map_err(|e| {
            Error::Config(format!("could not write {}: {e}", path.display()))
        })?;
        println!("\nwrote {}", path.display());
    }
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_records_speedups() {
        let opts = KernelBenchOpts {
            shapes: vec![(128, 128), (96, 160)],
            reps: 1,
            batch: 2,
            threads: 1,
            budget: Duration::from_millis(2),
            json_path: None,
        };
        let record = run(&opts).unwrap();
        let shapes = record.get("shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes.len(), 2);
        let entry = &shapes[1];
        assert_eq!(entry.get("n").unwrap().as_f64(), Some(96.0));
        assert_eq!(entry.get("m").unwrap().as_f64(), Some(160.0));
        let sp = entry.get("speedup_vs_standard").unwrap();
        assert!(sp.get("rsrpp").unwrap().as_f64().unwrap() > 0.0);
        assert!(sp.get("tl").unwrap().as_f64().unwrap() > 0.0);
        assert!(entry.get("ms").unwrap().get("tl").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn unwritable_json_path_is_an_error_not_a_warning() {
        let opts = KernelBenchOpts {
            shapes: vec![(64, 64)],
            reps: 1,
            batch: 1,
            threads: 1,
            budget: Duration::from_millis(1),
            json_path: Some(PathBuf::from("/nonexistent-dir/bench.json")),
        };
        let err = run(&opts).unwrap_err();
        assert!(err.to_string().contains("could not write"), "{err}");
    }
}
