//! `rsr bench-kernels` — the kernel-layer perf trajectory.
//!
//! Times one `v·A` (ternary, square `n×n`) through every hot-path
//! backend on a fixed size grid and writes the numbers to
//! `BENCH_kernels.json`, so the repo records its kernel performance
//! machine-readably from PR to PR (CI runs a 1-size smoke on every
//! push; the full grid is `n ∈ {1024, 4096, 8192}`).
//!
//! Backends:
//! * `standard` — dense `O(n²)` i8 multiply (the paper's baseline);
//! * `rsr` — Algorithm 2 on the flat plan;
//! * `rsrpp` — Algorithm 2 + 3 on the flat plan (SIMD-dispatched
//!   segmented sums, pairwise fold);
//! * `rsr_parallel` — RSR++ across the persistent worker pool;
//! * `batched_per_vec` — batched RSR++ (segment-major interleaved
//!   layout), reported **per vector** at the configured batch size.

use std::path::PathBuf;

use crate::bench::harness::{measure, ms, Measurement, Table};
use crate::kernels::batched::BatchedTernaryRsrPlan;
use crate::kernels::index::TernaryRsrIndex;
use crate::kernels::optimal_k::optimal_k_rsrpp;
use crate::kernels::parallel::ParallelTernaryRsrPlan;
use crate::kernels::rsr::TernaryRsrPlan;
use crate::kernels::rsrpp::TernaryRsrPlusPlusPlan;
use crate::kernels::standard::standard_mul_ternary_i8;
use crate::kernels::TernaryMatrix;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Options for one bench-kernels run.
#[derive(Debug, Clone)]
pub struct KernelBenchOpts {
    /// Matrix sizes (`n×n`) to sweep.
    pub sizes: Vec<usize>,
    /// Measured iterations per backend per size.
    pub reps: usize,
    /// Batch size for the batched backend.
    pub batch: usize,
    /// Thread count for the parallel backend (`0` → default).
    pub threads: usize,
    /// Where to write the JSON record (`None` → stdout table only).
    pub json_path: Option<PathBuf>,
}

impl Default for KernelBenchOpts {
    fn default() -> Self {
        Self {
            sizes: vec![1024, 4096, 8192],
            reps: 5,
            batch: 8,
            threads: 0,
            json_path: Some(PathBuf::from("BENCH_kernels.json")),
        }
    }
}

fn speedup(standard: &Measurement, other: &Measurement) -> f64 {
    standard.summary.mean() / other.summary.mean().max(1e-12)
}

/// Run the grid; returns the JSON record that was (optionally) written.
pub fn run(opts: &KernelBenchOpts) -> Json {
    let mut table = Table::new(&[
        "n",
        "k",
        "standard",
        "rsr",
        "rsr++",
        "rsr++ parallel",
        "batched/vec",
        "rsr++ speedup",
    ]);
    let mut sizes_json = Vec::new();

    for &n in &opts.sizes {
        let k = optimal_k_rsrpp(n);
        let mut rng = Rng::new(0xBE7C + n as u64);
        let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
        let v = rng.f32_vec(n, -1.0, 1.0);
        let vs = rng.f32_vec(opts.batch * n, -1.0, 1.0);
        let mut out = vec![0.0f32; n];
        let mut bout = vec![0.0f32; opts.batch * n];

        // Preprocess once; cloning the index for each plan is a bulk
        // copy, not a repeat of Algorithm 1's sorting passes.
        let idx = TernaryRsrIndex::preprocess(&a, k);
        let mut rsr = TernaryRsrPlan::new(idx.clone()).expect("fresh index");
        let mut rsrpp = TernaryRsrPlusPlusPlan::new(idx.clone()).expect("fresh index");
        let mut par =
            ParallelTernaryRsrPlan::new(idx.clone(), opts.threads).expect("fresh index");
        let mut bat = BatchedTernaryRsrPlan::new(idx, opts.batch).expect("fresh index");

        let reps = opts.reps.max(1);
        let m_std = measure(format!("standard n={n}"), 1, reps, || {
            std::hint::black_box(standard_mul_ternary_i8(&v, &a))
        });
        let m_rsr = measure(format!("rsr n={n}"), 1, reps, || {
            rsr.execute(&v, &mut out).unwrap()
        });
        let m_pp = measure(format!("rsr++ n={n}"), 1, reps, || {
            rsrpp.execute(&v, &mut out).unwrap()
        });
        let m_par = measure(format!("rsr++ parallel n={n}"), 1, reps, || {
            par.execute(&v, &mut out).unwrap()
        });
        let m_bat = measure(format!("batched n={n}"), 1, reps, || {
            bat.execute(&vs, opts.batch, &mut bout).unwrap()
        });
        let bat_per_vec_ms = m_bat.mean_ms() / opts.batch as f64;

        table.row(&[
            n.to_string(),
            k.to_string(),
            ms(&m_std),
            ms(&m_rsr),
            ms(&m_pp),
            ms(&m_par),
            format!("{bat_per_vec_ms:.3}ms"),
            format!("{:.2}x", speedup(&m_std, &m_pp)),
        ]);

        sizes_json.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("k", Json::num(k as f64)),
            (
                "ms",
                Json::obj(vec![
                    ("standard", Json::num(m_std.mean_ms())),
                    ("rsr", Json::num(m_rsr.mean_ms())),
                    ("rsrpp", Json::num(m_pp.mean_ms())),
                    ("rsr_parallel", Json::num(m_par.mean_ms())),
                    ("batched_per_vec", Json::num(bat_per_vec_ms)),
                ]),
            ),
            (
                "speedup_vs_standard",
                Json::obj(vec![
                    ("rsr", Json::num(speedup(&m_std, &m_rsr))),
                    ("rsrpp", Json::num(speedup(&m_std, &m_pp))),
                    ("rsr_parallel", Json::num(speedup(&m_std, &m_par))),
                    (
                        "batched_per_vec",
                        Json::num(m_std.mean_ms() / bat_per_vec_ms.max(1e-12)),
                    ),
                ]),
            ),
        ]));
    }

    let record = Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("reps", Json::num(opts.reps as f64)),
        ("batch", Json::num(opts.batch as f64)),
        (
            "threads",
            Json::num(if opts.threads == 0 {
                crate::util::threadpool::default_threads() as f64
            } else {
                opts.threads as f64
            }),
        ),
        ("sizes", Json::Arr(sizes_json)),
    ]);

    table.print("bench-kernels: standard vs RSR vs RSR++ vs parallel/batched");
    if let Some(path) = &opts.json_path {
        match std::fs::write(path, record.to_string()) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }
    record
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_records_speedups() {
        let opts = KernelBenchOpts {
            sizes: vec![128],
            reps: 1,
            batch: 2,
            threads: 1,
            json_path: None,
        };
        let record = run(&opts);
        let sizes = record.get("sizes").unwrap().as_arr().unwrap();
        assert_eq!(sizes.len(), 1);
        let entry = &sizes[0];
        assert_eq!(entry.get("n").unwrap().as_f64(), Some(128.0));
        let sp = entry.get("speedup_vs_standard").unwrap();
        assert!(sp.get("rsrpp").unwrap().as_f64().unwrap() > 0.0);
    }
}
