//! Workload generation shared by the experiment drivers: the paper's
//! size ranges, seeded random matrices and vectors.

use crate::kernels::{BinaryMatrix, TernaryMatrix};
use crate::util::rng::Rng;

/// Canonical bench seed (all experiments are reproducible).
pub const SEED: u64 = 0x5EED_2025;

/// Fig 4's size range: full = `2^11..=2^16`, quick = `2^11..=2^13`.
pub fn fig4_sizes(full: bool) -> Vec<usize> {
    let max_pow = if full { 16 } else { 13 };
    (11..=max_pow).map(|p| 1usize << p).collect()
}

/// Fig 11's NumPy-comparison range: full = `2^11..=2^15` (paper),
/// quick = `2^11..=2^12` — capped by what the AOT artifacts provide.
pub fn fig11_sizes(full: bool) -> Vec<usize> {
    let max_pow = if full { 12 } else { 11 };
    (11..=max_pow).map(|p| 1usize << p).collect()
}

/// Fig 12's GPU range: `2^11..=2^14`.
pub fn fig12_sizes() -> Vec<usize> {
    (11..=14).map(|p| 1usize << p).collect()
}

/// Random binary matrix + input vector for size `n` (density 0.5,
/// values uniform in [-1, 1) like the paper's random inputs).
pub fn binary_workload(n: usize, seed: u64) -> (BinaryMatrix, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let b = BinaryMatrix::random(n, n, 0.5, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);
    (b, v)
}

/// Random ternary matrix + input vector.
pub fn ternary_workload(n: usize, seed: u64) -> (TernaryMatrix, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);
    (a, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_ranges_match_paper() {
        assert_eq!(fig4_sizes(true), vec![2048, 4096, 8192, 16384, 32768, 65536]);
        assert_eq!(fig4_sizes(false).last(), Some(&8192));
        assert_eq!(fig12_sizes(), vec![2048, 4096, 8192, 16384]);
    }

    #[test]
    fn workloads_are_seeded() {
        let (a1, v1) = binary_workload(64, 1);
        let (a2, v2) = binary_workload(64, 1);
        assert_eq!(a1, a2);
        assert_eq!(v1, v2);
        let (a3, _) = binary_workload(64, 2);
        assert_ne!(a1, a3);
    }
}
