//! The benchmark harness regenerating every table and figure in the
//! paper's evaluation section (see DESIGN.md §5 for the index).
//!
//! criterion is unavailable offline; [`harness`] provides warmup +
//! repeated timing + summary statistics + aligned table printing, and
//! each `cargo bench` target (`rust/benches/*.rs`, `harness = false`)
//! calls one function from [`experiments`].
//!
//! Default runs use trimmed size ranges so `cargo bench` completes in
//! minutes; set `BENCH_FULL=1` for the paper's full ranges
//! (`n = 2^11..2^16` in Fig 4).

pub mod experiments;
pub mod gpusim;
pub mod harness;
pub mod workloads;

/// True when the full (paper-range) benches were requested.
pub fn full_mode() -> bool {
    std::env::var("BENCH_FULL").map(|v| v == "1" || v == "true").unwrap_or(false)
}
