//! GPU cost-model simulator — the DESIGN.md substitution for the
//! paper's NVIDIA Tesla T4 experiments (Table 1, Fig 12).
//!
//! No GPU exists in this environment, so we model the two PyTorch
//! implementations the paper timed, with a roofline latency model
//! calibrated to T4-class hardware (320 GB/s, ~10µs eager-mode launch
//! overhead). Two comparisons appear in the paper and they have
//! different baselines — we model each explicitly:
//!
//! **Table 1 (BitLinear level, ~2.5×).** The Standard path is
//! PyTorch's 1.58-bit `BitLinear.forward`: read ternary weights
//! (int8, `n²` bytes), dequantize+scale to fp16 (write `2n²`, read
//! back `2n²`), then a cuBLAS GEMV — three kernels. The RSR path is a
//! single batched matmul over the *precomputed* `N = M × Bin_[k]`
//! tensor (App E.2 — same element count as the weight matrix, fp16,
//! `2n²` bytes, one kernel). Asymptotic ratio
//! `(3/e_ew + 2/e_gemv) / (2/e_gemv) ≈ 2.7`, matching the paper's
//! 1.7–2.7×.
//!
//! **Fig 12 (bare vecmat, ≤2× and shrinking).** The baseline is a bare
//! cuBLAS GEMV (no dequant pass). RSR's advantage there comes from the
//! batched block layout keeping the working set cache/coalescing
//! friendly at small `n`; the paper itself observes the advantage
//! *decays with n* as application-level overhead grows ("the overhead
//! of application-level optimization reducing the speedup"). We model
//! that with an effective-bandwidth factor `β(n) = β₀·√(n/2^11)`
//! calibrated to the paper's endpoints (≈2× at 2^11 → ≈1.1× at 2^14).
//!
//! Absolute µs come from calibration, not measurement; who-wins, the
//! rough factor, and the trend in `n` are the reproduction targets.
//! [`measured_parallel_speedup`] additionally runs the *real*
//! tensorized kernel across CPU threads so the "block decomposition
//! parallelizes" claim is backed by a measurement on this machine.

use std::time::Duration;

/// T4-class device parameters (fp16 data path).
#[derive(Debug, Clone, Copy)]
pub struct GpuParams {
    /// Peak memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Kernel launch + eager-mode dispatch overhead per kernel.
    pub launch_overhead: Duration,
    /// Elementwise-kernel efficiency (fraction of peak BW).
    pub elementwise_eff: f64,
    /// GEMV efficiency (fraction of peak BW for batch-1 matmul).
    pub gemv_eff: f64,
    /// Fig 12 RSR effective-bandwidth factor at n = 2^11 (β₀ < 1 means
    /// *faster* than the plain GEMV — cache-resident index blocks).
    pub rsr_beta0: f64,
}

impl Default for GpuParams {
    fn default() -> Self {
        Self {
            mem_bw: 320e9,
            launch_overhead: Duration::from_micros(10),
            elementwise_eff: 0.55,
            gemv_eff: 0.65,
            rsr_beta0: 0.31,
        }
    }
}

fn gemv_secs(p: &GpuParams, bytes: f64) -> f64 {
    bytes / (p.mem_bw * p.gemv_eff)
}

fn elementwise_secs(p: &GpuParams, bytes: f64) -> f64 {
    bytes / (p.mem_bw * p.elementwise_eff)
}

// ---------------------------------------------------------------- Table 1

/// Standard `BitLinear.forward` latency (dequant + GEMV, 3 kernels).
pub fn standard_latency(p: &GpuParams, n_in: usize, n_out: usize) -> Duration {
    let nn = n_in as f64 * n_out as f64;
    // int8 read + fp16 write, then fp16 read by the GEMV.
    let dequant = elementwise_secs(p, 3.0 * nn);
    let gemv = gemv_secs(p, 2.0 * nn);
    Duration::from_secs_f64(dequant + gemv) + 3 * p.launch_overhead
}

/// RSR tensorized latency (single bmm over precomputed `N`, fp16).
pub fn rsr_latency(p: &GpuParams, n_in: usize, n_out: usize) -> Duration {
    let nn = n_in as f64 * n_out as f64;
    Duration::from_secs_f64(gemv_secs(p, 2.0 * nn)) + p.launch_overhead
}

/// A model layer shape (for Table 1's per-model latency).
#[derive(Debug, Clone, Copy)]
pub struct LayerShape {
    /// Input width.
    pub n_in: usize,
    /// Output width.
    pub n_out: usize,
}

/// Average per-BitLinear-call latency across a model's layer shapes —
/// what Table 1 reports (µs per fully-connected forward).
pub fn model_latency_us(p: &GpuParams, shapes: &[LayerShape], rsr: bool) -> f64 {
    let total: f64 = shapes
        .iter()
        .map(|s| {
            let d = if rsr {
                rsr_latency(p, s.n_in, s.n_out)
            } else {
                standard_latency(p, s.n_in, s.n_out)
            };
            d.as_secs_f64()
        })
        .sum();
    total / shapes.len() as f64 * 1e6
}

// ---------------------------------------------------------------- Fig 12

/// Fig 12 baseline: bare cuBLAS GEMV over fp16 ternary weights.
pub fn vecmat_standard_latency(p: &GpuParams, n: usize) -> Duration {
    let nn = n as f64 * n as f64;
    Duration::from_secs_f64(gemv_secs(p, 2.0 * nn)) + p.launch_overhead
}

/// Fig 12 RSR: batched one-hot form with the calibrated decaying
/// advantage `β(n) = β₀ · √(n / 2^11)` (capped at 1.05 — the paper
/// never shows RSR losing in the measured range).
pub fn vecmat_rsr_latency(p: &GpuParams, n: usize) -> Duration {
    let nn = n as f64 * n as f64;
    let beta = (p.rsr_beta0 * (n as f64 / 2048.0).sqrt()).min(1.05);
    Duration::from_secs_f64(gemv_secs(p, 2.0 * nn) * beta) + 2 * p.launch_overhead
}

/// Simulated Fig 12 speedup for a square `n×n` product.
pub fn speedup(p: &GpuParams, n: usize) -> f64 {
    vecmat_standard_latency(p, n).as_secs_f64() / vecmat_rsr_latency(p, n).as_secs_f64()
}

/// Measured CPU-thread scaling of the real tensorized kernel — the
/// hardware-independent evidence behind the simulated parallel claim.
/// Returns (threads, mean_ms) pairs.
pub fn measured_parallel_speedup(n: usize, k: usize, threads: &[usize]) -> Vec<(usize, f64)> {
    use crate::bench::harness::measure;
    use crate::bench::workloads::binary_workload;
    use crate::kernels::tensorized::TensorizedIndex;

    let (b, v) = binary_workload(n, 0xA11E1);
    let idx = TensorizedIndex::preprocess(&b, k);
    let mut out = vec![0.0f32; n];
    threads
        .iter()
        .map(|&t| {
            let m = measure(format!("tensorized t={t}"), 1, 5, || {
                idx.execute_parallel(&v, &mut out, t).unwrap();
            });
            (t, m.mean_ms())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_speedup_in_band_and_shrinking() {
        let p = GpuParams::default();
        let s11 = speedup(&p, 1 << 11);
        let s14 = speedup(&p, 1 << 14);
        // Paper: ~2x at 2^11, approaching 1x by 2^14.
        assert!((1.5..2.6).contains(&s11), "s11 = {s11}");
        assert!((0.95..1.5).contains(&s14), "s14 = {s14}");
        assert!(s11 > s14, "advantage must shrink with n");
    }

    #[test]
    fn table1_magnitudes_match_paper_band() {
        // Paper Table 1: Standard 364–560µs, RSR 206–225µs (~2.5x).
        let p = GpuParams::default();
        let llama = [
            LayerShape { n_in: 4096, n_out: 4096 },
            LayerShape { n_in: 4096, n_out: 8192 },
            LayerShape { n_in: 8192, n_out: 4096 },
        ];
        let std_us = model_latency_us(&p, &llama, false);
        let rsr_us = model_latency_us(&p, &llama, true);
        assert!((150.0..900.0).contains(&std_us), "std {std_us}µs");
        assert!((80.0..400.0).contains(&rsr_us), "rsr {rsr_us}µs");
        let ratio = std_us / rsr_us;
        assert!((1.7..3.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn latencies_scale_with_size() {
        let p = GpuParams::default();
        assert!(standard_latency(&p, 4096, 4096) > standard_latency(&p, 1024, 1024));
        assert!(rsr_latency(&p, 4096, 4096) > rsr_latency(&p, 1024, 1024));
        assert!(vecmat_rsr_latency(&p, 4096) > vecmat_rsr_latency(&p, 2048));
    }

    #[test]
    fn measured_parallel_speedup_runs() {
        let results = measured_parallel_speedup(512, 6, &[1, 2]);
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|&(_, ms)| ms > 0.0));
    }
}
