//! Request/response types flowing through the serving stack.

use std::time::{Duration, Instant};

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// Prompt token ids (tokenized at the server edge).
    pub prompt: Vec<u32>,
    /// Maximum tokens to generate.
    pub max_new_tokens: usize,
    /// Arrival timestamp (set at admission).
    pub arrival: Instant,
}

impl Request {
    /// New request stamped with the current time.
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Self { id, prompt, max_new_tokens, arrival: Instant::now() }
    }
}

/// Per-request latency breakdown.
#[derive(Debug, Clone, Default)]
pub struct Timing {
    /// Queue admission → worker pickup.
    pub queue: Duration,
    /// Prompt prefill (all prompt tokens through the model).
    pub prefill: Duration,
    /// Token generation.
    pub decode: Duration,
}

impl Timing {
    /// End-to-end latency.
    pub fn total(&self) -> Duration {
        self.queue + self.prefill + self.decode
    }
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    /// Echoed request id.
    pub id: u64,
    /// Generated token ids.
    pub tokens: Vec<u32>,
    /// Latency breakdown.
    pub timing: Timing,
    /// Error message when generation failed (tokens empty).
    pub error: Option<String>,
}

impl Response {
    /// Successful response.
    pub fn ok(id: u64, tokens: Vec<u32>, timing: Timing) -> Self {
        Self { id, tokens, timing, error: None }
    }

    /// Failed response.
    pub fn err(id: u64, msg: impl Into<String>) -> Self {
        Self { id, tokens: Vec::new(), timing: Timing::default(), error: Some(msg.into()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_total_adds_phases() {
        let t = Timing {
            queue: Duration::from_millis(1),
            prefill: Duration::from_millis(2),
            decode: Duration::from_millis(3),
        };
        assert_eq!(t.total(), Duration::from_millis(6));
    }

    #[test]
    fn response_constructors() {
        let ok = Response::ok(7, vec![1, 2], Timing::default());
        assert!(ok.error.is_none());
        let err = Response::err(8, "boom");
        assert_eq!(err.error.as_deref(), Some("boom"));
        assert!(err.tokens.is_empty());
    }
}
