//! Request/response types flowing through the serving stack.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A shared cancellation flag for one request.
///
/// The connection thread sets it when it observes the client
/// disconnect; the engine checks it at admission, at slot assignment,
/// and between decode steps, so abandoned work frees its slot within
/// one lockstep step. Clones share the flag (a `Request` clone — e.g.
/// the router's per-replica submit attempts — stays cancellable
/// through any copy).
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// Fresh, un-cancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the request cancelled. Idempotent.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// True once [`cancel`](Self::cancel) has been called on any clone.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// Prompt token ids (tokenized at the server edge).
    pub prompt: Vec<u32>,
    /// Maximum tokens to generate.
    pub max_new_tokens: usize,
    /// Arrival timestamp (set at admission).
    pub arrival: Instant,
    /// Absolute completion deadline. `None` = no deadline (the exact
    /// pre-deadline behavior). Enforced at admission, slot assignment,
    /// and between decode steps.
    pub deadline: Option<Instant>,
    /// Cancellation flag shared with the connection thread.
    pub cancel: CancelToken,
    /// Execution attempts consumed by worker-panic retries (the
    /// supervision quarantine: one retry, then poisoned). Internal —
    /// never set by clients.
    pub attempts: u32,
    /// Stream tokens as they are sampled: the engine emits one
    /// [`Frame::Token`] per generated token in addition to the
    /// terminal [`Frame::Done`]. `false` (the default) is the exact
    /// single-response behavior.
    pub stream: bool,
    /// Fair-admission lane key (the server stamps one per connection;
    /// `0` = the shared default lane). Requests from different lanes
    /// are admitted round-robin so one chatty client cannot starve
    /// others.
    pub client: u64,
}

impl Request {
    /// New request stamped with the current time, no deadline.
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            arrival: Instant::now(),
            deadline: None,
            cancel: CancelToken::new(),
            attempts: 0,
            stream: false,
            client: 0,
        }
    }

    /// Set an absolute deadline `budget` from the arrival timestamp.
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(self.arrival + budget);
        self
    }

    /// Request per-token streaming frames.
    pub fn with_stream(mut self, stream: bool) -> Self {
        self.stream = stream;
        self
    }

    /// Key the request into a fair-admission lane.
    pub fn with_client(mut self, client: u64) -> Self {
        self.client = client;
        self
    }

    /// True once the deadline (if any) has passed.
    pub fn deadline_expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// Per-request latency breakdown.
#[derive(Debug, Clone, Default)]
pub struct Timing {
    /// Queue admission → worker pickup.
    pub queue: Duration,
    /// Prompt prefill (all prompt tokens through the model).
    pub prefill: Duration,
    /// Token generation.
    pub decode: Duration,
}

impl Timing {
    /// End-to-end latency.
    pub fn total(&self) -> Duration {
        self.queue + self.prefill + self.decode
    }
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    /// Echoed request id.
    pub id: u64,
    /// Generated token ids.
    pub tokens: Vec<u32>,
    /// Latency breakdown.
    pub timing: Timing,
    /// Error message when generation failed (tokens empty).
    pub error: Option<String>,
    /// Stable machine-readable code for the error (`None` on success;
    /// see [`crate::error::Error::code`] for the table). This is what
    /// the wire's `code` field carries — clients match on it instead
    /// of on message prose.
    pub code: Option<&'static str>,
}

impl Response {
    /// Successful response.
    pub fn ok(id: u64, tokens: Vec<u32>, timing: Timing) -> Self {
        Self { id, tokens, timing, error: None, code: None }
    }

    /// Failed response with the catch-all `internal` code.
    pub fn err(id: u64, msg: impl Into<String>) -> Self {
        Self::err_coded(id, msg, "internal")
    }

    /// Failed response carrying a stable wire code.
    pub fn err_coded(id: u64, msg: impl Into<String>, code: &'static str) -> Self {
        Self {
            id,
            tokens: Vec::new(),
            timing: Timing::default(),
            error: Some(msg.into()),
            code: Some(code),
        }
    }
}

/// One message from the engine to a request's waiter.
///
/// Non-streaming requests produce exactly one `Done`. A streaming
/// request ([`Request::stream`]) additionally produces one `Token` per
/// sampled token, in order, before the terminal `Done` — multi-frame
/// per request id through the same channel and
/// [`ResponseHub`](crate::serving::server::ResponseHub) routing.
#[derive(Debug, Clone)]
pub enum Frame {
    /// One sampled token of a streaming request.
    Token {
        /// Echoed request id.
        id: u64,
        /// 0-based position of this token in the generated sequence.
        index: usize,
        /// The sampled token id.
        token: u32,
    },
    /// The request's single terminal response (always sent, streaming
    /// or not).
    Done(Response),
}

impl Frame {
    /// The request id this frame belongs to.
    pub fn id(&self) -> u64 {
        match self {
            Frame::Token { id, .. } => *id,
            Frame::Done(r) => r.id,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_total_adds_phases() {
        let t = Timing {
            queue: Duration::from_millis(1),
            prefill: Duration::from_millis(2),
            decode: Duration::from_millis(3),
        };
        assert_eq!(t.total(), Duration::from_millis(6));
    }

    #[test]
    fn response_constructors() {
        let ok = Response::ok(7, vec![1, 2], Timing::default());
        assert!(ok.error.is_none());
        assert!(ok.code.is_none());
        let err = Response::err(8, "boom");
        assert_eq!(err.error.as_deref(), Some("boom"));
        assert!(err.tokens.is_empty());
        assert_eq!(err.code, Some("internal"));
        let coded = Response::err_coded(9, "late", "deadline_exceeded");
        assert_eq!(coded.code, Some("deadline_exceeded"));
    }

    #[test]
    fn frame_ids_route_by_request() {
        let t = Frame::Token { id: 3, index: 0, token: 42 };
        assert_eq!(t.id(), 3);
        let d = Frame::Done(Response::ok(4, vec![], Timing::default()));
        assert_eq!(d.id(), 4);
    }

    #[test]
    fn stream_and_client_builders() {
        let r = Request::new(1, vec![1], 4);
        assert!(!r.stream);
        assert_eq!(r.client, 0);
        let r = r.with_stream(true).with_client(9);
        assert!(r.stream);
        assert_eq!(r.client, 9);
    }

    #[test]
    fn cancel_token_is_shared_across_clones() {
        let r = Request::new(1, vec![1], 4);
        let clone = r.clone();
        assert!(!clone.cancel.is_cancelled());
        r.cancel.cancel();
        assert!(clone.cancel.is_cancelled(), "clones must share the flag");
    }

    #[test]
    fn deadline_expiry() {
        let r = Request::new(1, vec![1], 4);
        assert!(!r.deadline_expired(), "no deadline never expires");
        let r = r.with_deadline(Duration::from_secs(3600));
        assert!(!r.deadline_expired());
        let r = Request::new(2, vec![1], 4).with_deadline(Duration::ZERO);
        assert!(r.deadline_expired());
    }
}
