//! TCP front end: newline-delimited JSON protocol over `std::net`.
//!
//! Request line:  `{"id": 1, "prompt": "text", "max_new": 16,
//!                  "deadline_ms": 2000}`   (`deadline_ms` optional)
//! Response line: `{"id": 1, "text": "...", "tokens": [..],
//!                  "queue_us": .., "prefill_us": .., "decode_us": ..}`
//! Error line:    `{"id": 1, "error": "..."}`
//!
//! One OS thread per connection (tokio is unavailable offline; at the
//! request rates batch-1 CPU inference sustains, thread-per-conn is
//! not the bottleneck — see DESIGN.md §Substitutions).
//!
//! # Lifecycle at the edge
//!
//! `deadline_ms` (or the server-wide `--default-deadline-ms`) stamps an
//! absolute deadline on the request before it is routed. While a
//! request is in flight, the connection thread polls its socket with a
//! non-destructive peek; observing EOF sets the request's
//! [`CancelToken`](super::request::CancelToken), and the engine retires
//! the abandoned slot within one lockstep step. The thread then keeps
//! waiting for the terminal response the engine guarantees — the hard
//! timeout below is a defense line, not the cancellation mechanism.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::request::Request;
use super::router::Router;
use crate::error::{Error, Result};
use crate::model::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::obs::{render_prometheus, ReplicaScrape};

/// Hard ceiling on waiting for a response when the request carries no
/// deadline — the pre-deadline behavior.
const NO_DEADLINE_WAIT: Duration = Duration::from_secs(120);

/// Slack past a request's deadline before the connection thread stops
/// waiting: the engine retires an expired request at its next
/// between-step checkpoint, so the terminal response lands within one
/// step of the deadline — 5 s covers the slowest plausible step.
const DEADLINE_GRACE: Duration = Duration::from_secs(5);

/// Routes completed responses from every engine to the connection
/// thread that registered the request id. One dispatcher thread per
/// engine owns that engine's receiver, so concurrent connections never
/// steal each other's responses.
pub struct ResponseHub {
    waiters: Arc<std::sync::Mutex<std::collections::HashMap<u64, mpsc::Sender<super::request::Response>>>>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ResponseHub {
    /// Spawn one dispatcher per engine in the router.
    pub fn start(router: &Arc<Router>) -> Self {
        let waiters: Arc<
            std::sync::Mutex<
                std::collections::HashMap<u64, mpsc::Sender<super::request::Response>>,
            >,
        > = Arc::default();
        let stop = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();
        for i in 0..router.replicas() {
            let router = Arc::clone(router);
            let waiters = Arc::clone(&waiters);
            let stop = Arc::clone(&stop);
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    if let Some(resp) =
                        router.engine(i).recv_timeout(Duration::from_millis(100))
                    {
                        let tx = waiters.lock().unwrap().remove(&resp.id);
                        if let Some(tx) = tx {
                            let _ = tx.send(resp);
                        }
                    }
                }
            }));
        }
        Self { waiters, stop, threads }
    }

    /// Register interest in a request id; returns the receiver the
    /// response will arrive on. Must be called BEFORE submit to avoid
    /// a lost-wakeup race.
    pub fn register(&self, id: u64) -> mpsc::Receiver<super::request::Response> {
        let (tx, rx) = mpsc::channel();
        self.waiters.lock().unwrap().insert(id, tx);
        rx
    }

    /// Remove a registration (request failed to submit).
    pub fn unregister(&self, id: u64) {
        self.waiters.lock().unwrap().remove(&id);
    }

    /// Waiters currently registered (tests: leak detection — after a
    /// drain this must be 0, or some request path forgot to
    /// unregister/deliver).
    pub fn waiter_count(&self) -> usize {
        self.waiters.lock().unwrap().len()
    }

    /// Stop dispatchers.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Static facts the `status` wire command reports alongside the live
/// gauges: what is being served and from which artifacts. Filled by
/// `rsr serve` from its resolved flags.
#[derive(Clone, Debug, Default)]
pub struct ServerIdentity {
    /// Model description (config summary or generation seed).
    pub model: String,
    /// `--plans` directory, when serving packed `.rsrz` artifacts.
    pub plan_dir: Option<String>,
    /// `--profile` path, when serving under a `.rsrt` tuned profile.
    pub tune_profile: Option<String>,
}

/// The TCP server: accepts connections, parses request lines, routes
/// them, and writes response lines. Lines carrying a `cmd` key are
/// control commands (`metrics` / `status` / `trace`) answered from the
/// engines' observability surface instead of the inference path.
pub struct Server {
    router: Arc<Router>,
    hub: Arc<ResponseHub>,
    /// Internal request ids: one global counter, one increment per
    /// request — ids are unique for the lifetime of the process (no
    /// per-connection block allocation to collide past).
    next_id: Arc<AtomicU64>,
    /// Deadline stamped on requests that don't carry `deadline_ms`
    /// (the `--default-deadline-ms` flag). `None` = unbounded, the
    /// pre-deadline behavior.
    default_deadline: Option<Duration>,
    /// Identity reported by the `status` command.
    identity: Arc<ServerIdentity>,
}

impl Server {
    /// Server over a router (starts the response hub).
    pub fn new(router: Arc<Router>) -> Self {
        let hub = Arc::new(ResponseHub::start(&router));
        Self {
            router,
            hub,
            next_id: Arc::new(AtomicU64::new(1)),
            default_deadline: None,
            identity: Arc::new(ServerIdentity::default()),
        }
    }

    /// Stamp `budget` as the deadline on every request that doesn't
    /// set its own `deadline_ms` (the `--default-deadline-ms` flag).
    pub fn with_default_deadline(mut self, budget: Duration) -> Self {
        self.default_deadline = Some(budget);
        self
    }

    /// Attach the identity the `status` command reports.
    pub fn with_identity(mut self, identity: ServerIdentity) -> Self {
        self.identity = Arc::new(identity);
        self
    }

    /// The server's response hub (tests: waiter-leak assertions).
    pub fn hub(&self) -> &Arc<ResponseHub> {
        &self.hub
    }

    /// Bind and serve until `stop` is set. Returns the bound address
    /// through `on_bound` (lets tests use port 0).
    pub fn serve(
        &self,
        addr: &str,
        stop: Arc<AtomicBool>,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            // Reap finished connection threads — a long-lived server
            // must not grow one parked handle per connection served.
            conns.retain(|c| !c.is_finished());
            match listener.accept() {
                Ok((stream, _)) => {
                    let router = Arc::clone(&self.router);
                    let hub = Arc::clone(&self.hub);
                    let next_id = Arc::clone(&self.next_id);
                    let deadline = self.default_deadline;
                    let identity = Arc::clone(&self.identity);
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_connection(
                            stream, router, hub, next_id, deadline, identity,
                        );
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }
}

fn handle_connection(
    stream: TcpStream,
    router: Arc<Router>,
    hub: Arc<ResponseHub>,
    next_id: Arc<AtomicU64>,
    default_deadline: Option<Duration>,
    identity: Arc<ServerIdentity>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream.try_clone()?);
    let tokenizer = Tokenizer::new();

    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let json = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let reply =
                    Json::obj(vec![("error", Json::str(format!("bad json: {e}")))]);
                writeln!(writer, "{}", reply.to_string())?;
                continue;
            }
        };
        // Control commands bypass the inference path: they read the
        // engines' observability surface and answer immediately.
        if let Some(cmd) = json.get("cmd").and_then(|c| c.as_str()) {
            let reply = control_response(cmd, &json, &router, &identity);
            writeln!(writer, "{}", reply.to_string())?;
            continue;
        }
        let internal_id = next_id.fetch_add(1, Ordering::Relaxed);
        match parse_request(&json, internal_id, &tokenizer, default_deadline) {
            Ok((client_id, request)) => {
                let reply = match route_and_wait(&router, &hub, request, Some(&stream)) {
                    Ok(resp) => render_response(client_id, &resp, &tokenizer),
                    Err(e) => Json::obj(vec![
                        ("id", Json::num(client_id as f64)),
                        ("error", Json::str(e.to_string())),
                    ]),
                };
                writeln!(writer, "{}", reply.to_string())?;
            }
            Err(e) => {
                let reply = Json::obj(vec![("error", Json::str(e.to_string()))]);
                writeln!(writer, "{}", reply.to_string())?;
            }
        }
    }
    Ok(())
}

/// Everything one replica contributes to a scrape.
fn scrape_replicas(router: &Router) -> Vec<ReplicaScrape> {
    (0..router.replicas())
        .map(|i| {
            let e = router.engine(i);
            ReplicaScrape {
                replica: i,
                snapshot: e.snapshot(),
                queue_depth: e.queue_depth() as u64,
                inflight: e.inflight() as u64,
                live_slots: e.live_slots() as u64,
                heartbeat_ms: e.heartbeat_age().as_millis() as u64,
            }
        })
        .collect()
}

/// Server uptime: the oldest replica's engine uptime (replicas start
/// together at serve time).
fn uptime_s(router: &Router) -> f64 {
    (0..router.replicas())
        .map(|i| router.engine(i).uptime().as_secs_f64())
        .fold(0.0, f64::max)
}

/// Per-replica gauge object shared by `metrics` and `status`.
fn replica_gauges(router: &Router, i: usize) -> Vec<(&'static str, Json)> {
    let e = router.engine(i);
    let pool = e.kv_pool();
    let pages_total = if pool.is_bounded() { pool.total_pages() } else { 0 };
    vec![
        ("replica", Json::num(i as f64)),
        ("queue_depth", Json::num(e.queue_depth() as f64)),
        ("inflight", Json::num(e.inflight() as f64)),
        ("live_slots", Json::num(e.live_slots() as f64)),
        // KV pool occupancy (0 total = unbounded, no budget in force).
        ("kv_pages_in_use", Json::num(pool.pages_in_use() as f64)),
        ("kv_pages_total", Json::num(pages_total as f64)),
        ("heartbeat_ms", Json::num(e.heartbeat_age().as_millis() as f64)),
    ]
}

/// Answer one control command (`metrics` / `status` / `trace`).
fn control_response(
    cmd: &str,
    json: &Json,
    router: &Router,
    identity: &ServerIdentity,
) -> Json {
    match cmd {
        "metrics" => {
            if json.get("format").and_then(|f| f.as_str()) == Some("prom") {
                let text = render_prometheus(uptime_s(router), &scrape_replicas(router));
                Json::obj(vec![("prom", Json::str(text))])
            } else {
                let replicas: Vec<Json> = (0..router.replicas())
                    .map(|i| {
                        let mut fields = replica_gauges(router, i);
                        fields.push(("metrics", router.engine(i).snapshot()));
                        Json::obj(fields)
                    })
                    .collect();
                Json::obj(vec![
                    ("uptime_s", Json::num(uptime_s(router))),
                    ("replicas", Json::Arr(replicas)),
                ])
            }
        }
        "status" => {
            let replicas: Vec<Json> = (0..router.replicas())
                .map(|i| Json::obj(replica_gauges(router, i)))
                .collect();
            let opt = |v: &Option<String>| match v {
                Some(s) => Json::str(s.clone()),
                None => Json::Null,
            };
            Json::obj(vec![
                ("model", Json::str(identity.model.clone())),
                ("plan_dir", opt(&identity.plan_dir)),
                ("tune_profile", opt(&identity.tune_profile)),
                ("uptime_s", Json::num(uptime_s(router))),
                ("replicas", Json::Arr(replicas)),
            ])
        }
        "trace" => {
            let mut enabled = false;
            let replicas: Vec<Json> = (0..router.replicas())
                .map(|i| {
                    let t = match router.engine(i).trace_snapshot() {
                        Some(t) => {
                            enabled = true;
                            t
                        }
                        None => Json::Null,
                    };
                    Json::obj(vec![("replica", Json::num(i as f64)), ("trace", t)])
                })
                .collect();
            Json::obj(vec![
                ("enabled", Json::Bool(enabled)),
                ("replicas", Json::Arr(replicas)),
            ])
        }
        other => Json::obj(vec![(
            "error",
            Json::str(format!(
                "unknown cmd {other:?} (expected metrics, status or trace)"
            )),
        )]),
    }
}

fn parse_request(
    json: &Json,
    internal_id: u64,
    tokenizer: &Tokenizer,
    default_deadline: Option<Duration>,
) -> Result<(u64, Request)> {
    let client_id = json
        .get("id")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| Error::Serving("missing id".into()))? as u64;
    let prompt_text = json
        .get("prompt")
        .and_then(|x| x.as_str())
        .ok_or_else(|| Error::Serving("missing prompt".into()))?;
    if prompt_text.is_empty() {
        return Err(Error::Serving("empty prompt".into()));
    }
    let max_new = json.get("max_new").and_then(|x| x.as_f64()).unwrap_or(16.0) as usize;
    if max_new == 0 || max_new > 4096 {
        return Err(Error::Serving("max_new out of range".into()));
    }
    let prompt = tokenizer.encode_with_bos(prompt_text);
    let mut request = Request::new(internal_id, prompt, max_new);
    match json.get("deadline_ms").and_then(|x| x.as_f64()) {
        Some(ms) if (1.0..=86_400_000.0).contains(&ms) => {
            request = request.with_deadline(Duration::from_millis(ms as u64));
        }
        Some(_) => return Err(Error::Serving("deadline_ms out of range".into())),
        None => {
            if let Some(budget) = default_deadline {
                request = request.with_deadline(budget);
            }
        }
    }
    Ok((client_id, request))
}

/// True when the client side of `stream` is gone (orderly EOF or hard
/// error). Non-destructive: a nonblocking 1-byte peek, with blocking
/// mode restored before returning — `O_NONBLOCK` is a property of the
/// shared socket, and the connection's line reader needs it off.
fn client_disconnected(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut buf = [0u8; 1];
    let gone = match stream.peek(&mut buf) {
        Ok(0) => true,  // EOF: client closed its write side
        Ok(_) => false, // pipelined request bytes waiting
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true, // reset / broken
    };
    // `|` (not `||`): the restore must run even when the peer is gone.
    gone | stream.set_nonblocking(false).is_err()
}

fn route_and_wait(
    router: &Router,
    hub: &ResponseHub,
    request: Request,
    conn: Option<&TcpStream>,
) -> Result<super::request::Response> {
    let want_id = request.id;
    let cancel = request.cancel.clone();
    let deadline = request.deadline;
    // Register BEFORE submitting so the dispatcher can never observe
    // the response before the waiter exists.
    let rx = hub.register(want_id);
    if let Err(e) = router.submit(request) {
        hub.unregister(want_id);
        return Err(e);
    }
    // Poll in short ticks so a client disconnect converts to
    // cancellation within ~50 ms. After cancelling we keep waiting:
    // the engine guarantees exactly one terminal response per admitted
    // request, and consuming it here keeps the hub waiter-free. The
    // hard stop is a defense line for a wedged engine, not the
    // cancellation mechanism.
    let hard_stop = match deadline {
        Some(d) => d + DEADLINE_GRACE,
        None => Instant::now() + NO_DEADLINE_WAIT,
    };
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(resp) => return Ok(resp),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                hub.unregister(want_id);
                return Err(Error::Serving("response dispatcher gone".into()));
            }
        }
        if !cancel.is_cancelled() {
            if let Some(s) = conn {
                if client_disconnected(s) {
                    cancel.cancel();
                }
            }
        }
        if Instant::now() >= hard_stop {
            hub.unregister(want_id);
            return Err(Error::Serving("timeout waiting for response".into()));
        }
    }
}

fn render_response(
    client_id: u64,
    resp: &super::request::Response,
    tokenizer: &Tokenizer,
) -> Json {
    if let Some(err) = &resp.error {
        return Json::obj(vec![
            ("id", Json::num(client_id as f64)),
            ("error", Json::str(err.clone())),
        ]);
    }
    Json::obj(vec![
        ("id", Json::num(client_id as f64)),
        ("text", Json::str(tokenizer.decode(&resp.tokens))),
        (
            "tokens",
            Json::nums(resp.tokens.iter().map(|&t| t as f64).collect::<Vec<_>>()),
        ),
        ("queue_us", Json::num(resp.timing.queue.as_micros() as f64)),
        ("prefill_us", Json::num(resp.timing.prefill.as_micros() as f64)),
        ("decode_us", Json::num(resp.timing.decode.as_micros() as f64)),
    ])
}

/// A minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a server.
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr)? })
    }

    /// Send one prompt and wait for the reply line.
    pub fn request(&mut self, id: u64, prompt: &str, max_new: usize) -> Result<Json> {
        self.request_with(id, prompt, max_new, None)
    }

    /// Send one prompt with an optional per-request deadline
    /// (milliseconds of total budget; the server sheds or retires the
    /// request with a `deadline exceeded` error once it expires).
    pub fn request_with(
        &mut self,
        id: u64,
        prompt: &str,
        max_new: usize,
        deadline_ms: Option<u64>,
    ) -> Result<Json> {
        let mut fields = vec![
            ("id", Json::num(id as f64)),
            ("prompt", Json::str(prompt)),
            ("max_new", Json::num(max_new as f64)),
        ];
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::num(ms as f64)));
        }
        let req = Json::obj(fields);
        writeln!(self.stream, "{}", req.to_string())?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Json::parse(&line).map_err(Error::Serving)
    }

    /// Send a raw line (failure-injection tests).
    pub fn send_raw(&mut self, line: &str) -> Result<Json> {
        writeln!(self.stream, "{line}")?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut out = String::new();
        reader.read_line(&mut out)?;
        Json::parse(&out).map_err(Error::Serving)
    }
}
