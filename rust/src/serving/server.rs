//! TCP front end: newline-delimited JSON protocol over `std::net`.
//!
//! Request line:  `{"id": 1, "prompt": "text", "max_new": 16}`
//! Response line: `{"id": 1, "text": "...", "tokens": [..],
//!                  "queue_us": .., "prefill_us": .., "decode_us": ..}`
//! Error line:    `{"id": 1, "error": "..."}`
//!
//! One OS thread per connection (tokio is unavailable offline; at the
//! request rates batch-1 CPU inference sustains, thread-per-conn is
//! not the bottleneck — see DESIGN.md §Substitutions).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::request::Request;
use super::router::Router;
use crate::error::{Error, Result};
use crate::model::tokenizer::Tokenizer;
use crate::util::json::Json;

/// Routes completed responses from every engine to the connection
/// thread that registered the request id. One dispatcher thread per
/// engine owns that engine's receiver, so concurrent connections never
/// steal each other's responses.
pub struct ResponseHub {
    waiters: Arc<std::sync::Mutex<std::collections::HashMap<u64, std::sync::mpsc::Sender<super::request::Response>>>>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ResponseHub {
    /// Spawn one dispatcher per engine in the router.
    pub fn start(router: &Arc<Router>) -> Self {
        let waiters: Arc<
            std::sync::Mutex<
                std::collections::HashMap<u64, std::sync::mpsc::Sender<super::request::Response>>,
            >,
        > = Arc::default();
        let stop = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();
        for i in 0..router.replicas() {
            let router = Arc::clone(router);
            let waiters = Arc::clone(&waiters);
            let stop = Arc::clone(&stop);
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    if let Some(resp) =
                        router.engine(i).recv_timeout(Duration::from_millis(100))
                    {
                        let tx = waiters.lock().unwrap().remove(&resp.id);
                        if let Some(tx) = tx {
                            let _ = tx.send(resp);
                        }
                    }
                }
            }));
        }
        Self { waiters, stop, threads }
    }

    /// Register interest in a request id; returns the receiver the
    /// response will arrive on. Must be called BEFORE submit to avoid
    /// a lost-wakeup race.
    pub fn register(&self, id: u64) -> std::sync::mpsc::Receiver<super::request::Response> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.waiters.lock().unwrap().insert(id, tx);
        tx_len_hint(&rx);
        rx
    }

    /// Remove a registration (request failed to submit).
    pub fn unregister(&self, id: u64) {
        self.waiters.lock().unwrap().remove(&id);
    }

    /// Stop dispatchers.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn tx_len_hint<T>(_rx: &std::sync::mpsc::Receiver<T>) {}

/// The TCP server: accepts connections, parses request lines, routes
/// them, and writes response lines.
pub struct Server {
    router: Arc<Router>,
    hub: Arc<ResponseHub>,
    next_id: AtomicU64,
}

impl Server {
    /// Server over a router (starts the response hub).
    pub fn new(router: Arc<Router>) -> Self {
        let hub = Arc::new(ResponseHub::start(&router));
        Self { router, hub, next_id: AtomicU64::new(1) }
    }

    /// Bind and serve until `stop` is set. Returns the bound address
    /// through `on_bound` (lets tests use port 0).
    pub fn serve(
        &self,
        addr: &str,
        stop: Arc<AtomicBool>,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let router = Arc::clone(&self.router);
                    let hub = Arc::clone(&self.hub);
                    let next_id = self.next_id.fetch_add(1_000_000, Ordering::Relaxed);
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_connection(stream, router, hub, next_id);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }
}

fn handle_connection(
    stream: TcpStream,
    router: Arc<Router>,
    hub: Arc<ResponseHub>,
    id_base: u64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let tokenizer = Tokenizer::new();
    let mut local_id = 0u64;

    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        local_id += 1;
        let internal_id = id_base + local_id;
        match parse_request_line(&line, internal_id, &tokenizer) {
            Ok((client_id, request)) => {
                let reply = match route_and_wait(&router, &hub, request) {
                    Ok(resp) => render_response(client_id, &resp, &tokenizer),
                    Err(e) => {
                        Json::obj(vec![
                            ("id", Json::num(client_id as f64)),
                            ("error", Json::str(e.to_string())),
                        ])
                    }
                };
                writeln!(writer, "{}", reply.to_string())?;
            }
            Err(e) => {
                let reply = Json::obj(vec![("error", Json::str(e.to_string()))]);
                writeln!(writer, "{}", reply.to_string())?;
            }
        }
    }
    Ok(())
}

fn parse_request_line(
    line: &str,
    internal_id: u64,
    tokenizer: &Tokenizer,
) -> Result<(u64, Request)> {
    let json = Json::parse(line).map_err(|e| Error::Serving(format!("bad json: {e}")))?;
    let client_id = json
        .get("id")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| Error::Serving("missing id".into()))? as u64;
    let prompt_text = json
        .get("prompt")
        .and_then(|x| x.as_str())
        .ok_or_else(|| Error::Serving("missing prompt".into()))?;
    if prompt_text.is_empty() {
        return Err(Error::Serving("empty prompt".into()));
    }
    let max_new = json.get("max_new").and_then(|x| x.as_f64()).unwrap_or(16.0) as usize;
    if max_new == 0 || max_new > 4096 {
        return Err(Error::Serving("max_new out of range".into()));
    }
    let prompt = tokenizer.encode_with_bos(prompt_text);
    Ok((client_id, Request::new(internal_id, prompt, max_new)))
}

fn route_and_wait(
    router: &Router,
    hub: &ResponseHub,
    request: Request,
) -> Result<super::request::Response> {
    let want_id = request.id;
    // Register BEFORE submitting so the dispatcher can never observe
    // the response before the waiter exists.
    let rx = hub.register(want_id);
    if let Err(e) = router.submit(request) {
        hub.unregister(want_id);
        return Err(e);
    }
    rx.recv_timeout(Duration::from_secs(120))
        .map_err(|_| Error::Serving("timeout waiting for response".into()))
}

fn render_response(
    client_id: u64,
    resp: &super::request::Response,
    tokenizer: &Tokenizer,
) -> Json {
    if let Some(err) = &resp.error {
        return Json::obj(vec![
            ("id", Json::num(client_id as f64)),
            ("error", Json::str(err.clone())),
        ]);
    }
    Json::obj(vec![
        ("id", Json::num(client_id as f64)),
        ("text", Json::str(tokenizer.decode(&resp.tokens))),
        (
            "tokens",
            Json::nums(resp.tokens.iter().map(|&t| t as f64).collect::<Vec<_>>()),
        ),
        ("queue_us", Json::num(resp.timing.queue.as_micros() as f64)),
        ("prefill_us", Json::num(resp.timing.prefill.as_micros() as f64)),
        ("decode_us", Json::num(resp.timing.decode.as_micros() as f64)),
    ])
}

/// A minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a server.
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr)? })
    }

    /// Send one prompt and wait for the reply line.
    pub fn request(&mut self, id: u64, prompt: &str, max_new: usize) -> Result<Json> {
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("prompt", Json::str(prompt)),
            ("max_new", Json::num(max_new as f64)),
        ]);
        writeln!(self.stream, "{}", req.to_string())?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Json::parse(&line).map_err(Error::Serving)
    }

    /// Send a raw line (failure-injection tests).
    pub fn send_raw(&mut self, line: &str) -> Result<Json> {
        writeln!(self.stream, "{line}")?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut out = String::new();
        reader.read_line(&mut out)?;
        Json::parse(&out).map_err(Error::Serving)
    }
}
