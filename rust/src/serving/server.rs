//! TCP front end: newline-delimited JSON, wire protocol v2.
//!
//! Request line:  `{"id": 1, "prompt": "text", "max_new": 16,
//!                  "deadline_ms": 2000, "stream": true}`
//!                (`deadline_ms` and `stream` optional)
//! Response line: `{"id": 1, "text": "...", "tokens": [..],
//!                  "queue_us": .., "prefill_us": .., "decode_us": ..}`
//! Error line:    `{"id": 1, "error": "...", "code": "..."}`
//!
//! A request with `"stream": true` receives one frame per sampled
//! token — `{"event":"token","id":1,"index":0,"token":104,"text":"h"}`
//! — followed by a terminal `{"event":"done", ...}` frame carrying the
//! exact fields of the non-streaming response (or error) line. The
//! concatenation of every token frame's `text` is byte-identical to
//! the done frame's `text` (incremental UTF-8 decode buffers split
//! multi-byte characters; a trailing incomplete character flushes as
//! one final `text`-only frame). Requests without `"stream"` — every
//! v1 client — get the exact single-line v1 shape; `code` on error
//! lines is the one additive v2 field (see ARCHITECTURE.md §Wire
//! protocol v2 for the stable code table).
//!
//! One OS thread per connection (tokio is unavailable offline; at the
//! request rates batch-1 CPU inference sustains, thread-per-conn is
//! not the bottleneck — see DESIGN.md §Substitutions).
//!
//! # Lifecycle at the edge
//!
//! `deadline_ms` (or the server-wide `--default-deadline-ms`) stamps an
//! absolute deadline on the request before it is routed. While a
//! request is in flight, the connection thread polls its socket with a
//! non-destructive peek; observing EOF sets the request's
//! [`CancelToken`](super::request::CancelToken), and the engine retires
//! the abandoned slot within one lockstep step. The thread then keeps
//! waiting for the terminal response the engine guarantees — the hard
//! timeout below is a defense line, not the cancellation mechanism.
//!
//! # Fairness and drain
//!
//! Every connection gets a process-unique lane key stamped into its
//! requests ([`Request::client`]), so the engines' fair-admission
//! queues round-robin across connections. The `drain` control command
//! (or SIGTERM in `rsr serve`) flips every replica into drain mode:
//! queued and in-flight work — streams included — runs to completion,
//! new submissions are refused with code `draining`, and
//! [`Server::serve`] returns once every replica reads `drained()`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::request::{Frame, Request, Response};
use super::router::Router;
use crate::error::{Error, Result};
use crate::model::tokenizer::{StreamDecoder, Tokenizer};
use crate::util::json::Json;
use crate::util::obs::{render_prometheus, ReplicaScrape};

pub use super::client::Client;

/// Hard ceiling on waiting for a response when the request carries no
/// deadline — the pre-deadline behavior.
const NO_DEADLINE_WAIT: Duration = Duration::from_secs(120);

/// Slack past a request's deadline before the connection thread stops
/// waiting: the engine retires an expired request at its next
/// between-step checkpoint, so the terminal response lands within one
/// step of the deadline — 5 s covers the slowest plausible step.
const DEADLINE_GRACE: Duration = Duration::from_secs(5);

/// Routes frames from every engine to the connection thread that
/// registered the request id. One dispatcher thread per engine owns
/// that engine's receiver, so concurrent connections never steal each
/// other's frames. Since protocol v2 a request id may receive many
/// frames ([`Frame::Token`] per sampled token of a streaming request)
/// before its single terminal [`Frame::Done`] — token frames look the
/// waiter up without removing it; `Done` removes it.
pub struct ResponseHub {
    waiters: Arc<std::sync::Mutex<std::collections::HashMap<u64, mpsc::Sender<Frame>>>>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ResponseHub {
    /// Spawn one dispatcher per engine in the router.
    pub fn start(router: &Arc<Router>) -> Self {
        let waiters: Arc<
            std::sync::Mutex<std::collections::HashMap<u64, mpsc::Sender<Frame>>>,
        > = Arc::default();
        let stop = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();
        for i in 0..router.replicas() {
            let router = Arc::clone(router);
            let waiters = Arc::clone(&waiters);
            let stop = Arc::clone(&stop);
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    if let Some(frame) =
                        router.engine(i).recv_frame_timeout(Duration::from_millis(100))
                    {
                        let id = frame.id();
                        let terminal = matches!(frame, Frame::Done(_));
                        let mut g = waiters.lock().unwrap();
                        let tx = if terminal {
                            g.remove(&id)
                        } else {
                            g.get(&id).cloned()
                        };
                        drop(g);
                        if let Some(tx) = tx {
                            let _ = tx.send(frame);
                        }
                    }
                }
            }));
        }
        Self { waiters, stop, threads }
    }

    /// Register interest in a request id; returns the receiver the
    /// request's frames will arrive on. Must be called BEFORE submit to
    /// avoid a lost-wakeup race.
    pub fn register(&self, id: u64) -> mpsc::Receiver<Frame> {
        let (tx, rx) = mpsc::channel();
        self.waiters.lock().unwrap().insert(id, tx);
        rx
    }

    /// Remove a registration (request failed to submit).
    pub fn unregister(&self, id: u64) {
        self.waiters.lock().unwrap().remove(&id);
    }

    /// Waiters currently registered (tests: leak detection — after a
    /// drain this must be 0, or some request path forgot to
    /// unregister/deliver).
    pub fn waiter_count(&self) -> usize {
        self.waiters.lock().unwrap().len()
    }

    /// Stop dispatchers.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Static facts the `status` wire command reports alongside the live
/// gauges: what is being served and from which artifacts. Filled by
/// `rsr serve` from its resolved flags.
#[derive(Clone, Debug, Default)]
pub struct ServerIdentity {
    /// Model description (config summary or generation seed).
    pub model: String,
    /// `--plans` directory, when serving packed `.rsrz` artifacts.
    pub plan_dir: Option<String>,
    /// `--profile` path, when serving under a `.rsrt` tuned profile.
    pub tune_profile: Option<String>,
}

/// The TCP server: accepts connections, parses request lines, routes
/// them, and writes response lines. Lines carrying a `cmd` key are
/// control commands (`metrics` / `status` / `trace` / `drain`)
/// answered from the engines' observability surface instead of the
/// inference path.
pub struct Server {
    router: Arc<Router>,
    hub: Arc<ResponseHub>,
    /// Internal request ids: one global counter, one increment per
    /// request — ids are unique for the lifetime of the process (no
    /// per-connection block allocation to collide past).
    next_id: Arc<AtomicU64>,
    /// Fair-admission lane keys: one per connection, stamped into every
    /// request the connection submits so the engines' weighted
    /// round-robin treats each connection as one client.
    next_client: Arc<AtomicU64>,
    /// Set by the `drain` control command or by
    /// [`drain_handle`](Self::drain_handle) (SIGTERM bridge in
    /// `rsr serve`). Never cleared: draining is the beginning of the
    /// end of the process.
    draining: Arc<AtomicBool>,
    /// Deadline stamped on requests that don't carry `deadline_ms`
    /// (the `--default-deadline-ms` flag). `None` = unbounded, the
    /// pre-deadline behavior.
    default_deadline: Option<Duration>,
    /// Identity reported by the `status` command.
    identity: Arc<ServerIdentity>,
}

impl Server {
    /// Server over a router (starts the response hub).
    pub fn new(router: Arc<Router>) -> Self {
        let hub = Arc::new(ResponseHub::start(&router));
        Self {
            router,
            hub,
            next_id: Arc::new(AtomicU64::new(1)),
            next_client: Arc::new(AtomicU64::new(1)),
            draining: Arc::new(AtomicBool::new(false)),
            default_deadline: None,
            identity: Arc::new(ServerIdentity::default()),
        }
    }

    /// Stamp `budget` as the deadline on every request that doesn't
    /// set its own `deadline_ms` (the `--default-deadline-ms` flag).
    pub fn with_default_deadline(mut self, budget: Duration) -> Self {
        self.default_deadline = Some(budget);
        self
    }

    /// Attach the identity the `status` command reports.
    pub fn with_identity(mut self, identity: ServerIdentity) -> Self {
        self.identity = Arc::new(identity);
        self
    }

    /// The server's response hub (tests: waiter-leak assertions).
    pub fn hub(&self) -> &Arc<ResponseHub> {
        &self.hub
    }

    /// Handle an external party (the SIGTERM bridge in `rsr serve`)
    /// can set to start a drain — equivalent to the `drain` wire
    /// command. The accept loop notices within one tick.
    pub fn drain_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.draining)
    }

    /// Flip every replica into drain mode (idempotent).
    fn begin_drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
        for i in 0..self.router.replicas() {
            self.router.engine(i).set_draining();
        }
    }

    /// Bind and serve until `stop` is set or a drain completes (every
    /// replica draining with zero in-flight work). Returns the bound
    /// address through `on_bound` (lets tests use port 0).
    pub fn serve(
        &self,
        addr: &str,
        stop: Arc<AtomicBool>,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            // Reap finished connection threads — a long-lived server
            // must not grow one parked handle per connection served.
            conns.retain(|c| !c.is_finished());
            if self.draining.load(Ordering::Relaxed) {
                // The flag may have been set externally through
                // `drain_handle` — make sure the engines know.
                self.begin_drain();
                if (0..self.router.replicas()).all(|i| self.router.engine(i).drained()) {
                    stop.store(true, Ordering::Relaxed);
                    break;
                }
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let router = Arc::clone(&self.router);
                    let hub = Arc::clone(&self.hub);
                    let next_id = Arc::clone(&self.next_id);
                    let client_key = self.next_client.fetch_add(1, Ordering::Relaxed);
                    let deadline = self.default_deadline;
                    let identity = Arc::clone(&self.identity);
                    let draining = Arc::clone(&self.draining);
                    let conn_stop = Arc::clone(&stop);
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_connection(
                            stream, router, hub, next_id, client_key, deadline,
                            identity, draining, conn_stop,
                        );
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    stream: TcpStream,
    router: Arc<Router>,
    hub: Arc<ResponseHub>,
    next_id: Arc<AtomicU64>,
    client_key: u64,
    default_deadline: Option<Duration>,
    identity: Arc<ServerIdentity>,
    draining: Arc<AtomicBool>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let tokenizer = Tokenizer::new();

    // Short read timeout so the loop can notice a server stop between
    // lines; partial bytes of a slow line persist in `buf` across
    // WouldBlock retries, so no request bytes are ever dropped.
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut buf = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut buf) {
            Ok(0) => break, // EOF: client closed the connection
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Partial bytes of a slow line stay in `buf`; retry.
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let line = std::mem::take(&mut buf);
        if line.trim().is_empty() {
            continue;
        }
        let json = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let reply = Json::obj(vec![
                    ("error", Json::str(format!("bad json: {e}"))),
                    ("code", Json::str("bad_request")),
                ]);
                writeln!(writer, "{}", reply.to_string())?;
                continue;
            }
        };
        // Control commands bypass the inference path: they read the
        // engines' observability surface and answer immediately.
        if let Some(cmd) = json.get("cmd").and_then(|c| c.as_str()) {
            let reply = control_response(cmd, &json, &router, &identity, &draining);
            writeln!(writer, "{}", reply.to_string())?;
            continue;
        }
        let internal_id = next_id.fetch_add(1, Ordering::Relaxed);
        match parse_request(&json, internal_id, client_key, &tokenizer, default_deadline)
        {
            Ok((client_id, request)) if request.stream => {
                route_and_stream(
                    &router, &hub, request, client_id, &stream, &mut writer, &tokenizer,
                )?;
            }
            Ok((client_id, request)) => {
                let reply = match route_and_wait(&router, &hub, request, Some(&stream)) {
                    Ok(resp) => render_response(client_id, &resp, &tokenizer),
                    Err(e) => Json::obj(vec![
                        ("id", Json::num(client_id as f64)),
                        ("error", Json::str(e.to_string())),
                        ("code", Json::str(e.code())),
                    ]),
                };
                writeln!(writer, "{}", reply.to_string())?;
            }
            Err(e) => {
                let reply = Json::obj(vec![
                    ("error", Json::str(e.to_string())),
                    ("code", Json::str(e.code())),
                ]);
                writeln!(writer, "{}", reply.to_string())?;
            }
        }
    }
    Ok(())
}

/// Everything one replica contributes to a scrape.
fn scrape_replicas(router: &Router) -> Vec<ReplicaScrape> {
    (0..router.replicas())
        .map(|i| {
            let e = router.engine(i);
            ReplicaScrape {
                replica: i,
                snapshot: e.snapshot(),
                queue_depth: e.queue_depth() as u64,
                inflight: e.inflight() as u64,
                live_slots: e.live_slots() as u64,
                heartbeat_ms: e.heartbeat_age().as_millis() as u64,
            }
        })
        .collect()
}

/// Server uptime: the oldest replica's engine uptime (replicas start
/// together at serve time).
fn uptime_s(router: &Router) -> f64 {
    (0..router.replicas())
        .map(|i| router.engine(i).uptime().as_secs_f64())
        .fold(0.0, f64::max)
}

/// Per-replica gauge object shared by `metrics` and `status`.
fn replica_gauges(router: &Router, i: usize) -> Vec<(&'static str, Json)> {
    let e = router.engine(i);
    let pool = e.kv_pool();
    let pages_total = if pool.is_bounded() { pool.total_pages() } else { 0 };
    // Median time-to-first-token, from the engine's ttft phase
    // histogram — the router's least-loaded pick and operators both
    // read per-replica responsiveness from here.
    let ttft_p50 = e
        .snapshot()
        .get("ttft_us")
        .and_then(|t| t.get("p50_us"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    vec![
        ("replica", Json::num(i as f64)),
        ("queue_depth", Json::num(e.queue_depth() as f64)),
        ("inflight", Json::num(e.inflight() as f64)),
        ("live_slots", Json::num(e.live_slots() as f64)),
        // KV pool occupancy (0 total = unbounded, no budget in force).
        ("kv_pages_in_use", Json::num(pool.pages_in_use() as f64)),
        ("kv_pages_total", Json::num(pages_total as f64)),
        ("heartbeat_ms", Json::num(e.heartbeat_age().as_millis() as f64)),
        ("draining", Json::Bool(e.is_draining())),
        ("ttft_p50_us", Json::num(ttft_p50)),
    ]
}

/// Answer one control command (`metrics` / `status` / `trace` /
/// `drain`).
fn control_response(
    cmd: &str,
    json: &Json,
    router: &Router,
    identity: &ServerIdentity,
    draining: &AtomicBool,
) -> Json {
    match cmd {
        "drain" => {
            // Flip the server flag; the accept loop propagates it to
            // every engine within one tick. Set the engines here too so
            // the reply already reflects drain mode.
            draining.store(true, Ordering::Relaxed);
            let mut inflight = 0usize;
            for i in 0..router.replicas() {
                let e = router.engine(i);
                e.set_draining();
                inflight += e.load();
            }
            Json::obj(vec![
                ("draining", Json::Bool(true)),
                ("inflight", Json::num(inflight as f64)),
            ])
        }
        "metrics" => {
            if json.get("format").and_then(|f| f.as_str()) == Some("prom") {
                let text = render_prometheus(uptime_s(router), &scrape_replicas(router));
                Json::obj(vec![("prom", Json::str(text))])
            } else {
                let replicas: Vec<Json> = (0..router.replicas())
                    .map(|i| {
                        let mut fields = replica_gauges(router, i);
                        fields.push(("metrics", router.engine(i).snapshot()));
                        Json::obj(fields)
                    })
                    .collect();
                Json::obj(vec![
                    ("uptime_s", Json::num(uptime_s(router))),
                    ("replicas", Json::Arr(replicas)),
                ])
            }
        }
        "status" => {
            let replicas: Vec<Json> = (0..router.replicas())
                .map(|i| Json::obj(replica_gauges(router, i)))
                .collect();
            let opt = |v: &Option<String>| match v {
                Some(s) => Json::str(s.clone()),
                None => Json::Null,
            };
            Json::obj(vec![
                ("model", Json::str(identity.model.clone())),
                ("plan_dir", opt(&identity.plan_dir)),
                ("tune_profile", opt(&identity.tune_profile)),
                ("uptime_s", Json::num(uptime_s(router))),
                ("replicas", Json::Arr(replicas)),
            ])
        }
        "trace" => {
            let mut enabled = false;
            let replicas: Vec<Json> = (0..router.replicas())
                .map(|i| {
                    let t = match router.engine(i).trace_snapshot() {
                        Some(t) => {
                            enabled = true;
                            t
                        }
                        None => Json::Null,
                    };
                    Json::obj(vec![("replica", Json::num(i as f64)), ("trace", t)])
                })
                .collect();
            Json::obj(vec![
                ("enabled", Json::Bool(enabled)),
                ("replicas", Json::Arr(replicas)),
            ])
        }
        other => Json::obj(vec![
            (
                "error",
                Json::str(format!(
                    "unknown cmd {other:?} (expected metrics, status, trace or drain)"
                )),
            ),
            ("code", Json::str("bad_request")),
        ]),
    }
}

fn parse_request(
    json: &Json,
    internal_id: u64,
    client_key: u64,
    tokenizer: &Tokenizer,
    default_deadline: Option<Duration>,
) -> Result<(u64, Request)> {
    let client_id = json
        .get("id")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| Error::BadRequest("missing id".into()))? as u64;
    let prompt_text = json
        .get("prompt")
        .and_then(|x| x.as_str())
        .ok_or_else(|| Error::BadRequest("missing prompt".into()))?;
    if prompt_text.is_empty() {
        return Err(Error::BadRequest("empty prompt".into()));
    }
    let max_new = json.get("max_new").and_then(|x| x.as_f64()).unwrap_or(16.0) as usize;
    if max_new == 0 || max_new > 4096 {
        return Err(Error::BadRequest("max_new out of range".into()));
    }
    let stream = matches!(json.get("stream"), Some(Json::Bool(true)));
    let prompt = tokenizer.encode_with_bos(prompt_text);
    let mut request = Request::new(internal_id, prompt, max_new)
        .with_client(client_key)
        .with_stream(stream);
    match json.get("deadline_ms").and_then(|x| x.as_f64()) {
        Some(ms) if (1.0..=86_400_000.0).contains(&ms) => {
            request = request.with_deadline(Duration::from_millis(ms as u64));
        }
        Some(_) => return Err(Error::BadRequest("deadline_ms out of range".into())),
        None => {
            if let Some(budget) = default_deadline {
                request = request.with_deadline(budget);
            }
        }
    }
    Ok((client_id, request))
}

/// True when the client side of `stream` is gone (orderly EOF or hard
/// error). Non-destructive: a nonblocking 1-byte peek, with blocking
/// mode restored before returning — `O_NONBLOCK` is a property of the
/// shared socket, and the connection's line reader needs it off.
fn client_disconnected(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut buf = [0u8; 1];
    let gone = match stream.peek(&mut buf) {
        Ok(0) => true,  // EOF: client closed its write side
        Ok(_) => false, // pipelined request bytes waiting
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true, // reset / broken
    };
    // `|` (not `||`): the restore must run even when the peer is gone.
    gone | stream.set_nonblocking(false).is_err()
}

fn route_and_wait(
    router: &Router,
    hub: &ResponseHub,
    request: Request,
    conn: Option<&TcpStream>,
) -> Result<Response> {
    let want_id = request.id;
    let cancel = request.cancel.clone();
    let deadline = request.deadline;
    // Register BEFORE submitting so the dispatcher can never observe
    // the response before the waiter exists.
    let rx = hub.register(want_id);
    if let Err(e) = router.submit(request) {
        hub.unregister(want_id);
        return Err(e);
    }
    // Poll in short ticks so a client disconnect converts to
    // cancellation within ~50 ms. After cancelling we keep waiting:
    // the engine guarantees exactly one terminal response per admitted
    // request, and consuming it here keeps the hub waiter-free. The
    // hard stop is a defense line for a wedged engine, not the
    // cancellation mechanism.
    let hard_stop = match deadline {
        Some(d) => d + DEADLINE_GRACE,
        None => Instant::now() + NO_DEADLINE_WAIT,
    };
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(Frame::Done(resp)) => return Ok(resp),
            // Non-streaming requests never produce token frames, but
            // ignoring them here keeps the waiter alive regardless.
            Ok(Frame::Token { .. }) => continue,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                hub.unregister(want_id);
                return Err(Error::Unavailable("response dispatcher gone".into()));
            }
        }
        if !cancel.is_cancelled() {
            if let Some(s) = conn {
                if client_disconnected(s) {
                    cancel.cancel();
                }
            }
        }
        if Instant::now() >= hard_stop {
            hub.unregister(want_id);
            return Err(Error::Serving("timeout waiting for response".into()));
        }
    }
}

/// Stream one request: register, submit, then forward every token
/// frame to the wire as it arrives, terminated by a `done` frame with
/// the exact fields of the non-streaming reply. On mid-stream client
/// disconnect the request is cancelled but the loop keeps draining
/// frames until the terminal one, keeping the hub waiter-free and the
/// slot accounting exact.
fn route_and_stream(
    router: &Router,
    hub: &ResponseHub,
    request: Request,
    client_id: u64,
    stream: &TcpStream,
    writer: &mut TcpStream,
    tokenizer: &Tokenizer,
) -> Result<()> {
    let want_id = request.id;
    let cancel = request.cancel.clone();
    let deadline = request.deadline;
    let rx = hub.register(want_id);
    if let Err(e) = router.submit(request) {
        hub.unregister(want_id);
        let reply = Json::obj(vec![
            ("event", Json::str("done")),
            ("id", Json::num(client_id as f64)),
            ("error", Json::str(e.to_string())),
            ("code", Json::str(e.code())),
        ]);
        writeln!(writer, "{}", reply.to_string())?;
        return Ok(());
    }
    let hard_stop = match deadline {
        Some(d) => d + DEADLINE_GRACE,
        None => Instant::now() + NO_DEADLINE_WAIT,
    };
    // Incremental UTF-8: token frames carry exactly the bytes a
    // non-streaming reply would decode, split per token (multi-byte
    // characters buffer until complete).
    let mut dec = StreamDecoder::new();
    // After the peer vanishes we stop writing but keep draining frames
    // until the engine's guaranteed terminal response.
    let mut peer_gone = false;
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(Frame::Token { index, token, .. }) => {
                if peer_gone {
                    continue;
                }
                let text = dec.push(token);
                let frame = Json::obj(vec![
                    ("event", Json::str("token")),
                    ("id", Json::num(client_id as f64)),
                    ("index", Json::num(index as f64)),
                    ("token", Json::num(token as f64)),
                    ("text", Json::str(text)),
                ]);
                if writeln!(writer, "{}", frame.to_string()).is_err() {
                    peer_gone = true;
                    cancel.cancel();
                }
            }
            Ok(Frame::Done(resp)) => {
                if !peer_gone {
                    // Flush a buffered incomplete character (the lossy
                    // replacement the batch decode would emit) as one
                    // final text-only frame.
                    let tail = dec.finish();
                    if !tail.is_empty() {
                        let frame = Json::obj(vec![
                            ("event", Json::str("token")),
                            ("id", Json::num(client_id as f64)),
                            ("text", Json::str(tail)),
                        ]);
                        let _ = writeln!(writer, "{}", frame.to_string());
                    }
                    let mut done = render_response(client_id, &resp, tokenizer);
                    if let Json::Obj(map) = &mut done {
                        map.insert("event".into(), Json::str("done"));
                    }
                    writeln!(writer, "{}", done.to_string())?;
                }
                return Ok(());
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                hub.unregister(want_id);
                if !peer_gone {
                    let reply = Json::obj(vec![
                        ("event", Json::str("done")),
                        ("id", Json::num(client_id as f64)),
                        ("error", Json::str("unavailable: response dispatcher gone")),
                        ("code", Json::str("unavailable")),
                    ]);
                    let _ = writeln!(writer, "{}", reply.to_string());
                }
                return Ok(());
            }
        }
        if !peer_gone && !cancel.is_cancelled() && client_disconnected(stream) {
            peer_gone = true;
            cancel.cancel();
        }
        if Instant::now() >= hard_stop {
            hub.unregister(want_id);
            if !peer_gone {
                let reply = Json::obj(vec![
                    ("event", Json::str("done")),
                    ("id", Json::num(client_id as f64)),
                    ("error", Json::str("timeout waiting for response")),
                    ("code", Json::str("internal")),
                ]);
                let _ = writeln!(writer, "{}", reply.to_string());
            }
            return Ok(());
        }
    }
}

fn render_response(client_id: u64, resp: &Response, tokenizer: &Tokenizer) -> Json {
    if let Some(err) = &resp.error {
        return Json::obj(vec![
            ("id", Json::num(client_id as f64)),
            ("error", Json::str(err.clone())),
            ("code", Json::str(resp.code.unwrap_or("internal"))),
        ]);
    }
    Json::obj(vec![
        ("id", Json::num(client_id as f64)),
        ("text", Json::str(tokenizer.decode(&resp.tokens))),
        (
            "tokens",
            Json::nums(resp.tokens.iter().map(|&t| t as f64).collect::<Vec<_>>()),
        ),
        ("queue_us", Json::num(resp.timing.queue.as_micros() as f64)),
        ("prefill_us", Json::num(resp.timing.prefill.as_micros() as f64)),
        ("decode_us", Json::num(resp.timing.decode.as_micros() as f64)),
    ])
}
