//! Layer-3 serving coordinator: the production wrapper around the
//! RSR-backed ternary transformer.
//!
//! Architecture (vLLM-router-like, scaled to this crate):
//!
//! ```text
//!  TCP clients ──► server (line protocol, thread per conn)
//!                     │
//!                  router (least-loaded across engines)
//!                     │
//!              bounded request queue (backpressure)
//!                     │
//!                  batcher (idle pickup + non-blocking poll top-up)
//!                     │
//!               scheduler (prefill-priority admission)
//!                     │
//!        engine workers (one Transformer instance each) running
//!        CONTINUOUS BATCHED DECODE: a slot map of up to `max_slots`
//!        sequences stepped in lockstep — finished slots retire,
//!        queued requests join mid-flight, and every BitLinear reads
//!        its shared plan index once per step instead of once per
//!        sequence (`max_slots = 1` → the sequential per-request path)
//!                     │
//!                  metrics (latency histograms, counters,
//!                  batch occupancy, aggregate tokens/sec)
//! ```
//!
//! The paper's setting is single-vector matmuls (one token per forward
//! pass); continuous batching extends its core amortization across
//! concurrent sequences (the batched RSR kernels read the preprocessed
//! index once per lockstep step), while replica workers add
//! parallelism — matching §5.3's CPU deployment scenario under the
//! ROADMAP's heavy-traffic direction.
//!
//! # Request lifecycle (see ARCHITECTURE.md §Request lifecycle)
//!
//! ```text
//!  queued ──► assigned ──► generating ──► done
//!    │            │             │
//!    └────────────┴─────────────┴──► shed | deadline | cancelled
//!                               │
//!                 (worker panic)└──► quarantined ──► retried once
//!                                        │
//!                                        └──► poisoned
//! ```
//!
//! Deadlines and cancellation are checked at three points: admission
//! ([`InferenceEngine::submit`]), slot assignment, and between decode
//! steps. Worker panics are caught per step; the worker rebuilds its
//! model and the victim requests get terminal error responses — a
//! request that panics the worker twice is poisoned, never retried
//! again. The router skips replicas whose heartbeat is staler than
//! `--replica-stall-ms`.
//!
//! tokio is unavailable offline; everything is `std::thread` +
//! `std::net` + condvar queues (see DESIGN.md §Substitutions).

pub mod batcher;
pub mod client;
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use client::{Client, ErrorCode, Outcome, RequestBuilder};
pub use engine::{EngineConfig, InferenceEngine};
#[cfg(any(test, feature = "fault-inject"))]
pub use engine::FaultPlan;
pub use request::{CancelToken, Frame, Request, Response};
pub use router::Router;
pub use server::{ResponseHub, Server};
