//! Layer-3 serving coordinator: the production wrapper around the
//! RSR-backed ternary transformer.
//!
//! Architecture (vLLM-router-like, scaled to this crate):
//!
//! ```text
//!  TCP clients ──► server (line protocol, thread per conn)
//!                     │
//!                  router (least-loaded across engines)
//!                     │
//!              bounded request queue (backpressure)
//!                     │
//!                  batcher (size + deadline dynamic batching)
//!                     │
//!               scheduler (prefill-priority admission)
//!                     │
//!        engine workers (one Transformer instance each;
//!        per-request prefill → decode; RSR/RSR++ backends)
//!                     │
//!                  metrics (latency histograms, counters)
//! ```
//!
//! The paper's setting is single-vector matmuls (one token per forward
//! pass), so batching here amortizes *dispatch and queueing*, and
//! parallelism comes from engine workers each running vector–matrix
//! products — matching §5.3's CPU deployment scenario.
//!
//! tokio is unavailable offline; everything is `std::thread` +
//! `std::net` + condvar queues (see DESIGN.md §Substitutions).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use engine::{EngineConfig, InferenceEngine};
pub use request::{Request, Response};
pub use router::Router;
pub use server::Server;
