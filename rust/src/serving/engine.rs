//! The inference engine: worker threads each owning a `Transformer`
//! instance, pulling batches from the shared queue, running
//! prefill → decode per request, and reporting completions.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{BoundedQueue, PushError};
use super::request::{Request, Response, Timing};
use super::scheduler::{schedule, Policy};
use crate::error::{Error, Result};
use crate::kernels::Backend;
use crate::model::sampler::Sampler;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::runtime::plan_store::PlanStore;
use crate::tune::candidates::TunedBackend;
use crate::tune::profile::TuneProfile;
use crate::util::rng::Rng;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (each with its own `Transformer`).
    pub workers: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Batching policy.
    pub batch: BatchPolicy,
    /// Scheduling policy within a batch.
    pub schedule: Policy,
    /// Multiply backend for the model.
    pub backend: Backend,
    /// Blocking parameter (0 → analytic optimum).
    pub k: usize,
    /// Directory of `.rsrz` plan artifacts (the `rsr pack` output).
    /// When set — and the backend is an RSR plan backend — workers load
    /// preprocessed plans from disk instead of running Algorithm 1 at
    /// startup. When `None`, plans are still built only once per
    /// process and shared across workers via the [`PlanStore`].
    pub plan_dir: Option<PathBuf>,
    /// `.rsrt` tuning profile (the `rsr tune` output). When set — RSR++
    /// backend only, like `plan_dir` — every layer materializes with
    /// its measured `(k, backend)` winner instead of the analytic
    /// defaults. The profile must have been tuned on this machine
    /// (fingerprint-checked at startup).
    pub tune_profile: Option<PathBuf>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            schedule: Policy::default(),
            backend: Backend::RsrPlusPlus,
            k: 0,
            plan_dir: None,
            tune_profile: None,
        }
    }
}

/// A running engine: submit requests, receive responses.
///
/// The response receiver is Mutex-wrapped so the engine is `Sync`; in
/// multi-consumer settings (the TCP server) a single dispatcher thread
/// should own consumption (see `server::ResponseHub`).
pub struct InferenceEngine {
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    responses: std::sync::Mutex<mpsc::Receiver<Response>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
}

impl InferenceEngine {
    /// Start workers.
    ///
    /// On the RSR++ backend (the default), model preparation goes
    /// through a process-shared [`PlanStore`]: each weight matrix is
    /// preprocessed (paper Algorithm 1) — or loaded from a packed
    /// `.rsrz` artifact when [`EngineConfig::plan_dir`] is set — **at
    /// most once**, and every worker thread shares the resulting index,
    /// holding only per-thread scratch. Other backends keep the
    /// original prepare-per-worker path.
    pub fn start(weights: Arc<ModelWeights>, cfg: EngineConfig) -> Result<Self> {
        let store = Self::build_plan_store(&weights, &cfg)?;
        Self::spawn(weights, cfg, store)
    }

    /// Resolve the `(plan_dir, backend)` policy into the optional
    /// shared store [`start`](Self::start) uses. The single source of
    /// truth for that policy: `rsr serve` calls it once and hands the
    /// same store to every replica via
    /// [`start_with_store`](Self::start_with_store).
    pub fn build_plan_store(
        weights: &Arc<ModelWeights>,
        cfg: &EngineConfig,
    ) -> Result<Option<Arc<PlanStore>>> {
        // Load + host-verify the tuning profile first: a foreign or
        // corrupt .rsrt must fail startup before any preprocessing is
        // paid for.
        let profile = match &cfg.tune_profile {
            None => None,
            Some(path) => {
                if cfg.backend != Backend::RsrPlusPlus {
                    return Err(Error::Config(format!(
                        "tuning profiles drive the rsr++ plan path; backend {} \
                         cannot use --profile",
                        cfg.backend.name()
                    )));
                }
                let p = TuneProfile::load(path).map_err(|e| {
                    Error::Artifact(format!("loading {}: {e}", path.display()))
                })?;
                p.verify_host()?;
                println!(
                    "loaded tuning profile {} ({} layers, machine {})",
                    path.display(),
                    p.len(),
                    p.fingerprint.describe()
                );
                // The tuner measures the parallel backend on an
                // uncontended pool; many engine workers contend the
                // checkout (losers fall back to serial), so the tuned
                // ranking may not hold — say so rather than silently
                // serving a loser.
                let parallel_layers = p
                    .layers
                    .iter()
                    .filter(|l| l.winner().backend == TunedBackend::Parallel)
                    .count();
                if parallel_layers > 0 && cfg.workers > 1 {
                    eprintln!(
                        "warning: profile selects the parallel backend for \
                         {parallel_layers} layer(s), but it was measured without \
                         pool contention; with {} workers the shared pool will \
                         contend and rsr++ may serve faster — consider --workers 1 \
                         or re-tuning under load",
                        cfg.workers
                    );
                }
                // The batched candidate is microbenched at one
                // synthetic batch size (recorded in the .rsrt header);
                // an engine decoding at a materially different
                // occupancy may see a different ranking.
                let batched_layers = p
                    .layers
                    .iter()
                    .filter(|l| l.winner().backend == TunedBackend::Batched)
                    .count();
                let tuned_b = (p.bench_batch as usize).max(1);
                let slots = cfg.batch.max_slots.max(1);
                if batched_layers > 0 && slots.max(tuned_b) >= 2 * slots.min(tuned_b) {
                    eprintln!(
                        "warning: profile's batched winner ({batched_layers} \
                         layer(s)) was measured at batch {tuned_b}, but the engine \
                         decodes with max_slots {slots} — the measured ranking may \
                         not hold at this occupancy; serve --max-slots {tuned_b} to \
                         match the measurement, or treat batched winners as \
                         approximate"
                    );
                }
                Some(p)
            }
        };
        let with_profile = |store: PlanStore| -> Result<PlanStore> {
            match profile {
                Some(p) => store.with_profile(p),
                None => Ok(store),
            }
        };
        match (&cfg.plan_dir, cfg.backend) {
            (Some(dir), Backend::RsrPlusPlus) => {
                let store = with_profile(PlanStore::open(dir)?)?;
                // Resolve every layer now: a missing or corrupt
                // artifact fails engine startup, not the first request.
                store.preload(&weights.matrix_names())?;
                // One whole-store weights check here, so worker builds
                // skip their per-layer fingerprint recomputation.
                store.verify_fingerprints(weights)?;
                Ok(Some(Arc::new(store)))
            }
            (Some(_), other) => Err(Error::Config(format!(
                "plan artifacts execute via rsr++; backend {} cannot use --plans",
                other.name()
            ))),
            (None, Backend::RsrPlusPlus) => {
                let store =
                    with_profile(PlanStore::for_model(Arc::clone(weights), cfg.k))?;
                // Preprocess every layer HERE, before workers spawn:
                // lazily-racing worker threads would otherwise all miss
                // the cold cache together and run Algorithm 1 in
                // parallel duplicate — the exact W× cost this store
                // exists to eliminate.
                store.preload(&weights.matrix_names())?;
                Ok(Some(Arc::new(store)))
            }
            (None, _) => Ok(None),
        }
    }

    /// Start workers against an externally owned [`PlanStore`] — the
    /// multi-replica path: `rsr serve --replicas N` builds one store
    /// and passes the same `Arc` to every replica, so the whole process
    /// holds each layer's index exactly once. The store's plans execute
    /// via RSR++; `cfg.backend`/`cfg.k`/`cfg.plan_dir` are ignored on
    /// this path.
    pub fn start_with_store(
        weights: Arc<ModelWeights>,
        cfg: EngineConfig,
        store: Arc<PlanStore>,
    ) -> Result<Self> {
        Self::spawn(weights, cfg, Some(store))
    }

    fn spawn(
        weights: Arc<ModelWeights>,
        cfg: EngineConfig,
        store: Option<Arc<PlanStore>>,
    ) -> Result<Self> {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Response>();
        let inflight = Arc::new(AtomicUsize::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for wid in 0..cfg.workers.max(1) {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let tx = tx.clone();
            let weights = Arc::clone(&weights);
            let inflight = Arc::clone(&inflight);
            let shutdown = Arc::clone(&shutdown);
            let store = store.clone();
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rsr-worker-{wid}"))
                    .spawn(move || {
                        // Fixed weights — preprocessing amortizes (the
                        // paper's core observation): shared plans from
                        // the store, or per-worker prepare otherwise.
                        let built = match &store {
                            Some(s) => Transformer::from_plan_store(&weights, s),
                            None => Transformer::from_weights(&weights, cfg.backend, cfg.k),
                        };
                        let model = match built {
                            Ok(m) => m,
                            Err(e) => {
                                eprintln!("worker {wid}: model build failed: {e}");
                                return;
                            }
                        };
                        worker_loop(model, queue, metrics, tx, inflight, shutdown, &cfg);
                    })
                    .map_err(|e| Error::Serving(e.to_string()))?,
            );
        }
        Ok(Self {
            queue,
            metrics,
            responses: std::sync::Mutex::new(rx),
            workers,
            inflight,
            shutdown,
        })
    }

    /// Submit a request; fails fast under backpressure.
    pub fn submit(&self, request: Request) -> Result<()> {
        let res = self.queue.try_push(request);
        self.metrics.record_admission(res.is_ok());
        match res {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(PushError::Full) => {
                Err(Error::Serving("queue full — retry later".into()))
            }
            Err(PushError::Closed) => Err(Error::Serving("engine shut down".into())),
        }
    }

    /// Receive the next completed response (blocking with timeout).
    /// Single-consumer: concurrent callers serialize on an internal
    /// lock and may steal each other's responses — multi-connection
    /// fronts must use one dispatcher (see `server::ResponseHub`).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.responses.lock().unwrap().recv_timeout(timeout).ok()
    }

    /// Requests admitted but not yet answered.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Queue depth + inflight, the router's load signal.
    pub fn load(&self) -> usize {
        self.queue.len() + self.inflight()
    }

    /// Metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting work, drain, and join workers.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    model: Transformer,
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    tx: mpsc::Sender<Response>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    cfg: &EngineConfig,
) {
    // `max_slots == 1` with `prefill_chunk == 1` degrades to the
    // strictly sequential loop — the exact pre-batching code path, bit
    // for bit. Anything larger runs continuous batching: a slot map
    // stepped in lockstep, finished sequences retiring and queued
    // requests joining mid-flight. A single slot with a chunk > 1
    // still takes the continuous loop: chunked prefill pays off even
    // with no batchmates (that is the time-to-first-token case).
    if cfg.batch.max_slots <= 1 && cfg.batch.prefill_chunk <= 1 {
        sequential_loop(model, queue, metrics, tx, inflight, shutdown, cfg);
    } else {
        continuous_loop(model, queue, metrics, tx, inflight, shutdown, cfg);
    }
}

fn sequential_loop(
    mut model: Transformer,
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    tx: mpsc::Sender<Response>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    cfg: &EngineConfig,
) {
    let batcher = Batcher::new(Arc::clone(&queue), cfg.batch);
    let mut rng = Rng::new(0xC0FFEE);
    loop {
        if shutdown.load(Ordering::Relaxed) && queue.is_empty() {
            break;
        }
        let Some(batch) = batcher.next_batch(Duration::from_millis(50)) else {
            if queue.is_closed() && queue.is_empty() {
                break;
            }
            continue;
        };
        for request in schedule(batch.requests, cfg.schedule) {
            let response = run_request(&mut model, &request, &mut rng);
            match &response.error {
                None => {
                    metrics.record(
                        &response.timing,
                        response.tokens.len(),
                        request.prompt.len(),
                    );
                }
                Some(_) => metrics.record_failure(),
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
            if tx.send(response).is_err() {
                return; // receiver dropped — engine gone
            }
        }
    }
}

/// One live sequence in the continuous-batching slot map.
struct SlotState {
    request: Request,
    /// Next token to feed while decoding (the last sampled token).
    /// While prefilling, the step assembly reads the chunk straight
    /// from `request.prompt[prompt_pos..]` instead.
    next_input: u32,
    /// Prompt tokens consumed so far; `== prompt.len()` once decoding.
    prompt_pos: usize,
    /// Generated tokens.
    tokens: Vec<u32>,
    picked_up: Instant,
    /// Set by the step that consumes the final prompt token.
    prefill_done: Option<Instant>,
}

/// Retire one sequence: build its response, account it, and send it.
/// Returns `false` when the response receiver is gone (worker exits).
fn finish_slot(
    slot: SlotState,
    error: Option<String>,
    metrics: &Metrics,
    inflight: &AtomicUsize,
    tx: &mpsc::Sender<Response>,
) -> bool {
    let now = Instant::now();
    let prompt_tokens = slot.request.prompt.len();
    let response = match error {
        Some(msg) => Response::err(slot.request.id, msg),
        None => {
            let prefill_end = slot.prefill_done.unwrap_or(now);
            let timing = Timing {
                queue: slot.picked_up.duration_since(slot.request.arrival),
                prefill: prefill_end.duration_since(slot.picked_up),
                decode: now.duration_since(prefill_end),
            };
            Response::ok(slot.request.id, slot.tokens, timing)
        }
    };
    match &response.error {
        None => metrics.record(&response.timing, response.tokens.len(), prompt_tokens),
        Some(_) => metrics.record_failure(),
    }
    inflight.fetch_sub(1, Ordering::Relaxed);
    tx.send(response).is_ok()
}

/// The continuous-batching worker: a slot map of up to
/// `cfg.batch.max_slots` sequences stepped in lockstep through
/// [`Transformer::forward_chunk`]. Each step feeds every decoding slot
/// its last sampled token, and every **prefilling** slot a chunk of up
/// to `cfg.batch.prefill_chunk` unconsumed prompt tokens stacked along
/// the batch dimension — so a prompt is consumed as a matrix–matrix
/// workload (one shared-index read per layer per chunk) instead of one
/// decode-rate step per token, which is where time-to-first-token is
/// won. Finished sequences retire their slot; queued requests are
/// admitted into free slots between steps without ever stalling the
/// live ones ([`Batcher::poll`]).
///
/// **Per-step chunk budget:** the total prompt rows one step stacks is
/// capped at `max(prefill_chunk, prefilling slots)` — the fair share
/// `prefill_chunk / prefilling` per slot, floored at one token so
/// every slot still advances each step (more prefilling slots than
/// budget degrades each to one-token prefill, the pre-chunk baseline).
/// One long prompt inflates a step by at most `prefill_chunk − 1` rows
/// and can never starve decoding batchmates of their once-per-step
/// token.
///
/// Per-sequence results are independent of batchmates and chunking is
/// bit-identical to one-token prefill (see
/// [`Transformer::forward_chunk`]), so joins, retirements and chunk
/// boundaries never perturb the tokens of in-flight sequences.
fn continuous_loop(
    mut model: Transformer,
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    tx: mpsc::Sender<Response>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    cfg: &EngineConfig,
) {
    let max_slots = cfg.batch.max_slots.max(1);
    let prefill_chunk = cfg.batch.prefill_chunk.max(1);
    model.ensure_slots(max_slots);
    // The idle pickup must never admit more requests than there are
    // slots to hold them.
    let policy = BatchPolicy { max_batch: cfg.batch.max_batch.min(max_slots), ..cfg.batch };
    let batcher = Batcher::new(Arc::clone(&queue), policy);
    let mut rng = Rng::new(0xC0FFEE);
    let sampler = Sampler::Greedy;
    let max_seq = model.config().max_seq_len;
    let vocab = model.config().vocab_size;
    let mut slots: Vec<Option<SlotState>> = (0..max_slots).map(|_| None).collect();
    let mut step_slots: Vec<usize> = Vec::with_capacity(max_slots);
    let mut step_tokens: Vec<u32> = Vec::with_capacity(max_slots * prefill_chunk);
    let mut step_counts: Vec<usize> = Vec::with_capacity(max_slots);
    let mut len_after: Vec<usize> = Vec::with_capacity(max_slots);
    let mut retired: Vec<usize> = Vec::with_capacity(max_slots);
    loop {
        let live = slots.iter().filter(|s| s.is_some()).count();
        // Admission: block when idle (same idle/shutdown semantics as
        // the sequential loop); top up free slots without waiting while
        // sequences are in flight.
        let admitted = if live == 0 {
            if shutdown.load(Ordering::Relaxed) && queue.is_empty() {
                break;
            }
            let Some(batch) = batcher.next_batch(Duration::from_millis(50)) else {
                if queue.is_closed() && queue.is_empty() {
                    break;
                }
                continue;
            };
            batch.requests
        } else {
            batcher.poll(max_slots - live)
        };
        for request in schedule(admitted, cfg.schedule) {
            if request.prompt.is_empty() {
                metrics.record_failure();
                inflight.fetch_sub(1, Ordering::Relaxed);
                if tx.send(Response::err(request.id, "empty prompt")).is_err() {
                    return;
                }
                continue;
            }
            let free = slots
                .iter()
                .position(|s| s.is_none())
                .expect("admission is capped at the free-slot count");
            model.reset_slot(free);
            let next_input = request.prompt[0];
            slots[free] = Some(SlotState {
                picked_up: Instant::now(),
                next_input,
                prompt_pos: 0,
                tokens: Vec::with_capacity(request.max_new_tokens),
                prefill_done: None,
                request,
            });
        }
        // Fair-share chunk budget for this step: `prefill_chunk` total
        // prompt rows, split across the slots currently prefilling
        // (integer share, floor 1 — every slot always advances). With
        // one prefilling slot the full chunk goes to it; with many, no
        // single prompt can monopolize the step.
        let prefilling = slots
            .iter()
            .flatten()
            .filter(|st| st.prompt_pos < st.request.prompt.len())
            .count();
        let share = if prefilling == 0 { 1 } else { (prefill_chunk / prefilling).max(1) };
        // Assemble the ragged step, retiring slots that cannot take
        // another token — a bad request fails alone, never the batch.
        step_slots.clear();
        step_tokens.clear();
        step_counts.clear();
        len_after.clear();
        for i in 0..max_slots {
            let Some(st) = &slots[i] else { continue };
            let prompt = &st.request.prompt;
            let prefill = st.prompt_pos < prompt.len();
            let phase = if prefill { "prefill" } else { "decode" };
            let seq = model.seq_len_slot(i);
            // Validate the first token the step would feed — exactly
            // the failure (and message) the one-token path produced.
            let first = if prefill { prompt[st.prompt_pos] } else { st.next_input };
            let failure = if first as usize >= vocab {
                Some(format!("{phase}: token {first} out of vocab"))
            } else if seq >= max_seq {
                Some(format!("{phase}: sequence exceeds max_seq_len"))
            } else {
                None
            };
            if let Some(msg) = failure {
                let st = slots[i].take().expect("checked live above");
                if !finish_slot(st, Some(msg), &metrics, &inflight, &tx) {
                    return;
                }
                continue;
            }
            let take = if prefill {
                let mut take =
                    (prompt.len() - st.prompt_pos).min(share).min(max_seq - seq);
                // An invalid token mid-chunk truncates the chunk to the
                // valid prefix: the prefix is consumed exactly as the
                // one-token path would consume it, and the bad token
                // fails on the next step with the same message.
                for (j, &t) in prompt[st.prompt_pos..st.prompt_pos + take]
                    .iter()
                    .enumerate()
                {
                    if t as usize >= vocab {
                        take = j;
                        break;
                    }
                }
                debug_assert!(take >= 1, "first token was validated above");
                step_tokens.extend_from_slice(&prompt[st.prompt_pos..st.prompt_pos + take]);
                take
            } else {
                step_tokens.push(st.next_input);
                1
            };
            step_slots.push(i);
            step_counts.push(take);
            len_after.push(seq + take);
        }
        if step_slots.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let logits = match model.forward_chunk(&step_tokens, &step_slots, &step_counts) {
            Ok(l) => l,
            Err(e) => {
                // Per-slot preconditions were checked above, so a step
                // failure is an engine-bug class: fail the live rows
                // loudly rather than wedging them.
                let msg = e.to_string();
                for &i in &step_slots {
                    let st = slots[i].take().expect("was in the step");
                    if !finish_slot(st, Some(format!("step: {msg}")), &metrics, &inflight, &tx)
                    {
                        return;
                    }
                }
                continue;
            }
        };
        let step_dur = t0.elapsed();
        // Advance every slot: prefill consumes its chunk silently; the
        // step that feeds the final prompt token samples the first
        // generated one from the chunk's **last row** (exactly
        // `run_request`'s sequencing, per slot).
        retired.clear();
        let mut row0 = 0usize;
        for (idx, &i) in step_slots.iter().enumerate() {
            let c = step_counts[idx];
            let last_row = row0 + c - 1;
            row0 += c;
            let st = slots[i].as_mut().expect("was in the step");
            if st.prompt_pos < st.request.prompt.len() {
                st.prompt_pos += c;
                if st.prompt_pos < st.request.prompt.len() {
                    continue; // mid-prefill: logits unused
                }
                // This step consumed the final prompt token.
                st.prefill_done = Some(Instant::now());
                if st.request.max_new_tokens == 0 {
                    retired.push(i);
                    continue;
                }
            }
            let next =
                sampler.sample(&logits[last_row * vocab..(last_row + 1) * vocab], &mut rng);
            st.tokens.push(next);
            if st.tokens.len() >= st.request.max_new_tokens
                || next == crate::model::tokenizer::EOS
                || len_after[idx] >= max_seq
            {
                retired.push(i);
            } else {
                st.next_input = next;
            }
        }
        metrics.record_decode_step(step_slots.len(), step_dur);
        for &i in &retired {
            let st = slots[i].take().expect("retired from the step");
            if !finish_slot(st, None, &metrics, &inflight, &tx) {
                return;
            }
        }
    }
}

fn run_request(model: &mut Transformer, request: &Request, rng: &mut Rng) -> Response {
    let picked_up = Instant::now();
    let queue_time = picked_up.duration_since(request.arrival);

    model.reset();
    let mut timing = Timing { queue: queue_time, ..Timing::default() };

    // Prefill.
    let t0 = Instant::now();
    for &t in &request.prompt {
        if let Err(e) = model.forward_token(t) {
            return Response::err(request.id, format!("prefill: {e}"));
        }
    }
    timing.prefill = t0.elapsed();
    if request.prompt.is_empty() {
        return Response::err(request.id, "empty prompt");
    }

    // Decode (greedy — the §5.3 equality-comparable setting).
    let t0 = Instant::now();
    let mut tokens = Vec::with_capacity(request.max_new_tokens);
    let sampler = Sampler::Greedy;
    for _ in 0..request.max_new_tokens {
        let logits = match model_logits(model) {
            Ok(l) => l,
            Err(e) => return Response::err(request.id, format!("decode: {e}")),
        };
        let next = sampler.sample(&logits, rng);
        tokens.push(next);
        if next == crate::model::tokenizer::EOS
            || model.seq_len() >= model.config().max_seq_len
        {
            break;
        }
        if let Err(e) = model.forward_token(next) {
            return Response::err(request.id, format!("decode: {e}"));
        }
    }
    timing.decode = t0.elapsed();
    Response::ok(request.id, tokens, timing)
}

fn model_logits(model: &Transformer) -> Result<Vec<f32>> {
    // The logits of the last forward pass live in the model; we copy
    // them because sampling mutates nothing but we need ownership.
    Ok(model.last_logits().to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny_engine(cfg: EngineConfig) -> InferenceEngine {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        InferenceEngine::start(weights, cfg).unwrap()
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(resp.id, 1);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.tokens.is_empty());
        assert!(resp.timing.total() > Duration::ZERO);
        engine.shutdown();
    }

    #[test]
    fn multiple_workers_serve_many_requests() {
        let engine = tiny_engine(EngineConfig { workers: 3, ..Default::default() });
        for i in 0..12 {
            engine.submit(Request::new(i, vec![1 + i as u32, 2, 3], 3)).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..12 {
            let r = engine.recv_timeout(Duration::from_secs(60)).expect("resp");
            assert!(r.error.is_none());
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), 12);
        assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 12);
        engine.shutdown();
    }

    #[test]
    fn continuous_and_sequential_engines_agree_token_for_token() {
        // The batched-decode acceptance check at the engine level:
        // greedy responses from a continuous-batching engine must match
        // a strictly sequential (`max_slots == 1`) engine per request.
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let prompts: Vec<Vec<u32>> =
            (0..6u32).map(|i| vec![10 + i, 20, 30 + (i % 3)]).collect();
        // `prefill_chunk: 1` alongside `max_slots: 1` pins the strictly
        // sequential worker loop (the default chunk of 8 would route a
        // single slot through the continuous loop, and this test exists
        // to compare the two loops, not the continuous loop to itself).
        let run = |max_slots: usize, prefill_chunk: usize| -> Vec<Vec<u32>> {
            let engine = InferenceEngine::start(
                Arc::clone(&weights),
                EngineConfig {
                    workers: 1,
                    batch: BatchPolicy { max_slots, prefill_chunk, ..Default::default() },
                    ..Default::default()
                },
            )
            .unwrap();
            for (i, p) in prompts.iter().enumerate() {
                engine.submit(Request::new(i as u64, p.clone(), 6)).unwrap();
            }
            let mut out: Vec<(u64, Vec<u32>)> = (0..prompts.len())
                .map(|_| {
                    let r =
                        engine.recv_timeout(Duration::from_secs(60)).expect("response");
                    assert!(r.error.is_none(), "{:?}", r.error);
                    (r.id, r.tokens)
                })
                .collect();
            engine.shutdown();
            out.sort_by_key(|(id, _)| *id);
            out.into_iter().map(|(_, t)| t).collect()
        };
        let sequential = run(1, 1);
        assert_eq!(run(4, 8), sequential, "batched+chunked decode must match sequential");
        assert_eq!(run(4, 1), sequential, "batched unchunked decode must match sequential");
    }

    #[test]
    fn batched_engine_reports_occupancy_above_one() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        for i in 0..8 {
            engine.submit(Request::new(i, vec![5 + i as u32, 6, 7], 24)).unwrap();
        }
        for _ in 0..8 {
            let r = engine.recv_timeout(Duration::from_secs(60)).expect("response");
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        let snap = engine.metrics().snapshot();
        assert!(snap.get("decode_steps").unwrap().as_f64().unwrap() > 0.0);
        let occ = snap.get("batch_occupancy_mean").unwrap().as_f64().unwrap();
        assert!(occ > 1.0, "8 concurrent requests must batch (occupancy {occ})");
        assert!(snap.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        engine.shutdown();
    }

    #[test]
    fn serves_from_packed_plan_artifacts() {
        use crate::kernels::artifact::{ternary_fingerprint, PlanArtifact};
        use crate::kernels::index::TernaryRsrIndex;
        use crate::kernels::optimal_k::optimal_k_rsrpp;

        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let dir = std::env::temp_dir()
            .join(format!("rsr-engine-plans-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (name, m, scale) in weights.named_matrices() {
            let k = optimal_k_rsrpp(m.rows());
            let art = PlanArtifact::ternary(
                name.clone(),
                TernaryRsrIndex::preprocess(m, k),
                scale,
            )
            .unwrap()
            .with_weights_fingerprint(ternary_fingerprint(m));
            art.save(dir.join(format!("{name}.rsrz"))).unwrap();
        }

        let engine = InferenceEngine::start(
            Arc::clone(&weights),
            EngineConfig { workers: 2, plan_dir: Some(dir.clone()), ..Default::default() },
        )
        .unwrap();
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        engine.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_dir_requires_rsrpp_backend() {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let res = InferenceEngine::start(
            weights,
            EngineConfig {
                backend: Backend::Standard,
                plan_dir: Some(std::path::PathBuf::from("/nonexistent")),
                ..Default::default()
            },
        );
        assert!(res.is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            queue_capacity: 1,
            ..Default::default()
        });
        // Stuff the queue beyond capacity; at least one must be rejected.
        let mut rejected = 0;
        for i in 0..20 {
            if engine.submit(Request::new(i, vec![5; 16], 8)).is_err() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        // Drain what was admitted.
        while engine.recv_timeout(Duration::from_secs(10)).is_some() {
            if engine.inflight() == 0 {
                break;
            }
        }
        engine.shutdown();
    }

    #[test]
    fn malformed_request_yields_error_response() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        // Out-of-vocab token → prefill error, engine survives.
        engine.submit(Request::new(5, vec![999_999], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_some());
        // Engine still serves afterwards.
        engine.submit(Request::new(6, vec![10], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none());
        engine.shutdown();
    }
}
