//! The inference engine: worker threads each owning a `Transformer`
//! instance, pulling batches from the shared queue, running
//! prefill → decode per request, and reporting completions.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{BoundedQueue, PushError};
use super::request::{Request, Response, Timing};
use super::scheduler::{schedule, Policy};
use crate::error::{Error, Result};
use crate::kernels::Backend;
use crate::model::sampler::Sampler;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::runtime::plan_store::PlanStore;
use crate::tune::candidates::TunedBackend;
use crate::tune::profile::TuneProfile;
use crate::util::rng::Rng;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (each with its own `Transformer`).
    pub workers: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Batching policy.
    pub batch: BatchPolicy,
    /// Scheduling policy within a batch.
    pub schedule: Policy,
    /// Multiply backend for the model.
    pub backend: Backend,
    /// Blocking parameter (0 → analytic optimum).
    pub k: usize,
    /// Directory of `.rsrz` plan artifacts (the `rsr pack` output).
    /// When set — and the backend is an RSR plan backend — workers load
    /// preprocessed plans from disk instead of running Algorithm 1 at
    /// startup. When `None`, plans are still built only once per
    /// process and shared across workers via the [`PlanStore`].
    pub plan_dir: Option<PathBuf>,
    /// `.rsrt` tuning profile (the `rsr tune` output). When set — RSR++
    /// backend only, like `plan_dir` — every layer materializes with
    /// its measured `(k, backend)` winner instead of the analytic
    /// defaults. The profile must have been tuned on this machine
    /// (fingerprint-checked at startup).
    pub tune_profile: Option<PathBuf>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            schedule: Policy::default(),
            backend: Backend::RsrPlusPlus,
            k: 0,
            plan_dir: None,
            tune_profile: None,
        }
    }
}

/// A running engine: submit requests, receive responses.
///
/// The response receiver is Mutex-wrapped so the engine is `Sync`; in
/// multi-consumer settings (the TCP server) a single dispatcher thread
/// should own consumption (see `server::ResponseHub`).
pub struct InferenceEngine {
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    responses: std::sync::Mutex<mpsc::Receiver<Response>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
}

impl InferenceEngine {
    /// Start workers.
    ///
    /// On the RSR++ backend (the default), model preparation goes
    /// through a process-shared [`PlanStore`]: each weight matrix is
    /// preprocessed (paper Algorithm 1) — or loaded from a packed
    /// `.rsrz` artifact when [`EngineConfig::plan_dir`] is set — **at
    /// most once**, and every worker thread shares the resulting index,
    /// holding only per-thread scratch. Other backends keep the
    /// original prepare-per-worker path.
    pub fn start(weights: Arc<ModelWeights>, cfg: EngineConfig) -> Result<Self> {
        let store = Self::build_plan_store(&weights, &cfg)?;
        Self::spawn(weights, cfg, store)
    }

    /// Resolve the `(plan_dir, backend)` policy into the optional
    /// shared store [`start`](Self::start) uses. The single source of
    /// truth for that policy: `rsr serve` calls it once and hands the
    /// same store to every replica via
    /// [`start_with_store`](Self::start_with_store).
    pub fn build_plan_store(
        weights: &Arc<ModelWeights>,
        cfg: &EngineConfig,
    ) -> Result<Option<Arc<PlanStore>>> {
        // Load + host-verify the tuning profile first: a foreign or
        // corrupt .rsrt must fail startup before any preprocessing is
        // paid for.
        let profile = match &cfg.tune_profile {
            None => None,
            Some(path) => {
                if cfg.backend != Backend::RsrPlusPlus {
                    return Err(Error::Config(format!(
                        "tuning profiles drive the rsr++ plan path; backend {} \
                         cannot use --profile",
                        cfg.backend.name()
                    )));
                }
                let p = TuneProfile::load(path).map_err(|e| {
                    Error::Artifact(format!("loading {}: {e}", path.display()))
                })?;
                p.verify_host()?;
                println!(
                    "loaded tuning profile {} ({} layers, machine {})",
                    path.display(),
                    p.len(),
                    p.fingerprint.describe()
                );
                // The tuner measures the parallel backend on an
                // uncontended pool; many engine workers contend the
                // checkout (losers fall back to serial), so the tuned
                // ranking may not hold — say so rather than silently
                // serving a loser.
                let parallel_layers = p
                    .layers
                    .iter()
                    .filter(|l| l.winner().backend == TunedBackend::Parallel)
                    .count();
                if parallel_layers > 0 && cfg.workers > 1 {
                    eprintln!(
                        "warning: profile selects the parallel backend for \
                         {parallel_layers} layer(s), but it was measured without \
                         pool contention; with {} workers the shared pool will \
                         contend and rsr++ may serve faster — consider --workers 1 \
                         or re-tuning under load",
                        cfg.workers
                    );
                }
                Some(p)
            }
        };
        let with_profile = |store: PlanStore| -> Result<PlanStore> {
            match profile {
                Some(p) => store.with_profile(p),
                None => Ok(store),
            }
        };
        match (&cfg.plan_dir, cfg.backend) {
            (Some(dir), Backend::RsrPlusPlus) => {
                let store = with_profile(PlanStore::open(dir)?)?;
                // Resolve every layer now: a missing or corrupt
                // artifact fails engine startup, not the first request.
                store.preload(&weights.matrix_names())?;
                // One whole-store weights check here, so worker builds
                // skip their per-layer fingerprint recomputation.
                store.verify_fingerprints(weights)?;
                Ok(Some(Arc::new(store)))
            }
            (Some(_), other) => Err(Error::Config(format!(
                "plan artifacts execute via rsr++; backend {} cannot use --plans",
                other.name()
            ))),
            (None, Backend::RsrPlusPlus) => {
                let store =
                    with_profile(PlanStore::for_model(Arc::clone(weights), cfg.k))?;
                // Preprocess every layer HERE, before workers spawn:
                // lazily-racing worker threads would otherwise all miss
                // the cold cache together and run Algorithm 1 in
                // parallel duplicate — the exact W× cost this store
                // exists to eliminate.
                store.preload(&weights.matrix_names())?;
                Ok(Some(Arc::new(store)))
            }
            (None, _) => Ok(None),
        }
    }

    /// Start workers against an externally owned [`PlanStore`] — the
    /// multi-replica path: `rsr serve --replicas N` builds one store
    /// and passes the same `Arc` to every replica, so the whole process
    /// holds each layer's index exactly once. The store's plans execute
    /// via RSR++; `cfg.backend`/`cfg.k`/`cfg.plan_dir` are ignored on
    /// this path.
    pub fn start_with_store(
        weights: Arc<ModelWeights>,
        cfg: EngineConfig,
        store: Arc<PlanStore>,
    ) -> Result<Self> {
        Self::spawn(weights, cfg, Some(store))
    }

    fn spawn(
        weights: Arc<ModelWeights>,
        cfg: EngineConfig,
        store: Option<Arc<PlanStore>>,
    ) -> Result<Self> {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Response>();
        let inflight = Arc::new(AtomicUsize::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for wid in 0..cfg.workers.max(1) {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let tx = tx.clone();
            let weights = Arc::clone(&weights);
            let inflight = Arc::clone(&inflight);
            let shutdown = Arc::clone(&shutdown);
            let store = store.clone();
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rsr-worker-{wid}"))
                    .spawn(move || {
                        // Fixed weights — preprocessing amortizes (the
                        // paper's core observation): shared plans from
                        // the store, or per-worker prepare otherwise.
                        let built = match &store {
                            Some(s) => Transformer::from_plan_store(&weights, s),
                            None => Transformer::from_weights(&weights, cfg.backend, cfg.k),
                        };
                        let model = match built {
                            Ok(m) => m,
                            Err(e) => {
                                eprintln!("worker {wid}: model build failed: {e}");
                                return;
                            }
                        };
                        worker_loop(model, queue, metrics, tx, inflight, shutdown, &cfg);
                    })
                    .map_err(|e| Error::Serving(e.to_string()))?,
            );
        }
        Ok(Self {
            queue,
            metrics,
            responses: std::sync::Mutex::new(rx),
            workers,
            inflight,
            shutdown,
        })
    }

    /// Submit a request; fails fast under backpressure.
    pub fn submit(&self, request: Request) -> Result<()> {
        let res = self.queue.try_push(request);
        self.metrics.record_admission(res.is_ok());
        match res {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(PushError::Full) => {
                Err(Error::Serving("queue full — retry later".into()))
            }
            Err(PushError::Closed) => Err(Error::Serving("engine shut down".into())),
        }
    }

    /// Receive the next completed response (blocking with timeout).
    /// Single-consumer: concurrent callers serialize on an internal
    /// lock and may steal each other's responses — multi-connection
    /// fronts must use one dispatcher (see `server::ResponseHub`).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.responses.lock().unwrap().recv_timeout(timeout).ok()
    }

    /// Requests admitted but not yet answered.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Queue depth + inflight, the router's load signal.
    pub fn load(&self) -> usize {
        self.queue.len() + self.inflight()
    }

    /// Metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting work, drain, and join workers.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    mut model: Transformer,
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    tx: mpsc::Sender<Response>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    cfg: &EngineConfig,
) {
    let batcher = Batcher::new(Arc::clone(&queue), cfg.batch);
    let mut rng = Rng::new(0xC0FFEE);
    loop {
        if shutdown.load(Ordering::Relaxed) && queue.is_empty() {
            break;
        }
        let Some(batch) = batcher.next_batch(Duration::from_millis(50)) else {
            if queue.is_closed() && queue.is_empty() {
                break;
            }
            continue;
        };
        for request in schedule(batch.requests, cfg.schedule) {
            let response = run_request(&mut model, &request, &mut rng);
            match &response.error {
                None => metrics.record(&response.timing, response.tokens.len()),
                Some(_) => metrics.record_failure(),
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
            if tx.send(response).is_err() {
                return; // receiver dropped — engine gone
            }
        }
    }
}

fn run_request(model: &mut Transformer, request: &Request, rng: &mut Rng) -> Response {
    let picked_up = Instant::now();
    let queue_time = picked_up.duration_since(request.arrival);

    model.reset();
    let mut timing = Timing { queue: queue_time, ..Timing::default() };

    // Prefill.
    let t0 = Instant::now();
    for &t in &request.prompt {
        if let Err(e) = model.forward_token(t) {
            return Response::err(request.id, format!("prefill: {e}"));
        }
    }
    timing.prefill = t0.elapsed();
    if request.prompt.is_empty() {
        return Response::err(request.id, "empty prompt");
    }

    // Decode (greedy — the §5.3 equality-comparable setting).
    let t0 = Instant::now();
    let mut tokens = Vec::with_capacity(request.max_new_tokens);
    let sampler = Sampler::Greedy;
    for _ in 0..request.max_new_tokens {
        let logits = match model_logits(model) {
            Ok(l) => l,
            Err(e) => return Response::err(request.id, format!("decode: {e}")),
        };
        let next = sampler.sample(&logits, rng);
        tokens.push(next);
        if next == crate::model::tokenizer::EOS
            || model.seq_len() >= model.config().max_seq_len
        {
            break;
        }
        if let Err(e) = model.forward_token(next) {
            return Response::err(request.id, format!("decode: {e}"));
        }
    }
    timing.decode = t0.elapsed();
    Response::ok(request.id, tokens, timing)
}

fn model_logits(model: &Transformer) -> Result<Vec<f32>> {
    // The logits of the last forward pass live in the model; we copy
    // them because sampling mutates nothing but we need ownership.
    Ok(model.last_logits().to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny_engine(cfg: EngineConfig) -> InferenceEngine {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        InferenceEngine::start(weights, cfg).unwrap()
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(resp.id, 1);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.tokens.is_empty());
        assert!(resp.timing.total() > Duration::ZERO);
        engine.shutdown();
    }

    #[test]
    fn multiple_workers_serve_many_requests() {
        let engine = tiny_engine(EngineConfig { workers: 3, ..Default::default() });
        for i in 0..12 {
            engine.submit(Request::new(i, vec![1 + i as u32, 2, 3], 3)).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..12 {
            let r = engine.recv_timeout(Duration::from_secs(60)).expect("resp");
            assert!(r.error.is_none());
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), 12);
        assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 12);
        engine.shutdown();
    }

    #[test]
    fn serves_from_packed_plan_artifacts() {
        use crate::kernels::artifact::{ternary_fingerprint, PlanArtifact};
        use crate::kernels::index::TernaryRsrIndex;
        use crate::kernels::optimal_k::optimal_k_rsrpp;

        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let dir = std::env::temp_dir()
            .join(format!("rsr-engine-plans-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (name, m, scale) in weights.named_matrices() {
            let k = optimal_k_rsrpp(m.rows());
            let art = PlanArtifact::ternary(
                name.clone(),
                TernaryRsrIndex::preprocess(m, k),
                scale,
            )
            .unwrap()
            .with_weights_fingerprint(ternary_fingerprint(m));
            art.save(dir.join(format!("{name}.rsrz"))).unwrap();
        }

        let engine = InferenceEngine::start(
            Arc::clone(&weights),
            EngineConfig { workers: 2, plan_dir: Some(dir.clone()), ..Default::default() },
        )
        .unwrap();
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        engine.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_dir_requires_rsrpp_backend() {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let res = InferenceEngine::start(
            weights,
            EngineConfig {
                backend: Backend::Standard,
                plan_dir: Some(std::path::PathBuf::from("/nonexistent")),
                ..Default::default()
            },
        );
        assert!(res.is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            queue_capacity: 1,
            ..Default::default()
        });
        // Stuff the queue beyond capacity; at least one must be rejected.
        let mut rejected = 0;
        for i in 0..20 {
            if engine.submit(Request::new(i, vec![5; 16], 8)).is_err() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        // Drain what was admitted.
        while engine.recv_timeout(Duration::from_secs(10)).is_some() {
            if engine.inflight() == 0 {
                break;
            }
        }
        engine.shutdown();
    }

    #[test]
    fn malformed_request_yields_error_response() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        // Out-of-vocab token → prefill error, engine survives.
        engine.submit(Request::new(5, vec![999_999], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_some());
        // Engine still serves afterwards.
        engine.submit(Request::new(6, vec![10], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none());
        engine.shutdown();
    }
}
